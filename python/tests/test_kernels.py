"""Kernel-vs-oracle: the core L1 correctness signal.

hypothesis sweeps the kernel over conditioning-set sizes, batch shapes
and near-singular correlation structures; every case asserts allclose
against the independent numpy/SVD oracle in kernels/ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ci_e, ci_s, level0, ref

TOL = dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("l", list(range(1, 9)))
def test_ci_e_matches_ref(l):
    rng = np.random.default_rng(l)
    c_ij, m1, m2 = ref.random_ci_batch(rng, 256, l)
    z = np.asarray(ci_e.ci_e(c_ij, m1, m2, l=l, block_b=128))
    np.testing.assert_allclose(z, ref.ci_e_ref(c_ij, m1, m2), **TOL)


@pytest.mark.parametrize("l", [1, 2, 3, 4])
def test_ci_e_near_singular(l):
    """m << n regime: sample correlation is near-singular; kernel must
    stay finite and agree with the SVD pinv oracle on the z decision."""
    rng = np.random.default_rng(40 + l)
    c_ij, m1, m2 = ref.random_ci_batch(rng, 128, l, near_singular=True)
    z = np.asarray(ci_e.ci_e(c_ij, m1, m2, l=l, block_b=128))
    assert np.isfinite(z).all()
    zr = ref.ci_e_ref(c_ij, m1, m2)
    # near-singular pinv can legitimately differ in magnitude between
    # Cholesky-jitter and SVD-rcond; what must agree is the large-vs-small
    # structure. Compare on the well-conditioned (finite, moderate) rows.
    ok = zr < 5.0
    np.testing.assert_allclose(z[ok], zr[ok], rtol=0.15, atol=0.15)


@pytest.mark.parametrize("l,k", [(1, 4), (2, 8), (3, 32), (5, 16)])
def test_ci_s_matches_ref(l, k):
    rng = np.random.default_rng(7 * l + k)
    c_ij, m1, m2 = ref.random_ci_batch(rng, 64, l, k=k)
    z = np.asarray(ci_s.ci_s(c_ij, m1, m2, l=l, k=k, block_b=32))
    np.testing.assert_allclose(z, ref.ci_s_ref(c_ij, m1, m2), **TOL)


def test_ci_s_shares_pinv_consistently_with_ci_e():
    """cuPC-S and cuPC-E must compute the same statistic for the same
    (i, j, S): flatten the S-batch and compare."""
    rng = np.random.default_rng(99)
    l, k = 3, 8
    c_ij, m1, m2 = ref.random_ci_batch(rng, 64, l, k=k)
    z_s = np.asarray(ci_s.ci_s(c_ij, m1, m2, l=l, k=k, block_b=32))
    m2_rep = np.repeat(m2, k, axis=0)
    z_e = np.asarray(
        ci_e.ci_e(
            c_ij.reshape(-1), m1.reshape(-1, 2, l), m2_rep, l=l, block_b=64
        )
    )
    np.testing.assert_allclose(z_s.reshape(-1), z_e, rtol=1e-4, atol=1e-5)


def test_level0_matches_ref():
    rng = np.random.default_rng(0)
    c = rng.uniform(-0.99, 0.99, 4096).astype(np.float32)
    z = np.asarray(level0.level0(c, block_b=1024))
    np.testing.assert_allclose(z, ref.level0_ref(c), **TOL)


def test_level0_symmetry():
    c = np.array([0.5, -0.5] * 512, dtype=np.float32)
    z = np.asarray(level0.level0(c, block_b=1024))
    np.testing.assert_allclose(z[0::2], z[1::2], rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    l=st.integers(1, 8),
    log_b=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_ci_e_hypothesis(l, log_b, seed):
    rng = np.random.default_rng(seed)
    b = 128 * (2**log_b)
    c_ij, m1, m2 = ref.random_ci_batch(rng, b, l)
    z = np.asarray(ci_e.ci_e(c_ij, m1, m2, l=l, block_b=128))
    np.testing.assert_allclose(z, ref.ci_e_ref(c_ij, m1, m2), rtol=5e-3, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(1, 6),
    k=st.sampled_from([2, 4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ci_s_hypothesis(l, k, seed):
    rng = np.random.default_rng(seed)
    c_ij, m1, m2 = ref.random_ci_batch(rng, 32, l, k=k)
    z = np.asarray(ci_s.ci_s(c_ij, m1, m2, l=l, k=k, block_b=32))
    np.testing.assert_allclose(z, ref.ci_s_ref(c_ij, m1, m2), rtol=5e-3, atol=5e-3)


def test_ci_e_rejects_bad_batch():
    rng = np.random.default_rng(1)
    c_ij, m1, m2 = ref.random_ci_batch(rng, 100, 2)  # not multiple of block
    with pytest.raises(AssertionError):
        ci_e.ci_e(c_ij, m1, m2, l=2, block_b=64)


def test_independence_decision_on_known_structure():
    """Construct X -> Z -> Y: rho(X,Y) != 0 but rho(X,Y|Z) ~ 0."""
    rng = np.random.default_rng(5)
    m = 20000
    x = rng.standard_normal(m)
    zv = 0.8 * x + 0.6 * rng.standard_normal(m)
    y = 0.8 * zv + 0.6 * rng.standard_normal(m)
    data = np.stack([x, y, zv], axis=1)
    d = data - data.mean(0)
    d /= d.std(0)
    c = d.T @ d / m
    # level 0: X-Y dependent
    z0 = np.asarray(
        level0.level0(np.full(1024, c[0, 1], dtype=np.float32), block_b=1024)
    )[0]
    tau_ish = 2.58 / np.sqrt(m - 3)  # alpha=0.01
    assert z0 > tau_ish
    # level 1 with S={Z}: X indep Y
    c_ij = np.full(128, c[0, 1], dtype=np.float32)
    m1 = np.tile(
        np.array([[c[0, 2]], [c[1, 2]]], dtype=np.float32), (128, 1, 1)
    )
    m2 = np.ones((128, 1, 1), dtype=np.float32)
    z1 = np.asarray(ci_e.ci_e(c_ij, m1, m2, l=1, block_b=128))[0]
    assert z1 < 2.58 / np.sqrt(m - 1 - 3)
