"""Tests for the unrolled batched matmul (the XLA-CPU GEMM-cliff fix)."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import linalg


@pytest.mark.parametrize("l", [1, 2, 3, 5])  # unrolled range
@pytest.mark.parametrize("ta,tb", [(False, False), (True, False), (False, True), (True, True)])
def test_bmm_unrolled_matches_einsum(l, ta, tb):
    rng = np.random.default_rng(l * 7 + ta * 2 + tb)
    a = rng.standard_normal((16, l, l)).astype(np.float32)
    b = rng.standard_normal((16, l, l)).astype(np.float32)
    got = np.asarray(linalg.bmm(a, b, l, ta=ta, tb=tb))
    aa = np.swapaxes(a, 1, 2) if ta else a
    bb = np.swapaxes(b, 1, 2) if tb else b
    want = aa @ bb
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("l", [6, 8])  # einsum fallback range
def test_bmm_fallback_matches_matmul(l):
    assert l > linalg.UNROLL_MAX_L
    rng = np.random.default_rng(l)
    a = rng.standard_normal((8, l, l)).astype(np.float32)
    b = rng.standard_normal((8, l, l)).astype(np.float32)
    got = np.asarray(linalg.bmm(a, b, l, ta=True))
    want = np.swapaxes(a, 1, 2) @ b
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bmm_boundary_consistency():
    """Results must not change across the UNROLL_MAX_L boundary — both
    code paths compute the same product."""
    rng = np.random.default_rng(0)
    for l in [linalg.UNROLL_MAX_L, linalg.UNROLL_MAX_L + 1]:
        a = rng.standard_normal((4, l, l)).astype(np.float32)
        b = rng.standard_normal((4, l, l)).astype(np.float32)
        got = np.asarray(linalg.bmm(a, b, l))
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(l=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_bmm_hypothesis(l, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((4, l, l)).astype(np.float32)
    b = rng.standard_normal((4, l, l)).astype(np.float32)
    got = np.asarray(linalg.bmm(a, b, l))
    np.testing.assert_allclose(got, a @ b, rtol=5e-4, atol=5e-5)


def test_bmm_jits_without_gemm_cliff():
    """Smoke: the jitted unrolled bmm at l=4 must run at fused speed —
    bound the per-element time loosely to catch a reintroduced cliff."""
    import time

    l, b = 4, 8192
    rng = np.random.default_rng(1)
    a = rng.standard_normal((b, l, l)).astype(np.float32)
    c = rng.standard_normal((b, l, l)).astype(np.float32)
    f = jax.jit(lambda x, y: linalg.bmm(x, y, l))
    jax.block_until_ready(f(a, c))
    t = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f(a, c))
    per = (time.perf_counter() - t) / 5 / b
    assert per < 2e-6, f"bmm l=4 at {per*1e9:.0f} ns/matrix — GEMM cliff is back?"
