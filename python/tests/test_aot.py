"""AOT pipeline tests: lowering to HLO text, manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_small():
    def fn(x):
        return (x * 2.0 + 1.0,)

    text = aot.to_hlo_text(fn, (jax.ShapeDtypeStruct((4,), jnp.float32),))
    assert "HloModule" in text
    assert "f32[4]" in text


def test_computations_cover_all_levels():
    names = [name for name, *_ in model.computations()]
    assert "level0" in names
    for l in range(1, model.MAX_LEVEL + 1):
        assert f"ci_e_l{l}" in names
        assert f"ci_s_l{l}" in names
    assert len(names) == 1 + 2 * model.MAX_LEVEL


def test_example_shapes_match_meta():
    for name, _fn, ex_args, meta in model.computations():
        if meta["kind"] == "level0":
            assert ex_args[0].shape == (meta["b"],)
        elif meta["kind"] == "ci_e":
            b, l = meta["b"], meta["l"]
            assert ex_args[0].shape == (b,)
            assert ex_args[1].shape == (b, 2, l)
            assert ex_args[2].shape == (b, l, l)
        elif meta["kind"] == "ci_s":
            b, l, k = meta["b"], meta["l"], meta["k"]
            assert ex_args[0].shape == (b, k)
            assert ex_args[1].shape == (b, k, 2, l)
            assert ex_args[2].shape == (b, l, l)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["max_level"] == model.MAX_LEVEL
    assert man["be"] == model.BE and man["bs"] == model.BS and man["k"] == model.K
    for name, meta in man["artifacts"].items():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as f:
            head = f.read(64)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_lowered_hlo_has_expected_params():
    """The ci_e_l2 computation must take 3 f32 params with the documented
    shapes — the Rust literal marshaling depends on this exact order."""
    for name, fn, ex_args, meta in model.computations():
        if name != "ci_e_l2":
            continue
        text = aot.to_hlo_text(fn, ex_args)
        b = meta["b"]
        assert f"f32[{b}]" in text
        assert f"f32[{b},2,2]" in text
        assert f"f32[{b},2,2]" in text
        return
    raise AssertionError("ci_e_l2 not found")
