"""Unit tests for the hand-written batched linear algebra (Algorithm 7)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import linalg


def random_spd(rng, b, l, cond=10.0):
    q, _ = np.linalg.qr(rng.standard_normal((b, l, l)))
    eig = np.exp(rng.uniform(-np.log(cond), 0.0, (b, l)))
    return np.einsum("bik,bk,bjk->bij", q, eig, q).astype(np.float32)


@pytest.mark.parametrize("l", [1, 2, 3, 4, 6, 8])
def test_cholesky_reconstructs(l):
    rng = np.random.default_rng(l)
    a = random_spd(rng, 32, l)
    lo = np.asarray(linalg.batched_cholesky(a, l))
    rec = np.einsum("bik,bjk->bij", lo, lo)
    np.testing.assert_allclose(rec, a, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("l", [1, 2, 3, 4, 6, 8])
def test_cholesky_is_lower_triangular(l):
    rng = np.random.default_rng(100 + l)
    a = random_spd(rng, 8, l)
    lo = np.asarray(linalg.batched_cholesky(a, l))
    upper = np.triu(lo, k=1)
    assert np.abs(upper).max() == 0.0


@pytest.mark.parametrize("l", [2, 3, 4, 6, 8])
def test_tril_inverse(l):
    rng = np.random.default_rng(200 + l)
    a = random_spd(rng, 16, l)
    lo = np.asarray(linalg.batched_cholesky(a, l))
    li = np.asarray(linalg.batched_tril_inverse(lo, l))
    eye = np.einsum("bik,bkj->bij", lo, li)
    np.testing.assert_allclose(eye, np.broadcast_to(np.eye(l), eye.shape), atol=2e-3)


@pytest.mark.parametrize("l", [2, 3, 4, 8])
def test_spd_inverse(l):
    rng = np.random.default_rng(300 + l)
    a = random_spd(rng, 16, l)
    ai = np.asarray(linalg.batched_spd_inverse(a, l))
    eye = np.einsum("bik,bkj->bij", a, ai)
    np.testing.assert_allclose(eye, np.broadcast_to(np.eye(l), eye.shape), atol=5e-3)


@pytest.mark.parametrize("l", [1, 2, 3, 4, 6, 8])
def test_pinv_well_conditioned_matches_inverse(l):
    rng = np.random.default_rng(400 + l)
    a = random_spd(rng, 16, l, cond=5.0)
    pinv = np.asarray(linalg.batched_pinv(a, l))
    ref = np.linalg.inv(a.astype(np.float64))
    np.testing.assert_allclose(pinv, ref, rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("l", [2, 3, 4])
def test_pinv_singular_is_finite_and_penrose(l):
    """On a rank-deficient *correlation* matrix (duplicated variables:
    unit diagonal, rank 1 — the degenerate case PC actually hits) the
    pinv must stay finite and roughly satisfy Penrose A A+ A ~ A."""
    rng = np.random.default_rng(500 + l)
    s = np.sign(rng.standard_normal((8, l, 1))).astype(np.float32)
    a = np.einsum("bik,bjk->bij", s, s)  # +-1 rank-1 with unit diagonal
    pinv = np.asarray(linalg.batched_pinv(a, l))
    assert np.isfinite(pinv).all()
    apa = np.einsum("bij,bjk,bkl->bil", a, pinv, a)
    np.testing.assert_allclose(apa, a, atol=5e-2, rtol=5e-2)


@settings(max_examples=30, deadline=None)
@given(
    l=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pinv_hypothesis_finite(l, seed):
    rng = np.random.default_rng(seed)
    a = random_spd(rng, 4, l, cond=100.0)
    pinv = np.asarray(linalg.batched_pinv(a, l))
    assert np.isfinite(pinv).all()


def test_fisher_z_matches_numpy():
    r = np.linspace(-0.999, 0.999, 101).astype(np.float32)
    z = np.asarray(linalg.fisher_z(r))
    ref = np.abs(np.arctanh(r.astype(np.float64)))
    np.testing.assert_allclose(z, ref, rtol=1e-4, atol=1e-5)


def test_fisher_z_clamps_at_one():
    z = np.asarray(linalg.fisher_z(np.array([1.0, -1.0], dtype=np.float32)))
    assert np.isfinite(z).all()
