"""Statistical validity of the CI-test math, cross-checked against an
entirely different derivation: partial correlation via regression
residuals (scipy), and decision calibration under the null."""

import numpy as np
import scipy.stats
from scipy.stats import norm

from compile.kernels import ci_e, level0, ref


def partial_corr_residual_method(x, i, j, s_idx):
    """rho(Vi,Vj|S) as the correlation of OLS residuals — textbook
    definition, no matrix-inverse shortcut."""
    S = x[:, s_idx]
    S1 = np.column_stack([np.ones(len(x)), S])
    bi, *_ = np.linalg.lstsq(S1, x[:, i], rcond=None)
    bj, *_ = np.linalg.lstsq(S1, x[:, j], rcond=None)
    ri = x[:, i] - S1 @ bi
    rj = x[:, j] - S1 @ bj
    return scipy.stats.pearsonr(ri, rj)[0]


def test_kernel_partial_corr_matches_residual_method():
    rng = np.random.default_rng(0)
    m, nv = 2000, 6  # i=0, j=1, S={2,3,4,5}
    a = rng.standard_normal((nv, nv)) * 0.4
    x = rng.standard_normal((m, nv)) @ (np.eye(nv) + a)
    xs = (x - x.mean(0)) / x.std(0)
    c = xs.T @ xs / m
    l = 4
    c_ij = np.full(128, c[0, 1], dtype=np.float32)
    m1 = np.tile(
        np.stack([c[0, 2:], c[1, 2:]]).astype(np.float32)[None], (128, 1, 1)
    )
    m2 = np.tile(c[2:, 2:].astype(np.float32)[None], (128, 1, 1))
    z_kernel = float(np.asarray(ci_e.ci_e(c_ij, m1, m2, l=l, block_b=128))[0])

    rho_resid = partial_corr_residual_method(xs, 0, 1, [2, 3, 4, 5])
    z_resid = abs(np.arctanh(rho_resid))
    # sample partial-corr from C vs residual method agree to O(1/m)
    assert abs(z_kernel - z_resid) < 0.02, (z_kernel, z_resid)


def test_null_calibration_level0():
    """Under H0 (independent pairs), the level-0 test at significance
    alpha should fire ~alpha of the time."""
    rng = np.random.default_rng(1)
    m = 500
    trials = 2048
    alpha = 0.05
    x = rng.standard_normal((trials, m))
    y = rng.standard_normal((trials, m))
    xc = (x - x.mean(1, keepdims=True)) / x.std(1, keepdims=True)
    yc = (y - y.mean(1, keepdims=True)) / y.std(1, keepdims=True)
    r = np.einsum("tm,tm->t", xc, yc) / m
    z = np.asarray(level0.level0(r.astype(np.float32), block_b=1024))
    tau = norm.ppf(1 - alpha / 2) / np.sqrt(m - 3)
    reject_rate = float((z > tau).mean())
    assert 0.5 * alpha < reject_rate < 2.0 * alpha, reject_rate


def test_power_grows_with_effect_size():
    """z statistic must be monotone in |rho|."""
    rhos = np.array([0.05, 0.1, 0.2, 0.4, 0.8], dtype=np.float32)
    z = ref.level0_ref(rhos)
    assert np.all(np.diff(z) > 0)


def test_fisher_z_variance_stabilization():
    """atanh(r) of a true-rho sample has ~1/(m-3) variance regardless of
    rho — the property eq. (7)'s threshold relies on."""
    rng = np.random.default_rng(2)
    m = 200
    for true_rho in [0.0, 0.5]:
        zs = []
        for _ in range(300):
            x = rng.standard_normal(m)
            y = true_rho * x + np.sqrt(1 - true_rho**2) * rng.standard_normal(m)
            r = np.corrcoef(x, y)[0, 1]
            zs.append(np.arctanh(r))
        v = np.var(zs) * (m - 3)
        assert 0.6 < v < 1.6, (true_rho, v)
