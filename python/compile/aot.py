"""AOT lowering: JAX -> HLO *text* artifacts for the Rust PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits one .hlo.txt per computation plus manifest.json describing the
batch geometry (consumed by rust/src/runtime/artifacts.rs).
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {
        "max_level": model.MAX_LEVEL,
        "b0": model.B0,
        "be": model.BE,
        "bs": model.BS,
        "k": model.K,
        "artifacts": {},
    }
    for name, fn, ex_args, meta in model.computations():
        if only is not None and name not in only:
            continue
        text = to_hlo_text(fn, ex_args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
