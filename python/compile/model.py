"""L2 — jitted JAX computations around the L1 Pallas kernels.

One computation per (kind, level): the shapes baked here define the HLO
artifacts the Rust runtime loads. MAX_LEVEL bounds the conditioning-set
size we AOT-compile for; the paper's datasets top out at level ~5-6 and
the coordinator falls back to its native engine above MAX_LEVEL.

Batch geometry (must match rust/src/runtime/artifacts.rs):
  level0:       B0 = 4096 raw correlations per call
  ci_e, lvl l:  BE = 4096 tests per call
  ci_s, lvl l:  BS = 256 conditioning sets x K = 32 tests each
"""

import jax
import jax.numpy as jnp

from .kernels import ci_e as ci_e_k
from .kernels import ci_s as ci_s_k
from .kernels import level0 as level0_k

MAX_LEVEL = 8
B0 = 4096
BE = 4096
BS = 256
K = 32


def level0_fn(c_ij):
    return (level0_k.level0(c_ij),)


def make_ci_e_fn(l):
    def fn(c_ij, m1, m2):
        return (ci_e_k.ci_e(c_ij, m1, m2, l=l),)

    fn.__name__ = f"ci_e_l{l}"
    return fn


def make_ci_s_fn(l):
    def fn(c_ij, m1, m2):
        return (ci_s_k.ci_s(c_ij, m1, m2, l=l, k=K),)

    fn.__name__ = f"ci_s_l{l}"
    return fn


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def computations():
    """Yield (name, jitted_fn, example_args, meta) for every artifact."""
    yield (
        "level0",
        level0_fn,
        (f32(B0),),
        {"kind": "level0", "b": B0},
    )
    for l in range(1, MAX_LEVEL + 1):
        yield (
            f"ci_e_l{l}",
            make_ci_e_fn(l),
            (f32(BE), f32(BE, 2, l), f32(BE, l, l)),
            {"kind": "ci_e", "l": l, "b": BE},
        )
        yield (
            f"ci_s_l{l}",
            make_ci_s_fn(l),
            (f32(BS, K), f32(BS, K, 2, l), f32(BS, l, l)),
            {"kind": "ci_s", "l": l, "b": BS, "k": K},
        )
