"""L1 Pallas kernel — level-0 CI tests (paper Algorithm 3).

At level 0 the conditioning set is empty, so the test degenerates to
comparing the Fisher z of the *raw* correlation C[i, j] against tau.
The kernel maps a batch of correlation entries to |z| values; the Rust
coordinator owns the tau comparison and the n(n-1)/2 pair enumeration
(the CUDA 2-D grid of Algorithm 3 becomes the batch dimension here).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import linalg

BLOCK_B = 1024


def _level0_kernel(c_ij_ref, z_ref):
    z_ref[...] = linalg.fisher_z(c_ij_ref[...])


def level0(c_ij, *, block_b=BLOCK_B, interpret=True):
    """Fisher-z over a batch of raw correlations. Returns z[B] (f32)."""
    b = c_ij.shape[0]
    assert b % block_b == 0, f"batch {b} must be a multiple of {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _level0_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(c_ij)
