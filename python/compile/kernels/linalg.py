"""Batched small-matrix linear algebra used inside the Pallas CI kernels.

Everything here is written against plain ``jnp`` ops with *static* Python
loops over the (small, ``l <= MAX_LEVEL``) matrix dimension, so it traces
cleanly inside a Pallas kernel body (interpret=True) and lowers to fused
elementwise/matmul HLO. No ``jnp.linalg`` is used on purpose: the paper's
Algorithm 7 (Moore-Penrose pseudo-inverse via full-rank Cholesky) is
implemented by hand, and the Rust NativeEngine mirrors this file
operation-for-operation so the two engines agree bit-for-bit-ish (<=1e-4).

Shapes use the convention ``A[B, l, l]`` — a batch of B independent l-by-l
matrices. ``l`` must be a static Python int.
"""

import jax.numpy as jnp

# Tikhonov jitter added to the diagonal of M2^T M2 before Cholesky.
# M2 is a correlation submatrix and may be singular (perfectly correlated
# variables); the paper handles this with a pseudo-inverse. The jitter is
# the standard full-rank-ification and is mirrored in rust/src/stats/chol.rs.
CHOL_EPS = 1e-8

# bmm unrolling threshold: unrolled fused multiplies below, einsum above
# (see bmm docstring; levels above 5 are rare in PC runs).
UNROLL_MAX_L = 5


def batched_cholesky(a, l, rank_tol=None):
    """Lower Cholesky factor of a batch of SPD / PSD matrices.

    a: [B, l, l] symmetric positive (semi-)definite.
    Returns L with a = L @ L.T, L lower-triangular. Static unrolled loops.

    rank_tol: None -> jittered pivots (strict SPD assumption).
              [B] array -> *full-rank Cholesky* (Courrieu): any column whose
              pivot falls below the tolerance is zeroed out, the static-shape
              analogue of dropping it. Zero columns later self-cancel in the
              pseudo-inverse composition L R R L^T.
    """
    # Build L column by column (standard Cholesky-Banachiewicz), batched.
    cols = [[None] * l for _ in range(l)]  # cols[i][k] -> [B] entries L[i,k]
    for k in range(l):
        # diagonal: L[k,k] = sqrt(a[k,k] - sum_m L[k,m]^2)
        s = a[:, k, k]
        for m in range(k):
            s = s - cols[k][m] * cols[k][m]
        if rank_tol is None:
            dkk = jnp.sqrt(jnp.maximum(s, CHOL_EPS))
            cols[k][k] = dkk
            inv_dkk = 1.0 / dkk
        else:
            ok = s > rank_tol
            dkk = jnp.sqrt(jnp.maximum(s, CHOL_EPS))
            cols[k][k] = jnp.where(ok, dkk, 0.0)
            inv_dkk = jnp.where(ok, 1.0 / dkk, 0.0)
        for i in range(k + 1, l):
            s = a[:, i, k]
            for m in range(k):
                s = s - cols[i][m] * cols[k][m]
            cols[i][k] = s * inv_dkk
    # Assemble [B, l, l]
    zero = jnp.zeros_like(a[:, 0, 0])
    rows = []
    for i in range(l):
        row = [cols[i][k] if k <= i else zero for k in range(l)]
        rows.append(jnp.stack(row, axis=-1))
    return jnp.stack(rows, axis=-2)


def batched_tril_inverse(lmat, l):
    """Inverse of a batch of lower-triangular matrices by forward substitution.

    lmat: [B, l, l] lower triangular, returns X with lmat @ X = I.
    """
    # Solve column by column: X[:, :, j] solves L x = e_j.
    zero = jnp.zeros_like(lmat[:, 0, 0])
    xcols = []  # xcols[j][i] -> [B]
    for j in range(l):
        col = [zero] * l
        for i in range(j, l):
            s = jnp.where(jnp.array(i == j), jnp.ones_like(zero), zero)
            # s = e_j[i] - sum_{k<i} L[i,k] * x[k]
            for k in range(j, i):
                s = s - lmat[:, i, k] * col[k]
            col[i] = s / lmat[:, i, i]
        xcols.append(col)
    rows = []
    for i in range(l):
        rows.append(jnp.stack([xcols[j][i] for j in range(l)], axis=-1))
    return jnp.stack(rows, axis=-2)


def bmm(a, b, l, ta=False, tb=False):
    """Batched l-by-l matmul with optional transposes, fully unrolled.

    XLA CPU lowers batched `einsum`/`dot_general` with l >= 4 to library
    batched-GEMM calls — catastrophic for thousands of tiny matrices
    (measured ~100x cliff between l=3 and l=4). Static unrolling keeps
    every product an elementwise [B] op that fuses with its neighbours;
    on TPU the same graph vectorizes across the batch on the VPU.

    Beyond UNROLL_MAX_L the O(l^3) unrolled graph blows up compile time
    for little runtime gain (the GEMM overhead amortizes as matrices
    grow), so large l falls back to einsum.
    """
    if l > UNROLL_MAX_L:
        spec_a = "bki" if ta else "bik"
        spec_b = "bjk" if tb else "bkj"
        return jnp.einsum(f"{spec_a},{spec_b}->bij", a, b)
    rows = []
    for i in range(l):
        cols = []
        for j in range(l):
            s = None
            for k in range(l):
                av = a[:, k, i] if ta else a[:, i, k]
                bv = b[:, j, k] if tb else b[:, k, j]
                term = av * bv
                s = term if s is None else s + term
            cols.append(s)
        rows.append(jnp.stack(cols, axis=-1))
    return jnp.stack(rows, axis=-2)


def batched_spd_inverse(a, l):
    """Inverse of a batch of SPD matrices via Cholesky: A^-1 = L^-T L^-1."""
    lmat = batched_cholesky(a, l)
    linv = batched_tril_inverse(lmat, l)
    return bmm(linv, linv, l, ta=True)


def batched_pinv(m2, l):
    """Moore-Penrose pseudo-inverse, paper Algorithm 7 (Courrieu).

    m2: [B, l, l]. L = chol(M2^T M2); R = (L^T L)^-1;
    M2^+ = L R R L^T M2^T.
    """
    if l == 1:
        # 1x1 fast path: pinv(x) = x / (x^2 + eps)
        x = m2[:, 0, 0]
        return (x / (x * x + CHOL_EPS))[:, None, None]
    mtm = bmm(m2, m2, l, ta=True)
    eye = jnp.eye(l, dtype=m2.dtype)
    # Rank-revealing tolerance relative to the largest diagonal entry
    # (Courrieu's full-rank Cholesky drops columns below it; we zero them).
    diag = jnp.stack([mtm[:, d, d] for d in range(l)], axis=-1)
    rank_tol = jnp.max(diag, axis=-1) * 1e-6 + CHOL_EPS
    lmat = batched_cholesky(mtm, l, rank_tol=rank_tol)
    ltl = bmm(lmat, lmat, l, ta=True)  # L^T L
    r = batched_spd_inverse(ltl + CHOL_EPS * eye, l)
    lr = bmm(lmat, r, l)
    lrr = bmm(lr, r, l)
    lrrlt = bmm(lrr, lmat, l, tb=True)  # (L R R) L^T
    return bmm(lrrlt, m2, l, tb=True)  # ... M2^T


def fisher_z(rho):
    """|0.5 * ln((1+r)/(1-r))|, clamped away from +-1 (paper eq. 6)."""
    r = jnp.clip(rho, -0.9999999, 0.9999999)
    return jnp.abs(0.5 * jnp.log((1.0 + r) / (1.0 - r)))


def partial_corr_from_packed(c_ij, m1, m2inv, l):
    """rho(Vi,Vj|S) from pre-gathered blocks (paper eq. 4-5).

    c_ij:  [B]        C[i,j]
    m1:    [B, 2, l]  rows (C[i,S]; C[j,S])
    m2inv: [B, l, l]  pinv(C[S,S])
    Returns rho [B].
    H = M0 - M1 M2^-1 M1^T with M0 = [[1, c_ij],[c_ij, 1]] (C diag == 1).
    Unrolled like `bmm` (2×l×l then 2×2 contractions).
    """
    # w[s, c] = sum_k m1[s, k] m2inv[k, c]   (s in {0, 1})
    w = [[None] * l for _ in range(2)]
    for s in range(2):
        for c in range(l):
            acc = None
            for k in range(l):
                term = m1[:, s, k] * m2inv[:, k, c]
                acc = term if acc is None else acc + term
            w[s][c] = acc
    # h[s, t] = sum_k w[s, k] m1[t, k]
    def hdot(s, t):
        acc = None
        for k in range(l):
            term = w[s][k] * m1[:, t, k]
            acc = term if acc is None else acc + term
        return acc

    h00 = 1.0 - hdot(0, 0)
    h11 = 1.0 - hdot(1, 1)
    h01 = c_ij - hdot(0, 1)
    denom = jnp.sqrt(jnp.maximum(h00 * h11, 1e-12))
    return h01 / denom
