"""L1 Pallas kernel — cuPC-E style batched CI tests (paper Algorithm 4).

One conditional-independence test I(Vi, Vj | S), |S| = l, per batch row.
The coordinator (Rust L3) has already gathered the correlation blocks —
the analogue of cuPC's shared-memory staging of an A'_G row — so the
kernel's job is the pure numeric hot spot: the Moore-Penrose pseudo-
inverse of M2 (Algorithm 7), H = M0 - M1 M2^+ M1^T, the partial
correlation (eq. 5) and the Fisher z statistic (eq. 6).

Inputs (per batch of size B, conditioning-set size l static):
  c_ij [B]       C[i, j]
  m1   [B, 2, l] (C[i, S]; C[j, S])
  m2   [B, l, l] C[S, S]
Output:
  z    [B]       |Fisher z| of the estimated partial correlation.

The batch is tiled over a 1-D grid with BLOCK_B rows per program —
on TPU each block's operands live in VMEM and the einsums in
``linalg.batched_pinv`` feed the MXU; interpret=True lowers the same
body to plain HLO for the CPU PJRT client (see DESIGN.md
§Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import linalg

BLOCK_B = 256


def _ci_e_kernel(c_ij_ref, m1_ref, m2_ref, z_ref, *, l):
    c_ij = c_ij_ref[...]
    m1 = m1_ref[...]
    m2 = m2_ref[...]
    m2inv = linalg.batched_pinv(m2, l)
    rho = linalg.partial_corr_from_packed(c_ij, m1, m2inv, l)
    z_ref[...] = linalg.fisher_z(rho)


def ci_e(c_ij, m1, m2, *, l, block_b=BLOCK_B, interpret=True):
    """Batched CI tests, one (i,j,S) per row. Returns z[B] (f32)."""
    b = c_ij.shape[0]
    assert b % block_b == 0, f"batch {b} must be a multiple of {block_b}"
    assert m1.shape == (b, 2, l) and m2.shape == (b, l, l)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_ci_e_kernel, l=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, 2, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, l, l), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(c_ij, m1, m2)
