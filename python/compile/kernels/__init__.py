"""L1 — Pallas kernels for the cuPC CI-test hot spot.

ci_e:    cuPC-E style, one (i, j, S) test per batch row  (Algorithm 4)
ci_s:    cuPC-S style, one S per row, pinv shared over K tests (Algorithm 5)
level0:  Fisher-z over raw correlations                  (Algorithm 3)
linalg:  hand-written batched Cholesky / Moore-Penrose   (Algorithm 7)
ref:     independent numpy oracle (SVD pinv) for all of the above
"""

from . import ci_e, ci_s, level0, linalg, ref  # noqa: F401
