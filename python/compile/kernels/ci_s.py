"""L1 Pallas kernel — cuPC-S style shared-pinv CI tests (paper Algorithm 5).

The cuPC-S insight: M2 = C[S, S] depends only on the conditioning set S,
not on the tested pair. Assigning one conditional set per batch row and
computing pinv(M2) ONCE, then applying it to K candidate partners j of
the anchor variable i, removes the dominant redundant work (pseudo-
inverse) from all K tests. This kernel is that idea verbatim: row r
carries one S (via m2[r]) and K packed (c_ij, M1) pairs.

Inputs (B rows, K tests per row, set size l static):
  c_ij [B, K]       C[i, j_k]
  m1   [B, K, 2, l] (C[i, S]; C[j_k, S]) per candidate
  m2   [B, l, l]    C[S, S]  (shared across the K tests of the row)
Output:
  z    [B, K]       |Fisher z| per test. Padded slots (mask handled by
                    the Rust packer) simply produce garbage z that the
                    coordinator ignores.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import linalg

BLOCK_B = 64


def _ci_s_kernel(c_ij_ref, m1_ref, m2_ref, z_ref, *, l, k):
    c_ij = c_ij_ref[...]  # [b, K]
    m1 = m1_ref[...]  # [b, K, 2, l]
    m2 = m2_ref[...]  # [b, l, l]
    b = c_ij.shape[0]
    # ONE pseudo-inverse per row (the cuPC-S saving) ...
    m2inv = linalg.batched_pinv(m2, l)  # [b, l, l]
    # ... shared by the K tests: flatten (b, K) -> (b*K) with a broadcast
    # of m2inv, then reuse the packed partial-correlation routine.
    m2inv_rep = jnp.repeat(m2inv, k, axis=0)  # [b*K, l, l]
    c_flat = c_ij.reshape(b * k)
    m1_flat = m1.reshape(b * k, 2, l)
    rho = linalg.partial_corr_from_packed(c_flat, m1_flat, m2inv_rep, l)
    z_ref[...] = linalg.fisher_z(rho).reshape(b, k)


def ci_s(c_ij, m1, m2, *, l, k, block_b=BLOCK_B, interpret=True):
    """Shared-set batched CI tests. Returns z[B, K] (f32)."""
    b = m2.shape[0]
    assert b % block_b == 0, f"batch {b} must be a multiple of {block_b}"
    assert c_ij.shape == (b, k)
    assert m1.shape == (b, k, 2, l) and m2.shape == (b, l, l)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_ci_s_kernel, l=l, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k, 2, l), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_b, l, l), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(c_ij, m1, m2)
