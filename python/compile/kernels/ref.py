"""Pure-numpy oracle for the CI-test kernels.

Uses np.linalg.pinv (SVD-based) and straightforward loops — no Pallas,
no hand-written Cholesky — so it is an *independent* implementation of
eq. (3)-(6) of the paper. pytest asserts the kernels against this, and
the Rust NativeEngine is cross-checked against the XLA artifacts which
are themselves checked against this oracle, closing the loop.
"""

import numpy as np


def fisher_z_ref(rho):
    r = np.clip(np.asarray(rho, dtype=np.float64), -0.9999999, 0.9999999)
    return np.abs(0.5 * np.log((1.0 + r) / (1.0 - r)))


def partial_corr_ref(c_ij, m1, m2):
    """rho(Vi,Vj|S) per batch row, float64 numpy. m1 [B,2,l], m2 [B,l,l]."""
    c_ij = np.asarray(c_ij, dtype=np.float64)
    m1 = np.asarray(m1, dtype=np.float64)
    m2 = np.asarray(m2, dtype=np.float64)
    b = c_ij.shape[0]
    rho = np.empty(b)
    for r in range(b):
        m2inv = np.linalg.pinv(m2[r], rcond=1e-8)
        h = m1[r] @ m2inv @ m1[r].T  # 2x2
        h00 = 1.0 - h[0, 0]
        h11 = 1.0 - h[1, 1]
        h01 = c_ij[r] - h[0, 1]
        rho[r] = h01 / np.sqrt(max(h00 * h11, 1e-12))
    return rho


def ci_e_ref(c_ij, m1, m2):
    """Oracle for kernels.ci_e: |fisher z| per row."""
    return fisher_z_ref(partial_corr_ref(c_ij, m1, m2))


def ci_s_ref(c_ij, m1, m2):
    """Oracle for kernels.ci_s: |fisher z| [B, K]."""
    c_ij = np.asarray(c_ij, dtype=np.float64)
    b, k = c_ij.shape
    out = np.empty((b, k))
    for r in range(b):
        m2_rep = np.broadcast_to(np.asarray(m2[r]), (k, m2[r].shape[0], m2[r].shape[1]))
        out[r] = ci_e_ref(c_ij[r], m1[r], m2_rep)
    return out


def level0_ref(c_ij):
    return fisher_z_ref(c_ij)


def random_ci_batch(rng, b, l, k=None, near_singular=False):
    """Generate a consistent random batch by sampling *real* correlation
    matrices: draw data for (2+l) or (1+k+l) variables, compute the sample
    correlation, slice the blocks. Keeps M2 a valid (possibly near-singular
    when m is tiny) correlation submatrix, exactly as in a live PC run."""
    nv = (2 + l) if k is None else (1 + k + l)
    m = 8 if near_singular else 200  # few samples => near-singular C
    a = rng.standard_normal((nv, nv)) / np.sqrt(nv)
    x = rng.standard_normal((b, m, nv)) @ (np.eye(nv) + 0.5 * a)
    xs = x - x.mean(axis=1, keepdims=True)
    xs = xs / (xs.std(axis=1, keepdims=True) + 1e-12)
    c = np.einsum("bmi,bmj->bij", xs, xs) / m  # [b, nv, nv]
    if k is None:
        # variable layout: 0 = i, 1 = j, 2.. = S
        c_ij = c[:, 0, 1]
        m1 = np.stack([c[:, 0, 2:], c[:, 1, 2:]], axis=1)  # [b,2,l]
        m2 = c[:, 2:, 2:]
    else:
        # variable layout: 0 = i, 1..k = j's, k+1.. = S
        c_ij = c[:, 0, 1 : 1 + k]  # [b,k]
        ci_s_ = c[:, 0, 1 + k :]  # [b,l] = C[i,S]
        cj_s = c[:, 1 : 1 + k, 1 + k :]  # [b,k,l]
        m1 = np.stack(
            [np.broadcast_to(ci_s_[:, None, :], cj_s.shape), cj_s], axis=2
        )  # [b,k,2,l]
        m2 = c[:, 1 + k :, 1 + k :]
    return (
        np.ascontiguousarray(c_ij, dtype=np.float32),
        np.ascontiguousarray(m1, dtype=np.float32),
        np.ascontiguousarray(m2, dtype=np.float32),
    )
