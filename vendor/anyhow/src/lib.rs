//! Minimal, dependency-free shim of the `anyhow` error-handling API.
//!
//! The build environment for this workspace has no network access, so the
//! real `anyhow` crate cannot be fetched from crates.io. This shim covers
//! exactly the surface the `cupc` crate uses:
//!
//! * [`Result<T>`] — alias with the error type defaulted to [`Error`]
//! * [`Error`] — an error carrying a chain of context frames
//! * [`anyhow!`] — construct an [`Error`] from format arguments
//! * [`bail!`] — early-return an error from format arguments
//! * [`ensure!`] — bail unless a condition holds
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the underlying error with an outer message
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain separated by `": "`, and `{:?}`
//! prints the outermost message followed by a `Caused by:` list.

// Vendored shim: exempt from the workspace lint policy.
#![allow(clippy::all)]

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value holding a chain of messages, outermost context first.
pub struct Error {
    /// frames[0] is the outermost context; the last frame is the root cause
    frames: Vec<String>,
}

impl Error {
    /// Create an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            frames: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message (anyhow's
    /// `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// Iterate the chain from the outermost message to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first, ": "-separated.
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`], capturing its source chain.
/// (Coherent because [`Error`] itself does not implement
/// `std::error::Error`, mirroring the real anyhow.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// Attach context to fallible values, converting the error to [`Error`].
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root cause {}", 42);
    }

    #[test]
    fn display_outermost_only() {
        let e = anyhow!("boom");
        assert_eq!(format!("{e}"), "boom");
    }

    #[test]
    fn alternate_prints_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root cause 42");
        assert_eq!(format!("{e}"), "outer");
    }

    #[test]
    fn with_context_lazy() {
        let e: Result<()> = fails().with_context(|| format!("step {}", 7));
        let e = e.unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: root cause 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let some: Option<u32> = Some(5);
        assert_eq!(some.context("unused").unwrap(), 5);
    }

    #[test]
    fn std_error_converts_via_question_mark() {
        fn parse() -> Result<i32> {
            let v: i32 = "not-a-number".parse()?;
            Ok(v)
        }
        let e = parse().unwrap_err();
        assert!(format!("{e}").contains("invalid digit"), "{e}");
    }

    #[test]
    fn debug_lists_causes() {
        let e = fails().context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("root cause 42"), "{dbg}");
    }

    #[test]
    fn ensure_fires_only_on_false() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }
}
