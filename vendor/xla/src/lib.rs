//! Stub of the XLA PJRT binding surface `cupc::runtime` consumes.
//!
//! The real bindings (PJRT CPU client + HLO-text compilation) require a
//! native XLA installation that is not present in the offline build
//! image. This stub keeps the `--features xla` build compiling so the
//! runtime code stays type-checked, while every entry point that would
//! touch PJRT returns a descriptive [`Error`] instead of executing.
//! Swap this path dependency for real bindings to run the AOT artifacts.

// Vendored shim: exempt from the workspace lint policy.
#![allow(clippy::all)]

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "XLA PJRT runtime unavailable: this build links the vendored `xla` \
     API stub (no native XLA in the image); use the native engine, or replace vendor/xla with \
     real PJRT bindings";

/// Error type returned by every stubbed PJRT entry point.
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client. Always errors in the stub.
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::unavailable())
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// A compiled executable resident on a PJRT device.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals, returning per-device,
    /// per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// A device buffer produced by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// A host tensor literal.
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 f32 literal from a host slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(self)
    }

    /// Unwrap a 1-tuple literal into its sole element.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    /// Copy the literal's elements into a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always errors in the stub (nothing could
    /// execute it anyway).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        let msg = format!("{err:?}");
        assert!(msg.contains("PJRT"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_roundtrip_shapes_only() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
