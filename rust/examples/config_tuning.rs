//! Configuration tuning — the paper's §5.4 knobs through the public API.
//!
//! Sweeps cuPC-E's (β, γ) and cuPC-S's (θ, δ) on a sparse and a dense
//! problem, showing the trade-off the paper's heat maps (Fig. 7/8) map
//! out: larger per-edge flights help dense graphs and hurt sparse ones.
//!
//!     cargo run --release --example config_tuning

use cupc::prelude::*;
use cupc::sim::datasets;
use cupc::skeleton::run as run_skeleton;
use cupc::stats::corr::correlation_matrix;
use cupc::util::timer::median_time;

fn main() -> anyhow::Result<()> {
    for (label, n, d) in [("sparse", 120usize, 0.03f64), ("dense", 80, 0.25)] {
        let ds = datasets::generate_er(n, 800, d, 99);
        let corr = correlation_matrix(&ds.data, 1);
        println!("== {label} problem: n={n}, density {d} ==");

        println!("cuPC-E (β, γ) sweep:");
        let mut best: Option<(f64, usize, usize)> = None;
        for (beta, gamma) in [(1, 32), (2, 32), (2, 128), (8, 8), (32, 1)] {
            let cfg = Config {
                variant: Variant::CupcE,
                beta,
                gamma,
                ..Config::default()
            };
            let mut tests = 0;
            let t = median_time(0, 3, || {
                let r = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg).unwrap();
                tests = r.total_tests();
            });
            println!("  β={beta:<3} γ={gamma:<3}: {:>8.1} ms  ({tests} CI tests)", t * 1e3);
            if best.map(|(bt, _, _)| t < bt).unwrap_or(true) {
                best = Some((t, beta, gamma));
            }
        }
        let (_, bb, bg) = best.unwrap();
        println!("  -> best for {label}: β={bb}, γ={bg}");

        println!("cuPC-S (θ, δ) sweep:");
        for (theta, delta) in [(32, 1), (64, 2), (256, 8)] {
            let cfg = Config {
                variant: Variant::CupcS,
                theta,
                delta,
                ..Config::default()
            };
            let t = median_time(0, 3, || {
                run_skeleton(&corr, ds.data.n, ds.data.m, &cfg).unwrap();
            });
            println!("  θ={theta:<3} δ={delta:<2}: {:>8.1} ms", t * 1e3);
        }
        println!();
    }
    println!("(paper: cuPC-E varies 0.3–1.3x with config; cuPC-S only 0.7–1.2x)");
    Ok(())
}
