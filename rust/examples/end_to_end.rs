//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Exercises every layer in one run: a GRN-shaped dataset is generated
//! (substrate), its correlation matrix computed (L3 preprocessing), the
//! PC-stable level loop runs with CI-test batches dispatched to the
//! **AOT-compiled Pallas kernels through the XLA PJRT runtime** (L1/L2
//! artifacts — Python is not involved at runtime), results are
//! cross-checked against the pure-Rust native engine, the skeleton is
//! oriented into a CPDAG, and recovery metrics + per-level timings are
//! reported. This is the headline-workload driver recorded in
//! EXPERIMENTS.md.
//!
//!     cargo run --release --example end_to_end [dataset] (default saureus-mini)

use cupc::metrics::{level_time_shares, skeleton_metrics};
use cupc::prelude::*;
use cupc::sim::datasets;
use cupc::stats::corr::correlation_matrix;
use cupc::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "saureus-mini".to_string());
    let spec = datasets::spec(&name)
        .unwrap_or_else(|| panic!("unknown dataset {name}"));
    println!("== end-to-end: {} (n={}, m={}) ==", spec.name, spec.n, spec.m);

    // substrate: synthetic GRN + linear SEM observational data
    let t = Timer::start();
    let ds = datasets::generate(spec);
    println!("[gen ] {:.3}s  ({} true edges)", t.elapsed_s(), ds.dag.n_edges());

    // L3 preprocessing: correlation matrix
    let t = Timer::start();
    let corr = correlation_matrix(&ds.data, 1);
    println!("[corr] {:.3}s  ({}x{} matrix)", t.elapsed_s(), ds.data.n, ds.data.n);

    // production path: cuPC-S schedule over the XLA PJRT artifacts
    let cfg_xla = Config {
        variant: Variant::CupcS,
        engine: EngineKind::Xla,
        ..Config::default()
    };
    let res = cupc::api::pc_stable_corr(&corr, ds.data.n, ds.data.m, &cfg_xla)?;
    println!(
        "[xla ] skeleton {:.3}s + orient {:.3}s, {} CI tests, {} edges",
        res.skeleton.total_seconds(),
        res.orient_seconds,
        res.skeleton.total_tests(),
        res.skeleton.graph.n_edges()
    );
    for (ls, (lvl, share)) in res.skeleton.levels.iter().zip(level_time_shares(&res.skeleton.levels)) {
        println!(
            "       level {lvl}: {:>9} tests, removed {:>5}, {:>7.1} ms ({share:.1}%)",
            ls.tests, ls.removed, ls.seconds * 1e3
        );
    }

    // cross-check: native engine must produce the identical skeleton
    let cfg_nat = Config {
        engine: EngineKind::Native,
        ..cfg_xla.clone()
    };
    let res_nat = cupc::api::pc_stable_corr(&corr, ds.data.n, ds.data.m, &cfg_nat)?;
    assert_eq!(
        res.skeleton.graph.snapshot(),
        res_nat.skeleton.graph.snapshot(),
        "XLA and native engines must agree on the skeleton"
    );
    println!("[chk ] native engine skeleton identical ✓");

    // headline metric: structure recovery vs ground truth
    let m = skeleton_metrics(&res.skeleton.graph.snapshot(), &ds.dag.skeleton_dense(), ds.data.n);
    println!(
        "[eval] precision {:.3}  recall {:.3}  F1 {:.3}  (TP {} / FP {} / FN {})",
        m.precision, m.recall, m.f1, m.tp, m.fp, m.fn_
    );
    println!(
        "[eval] CPDAG: {} directed, {} undirected",
        res.cpdag.directed_edges().len(),
        res.cpdag.undirected_edges().len()
    );
    Ok(())
}
