//! Gene-regulatory-network discovery — the paper's motivating workload.
//!
//! Generates a GRN-like dataset with the shape of DREAM5-Insilico
//! (scaled), runs all four schedules (serial, parallel CPU, cuPC-E,
//! cuPC-S), verifies they agree on the skeleton, and reports runtimes
//! and recovery quality — a miniature Table 2 row.
//!
//!     cargo run --release --example grn_discovery [--engine xla]

use cupc::metrics::skeleton_metrics;
use cupc::prelude::*;
use cupc::sim::datasets;
use cupc::skeleton::run as run_skeleton;
use cupc::stats::corr::correlation_matrix;

fn main() -> anyhow::Result<()> {
    let engine = if std::env::args().any(|a| a == "xla" || a == "--engine=xla") {
        EngineKind::Xla
    } else {
        EngineKind::Native
    };

    let spec = datasets::spec("dream5-insilico-mini").unwrap();
    println!(
        "dataset {} (analog of DREAM5-Insilico): n={} genes, m={} expression samples",
        spec.name, spec.n, spec.m
    );
    let ds = datasets::generate(spec);
    let corr = correlation_matrix(&ds.data, 1);

    let mut skeletons = Vec::new();
    for (variant, label) in [
        (Variant::Serial, "serial (Stable.fast)"),
        (Variant::ParallelCpu, "parallel CPU (Parallel-PC)"),
        (Variant::CupcE, "cuPC-E"),
        (Variant::CupcS, "cuPC-S"),
    ] {
        let cfg = Config {
            variant,
            engine,
            ..Config::default()
        };
        let res = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg)?;
        println!(
            "{label:<28} {:.3}s  {:>8} CI tests  {:>5} edges  {} levels",
            res.total_seconds(),
            res.total_tests(),
            res.graph.n_edges(),
            res.levels.len()
        );
        skeletons.push(res);
    }

    // PC-stable order-independence: all schedules, same skeleton.
    let first = skeletons[0].graph.snapshot();
    for s in &skeletons[1..] {
        assert_eq!(first, s.graph.snapshot(), "schedules must agree");
    }

    let m = skeleton_metrics(&first, &ds.dag.skeleton_dense(), ds.data.n);
    println!(
        "\nGRN skeleton recovery: TP={} FP={} FN={} (precision {:.2}, recall {:.2})",
        m.tp, m.fp, m.fn_, m.precision, m.recall
    );

    // Orient the best run and show a few regulatory arrows.
    let res = &skeletons[3];
    let cpdag = cupc::orient::orient(&res.graph, &res.sepsets);
    let arrows = cpdag.directed_edges();
    println!("oriented {} regulatory directions, e.g.:", arrows.len());
    for (a, b) in arrows.iter().take(5) {
        println!("  gene{a} -> gene{b}");
    }
    Ok(())
}
