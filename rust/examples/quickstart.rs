//! Quickstart: learn a causal structure from synthetic observational
//! data in ~20 lines.
//!
//!     cargo run --release --example quickstart

use cupc::prelude::*;
use cupc::sim::{dag::WeightedDag, sem};
use cupc::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // 1. Make a ground-truth DAG and sample observational data from it.
    //    (With real data: `cupc::data::csv::load_csv` instead.)
    let truth = WeightedDag::random_er(50, 0.08, &mut Pcg::seeded(7));
    let data = sem::sample(&truth, 2000, &mut Pcg::seeded(8));
    println!("ground truth: {} variables, {} edges", truth.n, truth.n_edges());

    // 2. Run PC-stable with the cuPC-S schedule (default config).
    let cfg = Config::default();
    let result = cupc::api::pc_stable_data(&data, &cfg)?;

    // 3. Inspect the learned CPDAG.
    println!(
        "learned: {} edges ({} directed, {} undirected) in {:.3}s / {} CI tests",
        result.cpdag.n_edges(),
        result.cpdag.directed_edges().len(),
        result.cpdag.undirected_edges().len(),
        result.total_seconds(),
        result.skeleton.total_tests(),
    );

    // 4. Score against the ground truth.
    let m = cupc::metrics::skeleton_metrics(
        &result.skeleton.graph.snapshot(),
        &truth.skeleton_dense(),
        data.n,
    );
    println!(
        "skeleton recovery: precision {:.2}, recall {:.2}, F1 {:.2}",
        m.precision, m.recall, m.f1
    );
    assert!(m.f1 > 0.8, "quickstart should recover most of the graph");
    Ok(())
}
