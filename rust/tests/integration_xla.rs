//! Integration tests over the XLA PJRT runtime: these require the AOT
//! artifacts (`make artifacts`) and exercise the production path —
//! skipped gracefully when artifacts are absent so `cargo test` works in
//! a fresh checkout. The whole file is compile-gated behind the `xla`
//! cargo feature: without it there is no PJRT runtime to test (see
//! `tests/xla_gate.rs` for the feature-off behaviour).

#![cfg(feature = "xla")]

use cupc::prelude::*;
use cupc::runtime::XlaEngine;
use cupc::sim::datasets;
use cupc::skeleton::engine::{CiEngine, NativeEngine};
use cupc::skeleton::{run as run_skeleton, Variant};
use cupc::stats::corr::correlation_matrix;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("SKIP: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_engine_loads_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = XlaEngine::new(&dir).unwrap();
    assert_eq!(e.max_level(), 8);
    assert_eq!(e.batch_e(), 4096);
    // every level compiles and runs
    for l in 1..=e.max_level() {
        let b = 4;
        let c_ij = vec![0.3f32; b];
        let m1 = vec![0.1f32; b * 2 * l];
        let mut m2 = vec![0.0f32; b * l * l];
        for s in 0..b {
            for d in 0..l {
                m2[s * l * l + d * l + d] = 1.0;
            }
        }
        let z = e.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        assert_eq!(z.len(), b);
        assert!(z.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn xla_and_native_engines_agree_on_random_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::new(&dir).unwrap();
    let mut nat = NativeEngine::new();
    let mut rng = cupc::util::rng::Pcg::seeded(123);
    // reuse the binary's batch generators via a local re-implementation:
    // valid correlation slices
    for l in [1usize, 3, 5, 8] {
        let b = 300;
        let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
        let zx = xla.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        let zn = nat.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        let d = zx
            .iter()
            .zip(&zn)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 2e-3, "l={l}: max |Δz| = {d}");
    }
}

#[test]
fn full_run_xla_equals_native_skeleton() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = datasets::generate(datasets::spec("mcc-mini").unwrap());
    let corr = correlation_matrix(&ds.data, 1);
    for variant in [Variant::CupcE, Variant::CupcS] {
        let cfg_x = Config {
            variant,
            engine: EngineKind::Xla,
            artifacts_dir: dir.clone(),
            ..Config::default()
        };
        let cfg_n = Config {
            engine: EngineKind::Native,
            ..cfg_x.clone()
        };
        let rx = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg_x).unwrap();
        let rn = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg_n).unwrap();
        assert_eq!(
            rx.graph.snapshot(),
            rn.graph.snapshot(),
            "{variant:?}: XLA vs native skeleton"
        );
        assert_eq!(rx.total_tests(), rn.total_tests(), "{variant:?}: schedules diverged");
    }
}

#[test]
fn xla_missing_artifact_dir_errors_cleanly() {
    let err = match XlaEngine::new(Path::new("/nonexistent/dir")) {
        Ok(_) => panic!("expected an error for missing artifacts"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unhelpful error: {msg}");
}

/// valid correlation blocks: sample 2+l correlated variables.
fn random_batch(
    rng: &mut cupc::util::rng::Pcg,
    b: usize,
    l: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nv = 2 + l;
    let m = 48;
    let mut c_ij = Vec::new();
    let mut m1 = Vec::new();
    let mut m2 = Vec::new();
    for _ in 0..b {
        // sample, standardize, correlate
        let mut x = vec![0.0f64; m * nv];
        for row in 0..m {
            let shared = rng.normal() * 0.6;
            for v in 0..nv {
                x[row * nv + v] = rng.normal() + shared;
            }
        }
        let mut c = vec![0.0f64; nv * nv];
        for a in 0..nv {
            let mean: f64 = (0..m).map(|r| x[r * nv + a]).sum::<f64>() / m as f64;
            let sd: f64 = ((0..m)
                .map(|r| (x[r * nv + a] - mean).powi(2))
                .sum::<f64>()
                / m as f64)
                .sqrt();
            for r in 0..m {
                x[r * nv + a] = (x[r * nv + a] - mean) / sd.max(1e-9);
            }
        }
        for a in 0..nv {
            for bb in 0..nv {
                c[a * nv + bb] =
                    (0..m).map(|r| x[r * nv + a] * x[r * nv + bb]).sum::<f64>() / m as f64;
            }
        }
        c_ij.push(c[1] as f32);
        for s in 0..l {
            m1.push(c[2 + s] as f32);
        }
        for s in 0..l {
            m1.push(c[nv + 2 + s] as f32);
        }
        for a in 0..l {
            for bb in 0..l {
                m2.push(c[(2 + a) * nv + 2 + bb] as f32);
            }
        }
    }
    (c_ij, m1, m2)
}

/// Throughput probe for the AOT kernels (ignored by default):
///   cargo test --release --test integration_xla xla_throughput -- --ignored --nocapture
#[test]
#[ignore]
fn xla_throughput() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = XlaEngine::new(&dir).unwrap();
    let mut rng = cupc::util::rng::Pcg::seeded(7);
    for l in [1usize, 2, 4, 8] {
        let b = 4096 * 8;
        let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
        // warm
        let _ = e.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        let t = std::time::Instant::now();
        let reps = 5;
        for _ in 0..reps {
            let _ = e.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        }
        let dt = t.elapsed().as_secs_f64() / reps as f64;
        // rough flop count per test for Algorithm 7 + partial corr
        let flops = (10 * l * l * l + 8 * l * l + 8 * l + 20) as f64;
        println!(
            "xla ci_e l={l}: {:.0} ns/test, {:.2} Mtest/s, ~{:.2} GFLOP/s, {:.1} us/dispatch overhead incl.",
            dt / b as f64 * 1e9,
            b as f64 / dt / 1e6,
            flops * b as f64 / dt / 1e9,
            dt * 1e6 / (b / 4096) as f64
        );
    }
}
