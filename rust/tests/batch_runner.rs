//! Batch-runner determinism gate (ISSUE 3 acceptance criteria).
//!
//! Over a ≥ 6-job manifest mixing ER and GRN topologies, Pearson and
//! Spearman correlations, CSV / registry / scenario sources, and two
//! alphas on one dataset, the rendered results stream must be
//! bit-identical for `--job-threads ∈ {1, 4}`, for different global
//! thread budgets, and for warm vs. cold cache — with the cache
//! actually firing (≥ 1 recorded hit on the sequential cold run, full
//! result-layer hits on the warm run).

use cupc::service::{render_results, run_batch, BatchOptions, Cache, Manifest};
use cupc::util::json::Json;

/// Build the mixed manifest; writes the CSV job's data to a temp file
/// (`tag` keeps concurrently running tests off each other's file).
fn mixed_manifest(tag: &str) -> (Manifest, std::path::PathBuf) {
    // deterministic CSV source: a small simulated ER dataset
    let ds = cupc::sim::datasets::generate_er(12, 150, 0.2, 42);
    let csv_path = std::env::temp_dir().join(format!(
        "cupc_batch_gate_{}_{tag}.csv",
        std::process::id()
    ));
    cupc::data::csv::write_csv(&csv_path, &ds.data).unwrap();

    let text = format!(
        r#"{{"jobs": [
            {{"name": "er-a01",   "scenario": "sparse-a01", "variant": "cups"}},
            {{"name": "er-a05",   "scenario": "sparse-a01", "variant": "cups", "alpha": 0.05}},
            {{"name": "grn",      "scenario": "grn-mid",    "variant": "cups"}},
            {{"name": "rank-er",  "scenario": "rank-er",    "variant": "cupe", "corr": "spearman"}},
            {{"name": "rank-grn", "scenario": "rank-grn",   "variant": "cups", "corr": "spearman", "max_level": 2}},
            {{"name": "csv-job",  "csv": "{}",              "variant": "cupe", "alpha": 0.05, "orient": "majority"}},
            {{"name": "registry", "dataset": "nci60-mini",  "variant": "cups", "max_level": 1}}
        ]}}"#,
        csv_path.display()
    );
    (Manifest::parse(&text).unwrap(), csv_path)
}

fn opts(job_threads: usize, threads: usize) -> BatchOptions {
    BatchOptions {
        job_threads,
        threads,
        cache_bytes: 64 << 20,
        verbose: false,
    }
}

#[test]
fn batch_results_are_scheduling_and_cache_invariant() {
    let (manifest, csv_path) = mixed_manifest("invariance");
    assert!(
        manifest.jobs.len() >= 6,
        "the gate requires a ≥ 6-job manifest"
    );

    // cold run, sequential: the reference rendering
    let cache = Cache::new(64 << 20);
    let cold = run_batch(&manifest, &opts(1, 2), &cache).unwrap();
    let reference = render_results(&manifest.jobs, &cold.reports);

    // ≥ 1 recorded cache hit even cold: two alphas over one dataset
    // share the correlation layer (sequential, so the hit is guaranteed)
    assert!(
        cold.cache.hits >= 1,
        "expected a corr-layer hit on the cold sequential run, stats: {:?}",
        cold.cache
    );
    assert!(
        cold.reports[1].corr_cache_hit,
        "er-a05 must reuse er-a01's correlation matrix"
    );

    // job-threads 4, cold: bit-identical results, and the in-flight
    // coalescing still yields a corr-layer hit for the second alpha
    // (the waiter re-checks the cache after the computer's put)
    let cold4 = run_batch(&manifest, &opts(4, 2), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &cold4.reports),
        "results.jsonl must be bit-identical for --job-threads 1 vs 4"
    );
    assert!(
        cold4.cache.hits >= 1,
        "concurrent same-data jobs must coalesce on one gram, stats: {:?}",
        cold4.cache
    );

    // different global thread budget: bit-identical results
    let wide = run_batch(&manifest, &opts(1, 4), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &wide.reports),
        "results.jsonl must be bit-identical across thread budgets"
    );

    // warm rerun on the populated cache: bit-identical, fully served
    // from the result layer
    let warm = run_batch(&manifest, &opts(4, 2), &cache).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &warm.reports),
        "results.jsonl must be bit-identical warm vs cold"
    );
    assert!(
        warm.reports.iter().all(|r| r.result_cache_hit),
        "every warm job must be served from the result cache"
    );
    // cached-vs-recomputed cores are bitwise equal
    for (a, b) in cold.reports.iter().zip(&warm.reports) {
        assert_eq!(a.core, b.core);
    }

    // every record is valid JSON carrying the deterministic fields only
    assert_eq!(reference.lines().count(), manifest.jobs.len());
    for line in reference.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad record {line:?}: {e:#}"));
        assert!(v.get("job").is_some());
        assert!(v.get("levels").is_some());
        assert!(v.get("skeleton").is_some());
        assert!(
            v.get("seconds_run").is_none() && v.get("corr_cache").is_none(),
            "observational fields leaked into the deterministic stream: {line}"
        );
    }

    std::fs::remove_file(&csv_path).ok();
}

/// The manifest echo in each record pins the requested workload mix —
/// ER + GRN topologies, Pearson + Spearman, and ≥ 2 alphas on one
/// dataset — so the gate cannot silently lose coverage.
#[test]
fn gate_manifest_covers_the_required_mix() {
    let (manifest, csv_path) = mixed_manifest("mix");
    std::fs::remove_file(&csv_path).ok();
    let grid = cupc::sim::scenarios::default_grid;
    let topology_of = |name: &str| {
        grid()
            .into_iter()
            .find(|s| s.name == name)
            .map(|s| s.topology)
    };
    let has_grn = manifest.jobs.iter().any(|j| {
        matches!(
            &j.source,
            cupc::service::DataSource::Scenario(n)
                if matches!(topology_of(n), Some(cupc::sim::datasets::Topology::Grn(..)))
        )
    });
    let has_er = manifest.jobs.iter().any(|j| {
        matches!(
            &j.source,
            cupc::service::DataSource::Scenario(n)
                if matches!(topology_of(n), Some(cupc::sim::datasets::Topology::Er(_)))
        )
    });
    assert!(has_grn && has_er, "topology mix");
    let kinds: std::collections::HashSet<&str> =
        manifest.jobs.iter().map(|j| j.corr.name()).collect();
    assert!(kinds.contains("pearson") && kinds.contains("spearman"), "corr mix");
    // ≥ 2 alphas over one data source
    let mut sparse_alphas: Vec<u64> = manifest
        .jobs
        .iter()
        .filter(|j| j.source == cupc::service::DataSource::Scenario("sparse-a01".into()))
        .map(|j| (j.alpha * 1e6) as u64)
        .collect();
    sparse_alphas.sort_unstable();
    sparse_alphas.dedup();
    assert!(sparse_alphas.len() >= 2, "two alphas on one dataset");
}
