//! Batch-runner determinism gate (ISSUE 3 + ISSUE 4 acceptance
//! criteria).
//!
//! Over a ≥ 6-job manifest mixing ER and GRN topologies, Pearson and
//! Spearman correlations, CSV / registry / scenario sources, and two
//! alphas on one dataset, the rendered results stream must be
//! bit-identical for `--job-threads ∈ {1, 4}`, for different global
//! thread budgets (with between-level lease resizing active), and for
//! every cache state — cold, warm in-process, cold disk, warm disk, and
//! a cache directory shared by concurrent batch runs — with the caches
//! actually firing (≥ 1 corr-layer hit cold, all-warm result hits warm,
//! and ≥ 1 disk hit per layer on the warm-disk run).

use cupc::service::{
    render_results, render_stats, run_batch, BatchOptions, Cache, CacheOutcome, Manifest,
};
use cupc::util::json::Json;
use std::path::PathBuf;

/// Build the mixed manifest; writes the CSV job's data to a temp file
/// (`tag` keeps concurrently running tests off each other's file).
fn mixed_manifest(tag: &str) -> (Manifest, std::path::PathBuf) {
    // deterministic CSV source: a small simulated ER dataset
    let ds = cupc::sim::datasets::generate_er(12, 150, 0.2, 42);
    let csv_path = std::env::temp_dir().join(format!(
        "cupc_batch_gate_{}_{tag}.csv",
        std::process::id()
    ));
    cupc::data::csv::write_csv(&csv_path, &ds.data).unwrap();

    let text = format!(
        r#"{{"jobs": [
            {{"name": "er-a01",   "scenario": "sparse-a01", "variant": "cups"}},
            {{"name": "er-a05",   "scenario": "sparse-a01", "variant": "cups", "alpha": 0.05}},
            {{"name": "grn",      "scenario": "grn-mid",    "variant": "cups"}},
            {{"name": "rank-er",  "scenario": "rank-er",    "variant": "cupe", "corr": "spearman"}},
            {{"name": "rank-grn", "scenario": "rank-grn",   "variant": "cups", "corr": "spearman", "max_level": 2}},
            {{"name": "csv-job",  "csv": "{}",              "variant": "cupe", "alpha": 0.05, "orient": "majority"}},
            {{"name": "registry", "dataset": "nci60-mini",  "variant": "cups", "max_level": 1}}
        ]}}"#,
        csv_path.display()
    );
    (Manifest::parse(&text).unwrap(), csv_path)
}

fn opts(job_threads: usize, threads: usize) -> BatchOptions {
    BatchOptions {
        job_threads,
        threads,
        cache_bytes: 64 << 20,
        ..BatchOptions::default()
    }
}

fn disk_opts(job_threads: usize, threads: usize, dir: &std::path::Path) -> BatchOptions {
    BatchOptions {
        cache_dir: Some(dir.to_path_buf()),
        disk_bytes: 64 << 20,
        ..opts(job_threads, threads)
    }
}

fn tmp_cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cupc_batch_cachedir_{}_{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn batch_results_are_scheduling_and_cache_invariant() {
    let (manifest, csv_path) = mixed_manifest("invariance");
    assert!(
        manifest.jobs.len() >= 6,
        "the gate requires a ≥ 6-job manifest"
    );

    // cold run, sequential: the reference rendering
    let cache = Cache::new(64 << 20);
    let cold = run_batch(&manifest, &opts(1, 2), &cache).unwrap();
    let reference = render_results(&manifest.jobs, &cold.reports);

    // ≥ 1 recorded cache hit even cold: two alphas over one dataset
    // share the correlation layer (sequential, so the hit is guaranteed)
    assert!(
        cold.cache.hits >= 1,
        "expected a corr-layer hit on the cold sequential run, stats: {:?}",
        cold.cache
    );
    assert_eq!(
        cold.reports[1].corr_cache,
        CacheOutcome::Mem,
        "er-a05 must reuse er-a01's correlation matrix"
    );

    // job-threads 4, cold: bit-identical results, and the in-flight
    // coalescing still yields a corr-layer hit for the second alpha
    // (the waiter re-checks the cache after the computer's put).
    // With 4 job workers on a 2-worker budget the elastic leases start
    // narrow and re-lease between levels as jobs finish — the resize
    // schedule is nondeterministic, and the results must not care.
    let cold4 = run_batch(&manifest, &opts(4, 2), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &cold4.reports),
        "results.jsonl must be bit-identical for --job-threads 1 vs 4"
    );
    assert!(
        cold4.cache.hits >= 1,
        "concurrent same-data jobs must coalesce on one gram, stats: {:?}",
        cold4.cache
    );
    assert!(
        cold4
            .reports
            .iter()
            .all(|r| r.threads_peak >= r.threads_used),
        "the peak lease width can never be below the starting width"
    );

    // different global thread budget: bit-identical results
    let wide = run_batch(&manifest, &opts(1, 4), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &wide.reports),
        "results.jsonl must be bit-identical across thread budgets"
    );

    // warm rerun on the populated cache: bit-identical, fully served
    // from the result layer
    let warm = run_batch(&manifest, &opts(4, 2), &cache).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &warm.reports),
        "results.jsonl must be bit-identical warm vs cold"
    );
    assert!(
        warm.reports.iter().all(|r| r.result_cache.is_hit()),
        "every warm job must be served from the result cache"
    );
    // cached-vs-recomputed cores are bitwise equal
    for (a, b) in cold.reports.iter().zip(&warm.reports) {
        assert_eq!(a.core, b.core);
    }

    // every record is valid JSON carrying the deterministic fields only
    assert_eq!(reference.lines().count(), manifest.jobs.len());
    for line in reference.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad record {line:?}: {e:#}"));
        assert!(v.get("job").is_some());
        assert!(v.get("levels").is_some());
        assert!(v.get("skeleton").is_some());
        let o = v.get("orientation").expect("orientation block is deterministic");
        assert!(o.get("triples").is_some());
        assert!(o.get("census_tests").is_some());
        assert!(o.get("meek_sweeps").is_some());
        assert!(
            v.get("seconds_run").is_none() && v.get("corr_cache").is_none(),
            "observational fields leaked into the deterministic stream: {line}"
        );
    }

    std::fs::remove_file(&csv_path).ok();
}

/// The ISSUE 4 tentpole gate: cold-disk, warm-disk and in-process-only
/// runs must render bit-identical results, and the warm-disk run (a
/// fresh in-process cache over a populated `--cache-dir`, i.e. a new
/// process) must be served from the persistent store — ≥ 1 corr-layer
/// disk hit, ≥ 1 result-layer disk hit, and no result-layer recompute.
#[test]
fn disk_cache_survives_process_boundaries_bit_identically() {
    let (manifest, csv_path) = mixed_manifest("disk");
    let dir = tmp_cache_dir("persist");

    // in-process-only reference
    let inproc = run_batch(&manifest, &opts(1, 2), &Cache::new(64 << 20)).unwrap();
    let reference = render_results(&manifest.jobs, &inproc.reports);

    // cold disk: empty --cache-dir, fresh memory cache
    let cold = run_batch(&manifest, &disk_opts(2, 2, &dir), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &cold.reports),
        "a cold disk cache must not change results.jsonl"
    );
    let cold_disk = cold.disk.expect("disk stats with --cache-dir");
    assert_eq!(cold_disk.hits, 0, "nothing to hit on an empty store");
    assert!(cold_disk.entries >= 2, "grams + results persisted: {cold_disk:?}");
    assert_eq!(cold_disk.dropped, 0, "{cold_disk:?}");

    // warm disk, "new process": fresh memory cache, same directory
    let warm = run_batch(&manifest, &disk_opts(2, 2, &dir), &Cache::new(64 << 20)).unwrap();
    assert_eq!(
        reference,
        render_results(&manifest.jobs, &warm.reports),
        "a warm disk cache must serve byte-identical results"
    );
    assert!(
        warm.reports
            .iter()
            .any(|r| r.corr_cache == CacheOutcome::Disk),
        "≥ 1 correlation matrix must come off disk"
    );
    assert!(
        warm.reports
            .iter()
            .any(|r| r.result_cache == CacheOutcome::Disk),
        "≥ 1 result must come off disk"
    );
    assert!(
        warm.reports.iter().all(|r| r.result_cache.is_hit()),
        "no warm-disk job may recompute its result"
    );

    // the stats sidecar carries what the CI warm-cache gate greps for
    let warm_disk = warm.disk.expect("disk stats");
    let stats = render_stats(
        &manifest.jobs,
        &warm.reports,
        &warm.cache,
        Some(&warm_disk),
    );
    assert!(
        stats.contains("\"corr_cache\":\"disk\""),
        "sidecar must record the disk corr hit:\n{stats}"
    );
    assert!(
        !stats.contains("\"result_cache\":\"miss\""),
        "sidecar must show all-warm result hits:\n{stats}"
    );
    assert!(stats.contains("\"disk\":{"), "trailing disk record:\n{stats}");
    for line in stats.lines() {
        Json::parse(line).unwrap_or_else(|e| panic!("bad stats record {line:?}: {e:#}"));
    }

    let _ = std::fs::remove_dir_all(&dir);
    std::fs::remove_file(&csv_path).ok();
}

/// Two concurrent `run_batch` calls sharing one `--cache-dir` (the
/// multi-process story, exercised in-process with two independent
/// memory caches) must both succeed bit-identically — rename-atomic
/// writes and checksum-validated reads make torn sharing impossible.
#[test]
fn concurrent_batches_share_one_cache_dir() {
    let (manifest, csv_path) = mixed_manifest("shared");
    let dir = tmp_cache_dir("shared");
    let reference = render_results(
        &manifest.jobs,
        &run_batch(&manifest, &opts(1, 2), &Cache::new(64 << 20))
            .unwrap()
            .reports,
    );

    let renders: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let manifest = &manifest;
                let dir = &dir;
                scope.spawn(move || {
                    let out =
                        run_batch(manifest, &disk_opts(2, 2, dir), &Cache::new(64 << 20))
                            .unwrap();
                    render_results(&manifest.jobs, &out.reports)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, r) in renders.iter().enumerate() {
        assert_eq!(
            &reference, r,
            "concurrent batch #{i} over a shared cache dir must stay bit-identical"
        );
    }

    // and a third, warm run over whatever the race left behind
    let warm = run_batch(&manifest, &disk_opts(1, 2, &dir), &Cache::new(64 << 20)).unwrap();
    assert_eq!(reference, render_results(&manifest.jobs, &warm.reports));

    let _ = std::fs::remove_dir_all(&dir);
    std::fs::remove_file(&csv_path).ok();
}

/// A deliberately hostile between-level re-lease schedule (width
/// zig-zags every level) must be bit-identical to a fixed-width run —
/// the pipeline invariance that makes elastic leases a pure throughput
/// knob. Runs the batched schedules (cuPC-S, cuPC-E, reversed) over a
/// scenario each.
#[test]
fn pathological_re_lease_schedules_are_bit_identical() {
    use cupc::api::pc_stable_corr;
    use cupc::skeleton::{Config, Variant, WidthHook, WidthPolicy};
    use std::sync::Arc;

    struct ZigZag;
    impl WidthPolicy for ZigZag {
        fn width_for_level(&self, level: usize) -> usize {
            [3, 1, 4, 2][level % 4]
        }
    }

    for (scenario, variant) in [
        ("sparse-a01", Variant::CupcS),
        ("grn-mid", Variant::CupcE),
        ("grn-mid", Variant::Reversed),
    ] {
        let sc = cupc::sim::scenarios::find(scenario).unwrap();
        let (_, data) = sc.generate_data();
        let corr = sc.corr.matrix(&data, 1);
        let base = Config {
            alpha: sc.alpha,
            max_level: sc.max_level,
            variant,
            threads: 2,
            ..Config::default()
        };
        let fixed = pc_stable_corr(&corr, data.n, data.m, &base).unwrap();
        let hooked_cfg = Config {
            width_hook: Some(WidthHook(Arc::new(ZigZag))),
            ..base.clone()
        };
        let hooked = pc_stable_corr(&corr, data.n, data.m, &hooked_cfg).unwrap();
        assert_eq!(
            fixed.skeleton.graph.snapshot(),
            hooked.skeleton.graph.snapshot(),
            "{scenario}/{variant:?}: skeleton must be width-schedule invariant"
        );
        assert_eq!(
            fixed.skeleton.sepsets.sorted_entries(),
            hooked.skeleton.sepsets.sorted_entries(),
            "{scenario}/{variant:?}: sepsets must be width-schedule invariant"
        );
        let levels = |r: &cupc::api::PcResult| -> Vec<(usize, u64, usize, usize)> {
            r.skeleton
                .levels
                .iter()
                .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                .collect()
        };
        assert_eq!(
            levels(&fixed),
            levels(&hooked),
            "{scenario}/{variant:?}: per-level stats incl. test counts must match"
        );
    }
}

/// The manifest echo in each record pins the requested workload mix —
/// ER + GRN topologies, Pearson + Spearman, and ≥ 2 alphas on one
/// dataset — so the gate cannot silently lose coverage.
#[test]
fn gate_manifest_covers_the_required_mix() {
    let (manifest, csv_path) = mixed_manifest("mix");
    std::fs::remove_file(&csv_path).ok();
    let grid = cupc::sim::scenarios::default_grid;
    let topology_of = |name: &str| {
        grid()
            .into_iter()
            .find(|s| s.name == name)
            .map(|s| s.topology)
    };
    let has_grn = manifest.jobs.iter().any(|j| {
        matches!(
            &j.source,
            cupc::service::DataSource::Scenario(n)
                if matches!(topology_of(n), Some(cupc::sim::datasets::Topology::Grn(..)))
        )
    });
    let has_er = manifest.jobs.iter().any(|j| {
        matches!(
            &j.source,
            cupc::service::DataSource::Scenario(n)
                if matches!(topology_of(n), Some(cupc::sim::datasets::Topology::Er(_)))
        )
    });
    assert!(has_grn && has_er, "topology mix");
    let kinds: std::collections::HashSet<&str> =
        manifest.jobs.iter().map(|j| j.corr.name()).collect();
    assert!(kinds.contains("pearson") && kinds.contains("spearman"), "corr mix");
    // ≥ 2 alphas over one data source
    let mut sparse_alphas: Vec<u64> = manifest
        .jobs
        .iter()
        .filter(|j| j.source == cupc::service::DataSource::Scenario("sparse-a01".into()))
        .map(|j| (j.alpha * 1e6) as u64)
        .collect();
    sparse_alphas.sort_unstable();
    sparse_alphas.dedup();
    assert!(sparse_alphas.len() >= 2, "two alphas on one dataset");
}
