//! serve-vs-batch conformance gate (ISSUE 6 acceptance criteria).
//!
//! The demo manifest (`examples/batch_demo.json`) must produce a
//! byte-identical results stream whether it runs through `cupc batch`
//! (the in-process `run_batch` path) or a live `cupc serve` daemon —
//! cold cache, warm cache, either priority, two clients concurrently
//! over a shared `--cache-dir`, and a *fresh* daemon process serving
//! from the populated disk tier. On top of the determinism gate, a
//! malformed-request corpus (deep nesting bombs, non-finite numbers,
//! truncated frames, slow-loris stalls, garbage bytes, non-UTF-8
//! payloads) must each produce a structured error while the daemon
//! keeps serving everyone else.

use cupc::service::proto::Priority;
use cupc::service::server::{spawn, Client, ServeOptions};
use cupc::service::{render_results, run_batch, BatchOptions, Cache, Manifest};
use cupc::util::json::Json;
use std::path::PathBuf;
use std::time::Duration;

const DEMO: &str = "examples/batch_demo.json";

fn demo_text() -> String {
    std::fs::read_to_string(DEMO).expect("the demo manifest ships with the repo")
}

/// The `cupc batch` side of the conformance equation.
fn batch_reference(manifest_text: &str) -> String {
    let manifest = Manifest::parse(manifest_text).unwrap();
    let out = run_batch(
        &manifest,
        &BatchOptions {
            job_threads: 1,
            threads: 2,
            cache_bytes: 64 << 20,
            ..BatchOptions::default()
        },
        &Cache::new(64 << 20),
    )
    .unwrap();
    render_results(&manifest.jobs, &out.reports)
}

fn serve_opts() -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        threads: 2,
        cache_bytes: 64 << 20,
        frame_timeout: Duration::from_secs(2),
        ..ServeOptions::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cupc_serve_conf_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Cold daemon, warm daemon, and both priorities: every served stream
/// must equal the `cupc batch` rendering byte for byte.
#[test]
fn served_stream_is_bit_identical_to_batch_cold_and_warm() {
    let text = demo_text();
    let reference = batch_reference(&text);
    assert_eq!(reference.lines().count(), 9, "demo manifest is 9 jobs");

    let handle = spawn(serve_opts()).unwrap();
    let mut c = Client::connect(&handle.addr.to_string()).unwrap();
    let cold = c.submit(&text, Priority::Low).unwrap();
    assert_eq!(
        reference, cold,
        "cold serve stream must equal the batch results file byte for byte"
    );
    // warm: the daemon's in-process cache now holds every layer; a
    // different priority must not move a byte either
    let warm = c.submit(&text, Priority::High).unwrap();
    assert_eq!(reference, warm, "warm serve stream must stay byte-identical");

    // the warm pass was actually served from cache
    let stats = c.stats().unwrap();
    let v = Json::parse(&stats).unwrap();
    let cache = v.get("stats").unwrap().get("cache").unwrap();
    assert!(
        cache.get("hits").unwrap().as_usize().unwrap() >= 9,
        "warm submit must hit the shared result cache: {stats}"
    );
    handle.shutdown().unwrap();
}

/// Two clients submitting the demo manifest concurrently against one
/// daemon (shared budget, shared cache, shared `--cache-dir`) must both
/// receive the reference bytes; a *fresh* daemon over the populated
/// cache dir (memory-cold, disk-warm — the restart story) must serve
/// the same bytes again, off the disk tier.
#[test]
fn concurrent_clients_and_daemon_restarts_stay_bit_identical() {
    let text = demo_text();
    let reference = batch_reference(&text);
    let dir = tmp_dir("restart");

    let opts = ServeOptions {
        cache_dir: Some(dir.clone()),
        disk_bytes: 64 << 20,
        ..serve_opts()
    };
    let handle = spawn(opts.clone()).unwrap();
    let addr = handle.addr.to_string();
    let streams: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = [Priority::Normal, Priority::High]
            .into_iter()
            .map(|prio| {
                let addr = &addr;
                let text = &text;
                scope.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    c.submit(text, prio).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, s) in streams.iter().enumerate() {
        assert_eq!(
            &reference, s,
            "concurrent client #{i} must receive the reference bytes"
        );
    }
    handle.shutdown().unwrap();

    // restart: a fresh daemon, memory-cold, over the populated cache dir
    let handle = spawn(opts).unwrap();
    let mut c = Client::connect(&handle.addr.to_string()).unwrap();
    let after_restart = c.submit(&text, Priority::Normal).unwrap();
    assert_eq!(
        reference, after_restart,
        "a restarted daemon must serve byte-identical results from the disk tier"
    );
    let stats = c.stats().unwrap();
    let v = Json::parse(&stats).unwrap();
    let disk = v.get("stats").unwrap().get("disk").unwrap();
    assert!(
        disk.get("hits").unwrap().as_usize().unwrap() >= 2,
        "the restarted daemon must be served from the disk tier: {stats}"
    );
    handle.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The malformed-request corpus: every hostile input yields a
/// structured error (or a clean connection drop where framing is
/// unrecoverable), and the daemon keeps serving throughout.
#[test]
fn malformed_request_corpus_never_takes_the_daemon_down() {
    let handle = spawn(serve_opts()).unwrap();
    let addr = handle.addr.to_string();

    // --- well-framed but malformed payloads: the connection survives ---
    let mut c = Client::connect(&addr).unwrap();
    for (payload, needle) in [
        // a nesting bomb deep enough to overflow an uncapped recursive
        // parser's stack (which would abort the process, not error)
        ("[".repeat(100_000), "nesting deeper"),
        // overflow-to-infinity numbers have no JSON rendering downstream
        (
            r#"{"op":"submit","manifest":{"jobs":[{"scenario":"grn-mid","alpha":1e999}]}}"#
                .to_string(),
            "overflows a finite double",
        ),
        ("not json".to_string(), "bad-request"),
        (r#"{"op":"warp"}"#.to_string(), "unknown op"),
        (
            r#"{"op":"submit","manifest":{"jobs":[{"scenario":"nope"}]}}"#.to_string(),
            "unknown scenario",
        ),
        (
            r#"{"op":"submit","manifest":{"jobs":[{"name":"x","scenario":"grn-mid"},
                                                  {"name":"x","scenario":"rank-er"}]}}"#
                .to_string(),
            "duplicate job name",
        ),
    ] {
        c.send(&payload).unwrap();
        let resp = c.recv().unwrap();
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains(needle), "expected {needle:?} in {resp}");
        c.ping()
            .unwrap_or_else(|e| panic!("daemon must keep serving after {needle:?}: {e:#}"));
    }
    // non-UTF-8 payload bytes, correctly framed
    c.send_raw(&[4, 0, 0, 0, 0xff, 0xfe, 0x01, 0x02]).unwrap();
    let resp = c.recv().unwrap();
    assert!(resp.contains("not UTF-8"), "{resp}");
    c.ping().unwrap();
    drop(c);

    // --- framing violations: one structured error, then the daemon
    // closes that connection (its stream position is untrustworthy) ---
    // garbage bytes (an HTTP request line read as a length prefix)
    let mut g = Client::connect(&addr).unwrap();
    g.send_raw(b"GET / HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
    let resp = g.recv().unwrap();
    assert!(resp.contains("\"bad-frame\""), "{resp}");
    assert!(resp.contains("request cap"), "{resp}");
    drop(g);

    // an explicitly empty frame
    let mut e = Client::connect(&addr).unwrap();
    e.send_raw(&0u32.to_le_bytes()).unwrap();
    let resp = e.recv().unwrap();
    assert!(resp.contains("empty frame"), "{resp}");
    drop(e);

    // a truncated frame whose sender hangs up mid-payload
    let mut t = Client::connect(&addr).unwrap();
    t.send_raw(&100u32.to_le_bytes()).unwrap();
    t.send_raw(b"only ten b").unwrap();
    drop(t); // the daemon sees EOF mid-frame and drops the connection

    // a slow-loris: frame started, then silence past frame_timeout
    let mut s = Client::connect(&addr).unwrap();
    s.send_raw(&100u32.to_le_bytes()).unwrap();
    s.send_raw(b"stall").unwrap();
    let resp = s.recv().unwrap();
    assert!(resp.contains("stalled"), "{resp}");
    drop(s);

    // through all of it, fresh clients are served normally — including
    // a real job
    let mut alive = Client::connect(&addr).unwrap();
    alive.ping().unwrap();
    let results = alive
        .submit(
            r#"{"jobs":[{"name":"still-up","scenario":"sparse-a01"}]}"#,
            Priority::Normal,
        )
        .unwrap();
    assert_eq!(results.lines().count(), 1);
    assert!(results.contains("\"job\":\"still-up\""), "{results}");
    handle.shutdown().unwrap();
}

/// The connection cap turns extra clients away with a structured `busy`
/// error instead of queueing them invisibly, and a slot freed by a
/// disconnect is reusable.
#[test]
fn connection_cap_rejects_with_busy_and_recovers() {
    let opts = ServeOptions {
        max_conns: 1,
        ..serve_opts()
    };
    let handle = spawn(opts).unwrap();
    let addr = handle.addr.to_string();
    let mut first = Client::connect(&addr).unwrap();
    first.ping().unwrap(); // handler registered: the slot is taken
    let mut second = Client::connect(&addr).unwrap();
    let resp = second.recv().unwrap();
    assert!(resp.contains("\"busy\""), "{resp}");
    drop(second);
    drop(first);
    // the freed slot is reusable (poll briefly: the handler thread
    // releases its slot asynchronously after the disconnect)
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let mut again = Client::connect(&addr).unwrap();
        if again.ping().is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "freed connection slot never became reusable"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    handle.shutdown().unwrap();
}
