//! Out-of-core conformance gate (ISSUE 8 acceptance): the skeleton,
//! sepsets, and CPDAG must be **bit-identical** across
//!
//! * the dense in-memory path (the pre-out-of-core behavior),
//! * the sparse + streamed-window path (any window budget), and
//! * the cross-process sharded path (every rank of a `cupc shard`-style
//!   run, here driven in-process through the same [`DiskExchange`]
//!   protocol the binary uses),
//!
//! and the streamed window buffer must respect its byte budget
//! (`peak_window_bytes ≤ window_runs × size_of::<Run>()`), which is the
//! documented memory bound of the subsystem. Small grid points run in
//! every profile; the `oocore-2k` / `oocore-10k` sizes — where the
//! sparse representation actually engages via `AdjMode::Auto` — are
//! release-build only.
//!
//! [`DiskExchange`]: cupc::oocore::exchange::DiskExchange

use cupc::api::{finish_orientation, pc_stable_corr};
use cupc::oocore::shard::{publish_plan, run_skeleton_sharded, ShardPlan};
use cupc::service::{DiskStore, JobResultCore};
use cupc::sim::scenarios::{find, Scenario};
use cupc::skeleton::pipeline::Run;
use cupc::skeleton::{AdjMode, Config, OocConfig, SkeletonResult, Variant};
use std::time::Duration;

/// Everything deterministic about a skeleton run, comparable bitwise.
type Fingerprint = (
    Vec<u8>,
    Vec<((u32, u32), Vec<u32>)>,
    Vec<(usize, u64, usize, usize)>,
);

fn fingerprint(skel: &SkeletonResult) -> Fingerprint {
    (
        skel.graph.snapshot(),
        skel.sepsets.sorted_entries(),
        skel.levels
            .iter()
            .map(|l| (l.level, l.tests, l.removed, l.edges_after))
            .collect(),
    )
}

fn scenario(name: &str) -> Scenario {
    find(name).unwrap_or_else(|| panic!("scenario {name} missing"))
}

fn cfg_with(sc: &Scenario, variant: Variant, ooc: OocConfig) -> Config {
    let mut cfg = sc.config(variant);
    cfg.ooc = ooc;
    cfg
}

fn tiny_windows(adjacency: AdjMode) -> OocConfig {
    OocConfig {
        adjacency,
        window_runs: 3,
        window_slots: 32,
    }
}

/// Run `sc` sharded across `world` in-process ranks over one shared
/// store directory — the exact worker path of `cupc shard` minus the
/// process boundary — and return every rank's skeleton.
fn run_sharded(
    sc: &Scenario,
    variant: Variant,
    world: usize,
    ooc: OocConfig,
    tag: &str,
) -> (Vec<SkeletonResult>, Config, Vec<f64>) {
    let input = sc.generate();
    let mut cfg = cfg_with(sc, variant, ooc).with_threads(1);
    cfg.threads = 1;
    let dir = std::env::temp_dir().join(format!(
        "cupc_ooconf_{}_{}_{tag}",
        std::process::id(),
        sc.name
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let corr_key = (0xc0, 0xffee);
    let plan = ShardPlan::new(input.n, input.m, corr_key, &cfg, world);
    {
        let store = DiskStore::open(&dir, u64::MAX).unwrap();
        store.put_corr(corr_key, &input.corr);
        publish_plan(&store, &plan).unwrap();
    }
    let key = plan.key();
    let timing = Some((Duration::from_millis(1), Duration::from_secs(120)));
    let skels = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let dir = &dir;
                scope.spawn(move || {
                    let store = DiskStore::open(dir, u64::MAX).unwrap();
                    run_skeleton_sharded(store, key, rank, timing)
                        .unwrap_or_else(|e| panic!("rank {rank}: {e:#}"))
                        .1
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect::<Vec<_>>()
    });
    let _ = std::fs::remove_dir_all(&dir);
    (skels, cfg, input.corr)
}

/// The headline 3-way identity on CI-sized points: dense in-memory vs
/// forced-sparse streamed (tiny windows) — skeleton, sepsets, per-level
/// stats, and the majority-rule CPDAG.
#[test]
fn forced_sparse_and_tiny_windows_match_dense_bitwise() {
    for name in ["sparse-a01", "dense-cap2", "rank-grn"] {
        let sc = scenario(name);
        let input = sc.generate();
        for variant in [Variant::CupcS, Variant::CupcE, Variant::Reversed] {
            let dense_cfg = cfg_with(
                &sc,
                variant,
                OocConfig {
                    adjacency: AdjMode::Dense,
                    ..OocConfig::default()
                },
            );
            let sparse_cfg = cfg_with(&sc, variant, tiny_windows(AdjMode::Sparse));
            let dense = pc_stable_corr(&input.corr, input.n, input.m, &dense_cfg).unwrap();
            let sparse = pc_stable_corr(&input.corr, input.n, input.m, &sparse_cfg).unwrap();
            assert_eq!(dense.skeleton.ooc.adjacency, "dense", "{name}/{variant:?}");
            assert_eq!(sparse.skeleton.ooc.adjacency, "sparse", "{name}/{variant:?}");
            assert_eq!(
                fingerprint(&dense.skeleton),
                fingerprint(&sparse.skeleton),
                "{name}/{variant:?}: sparse+streamed skeleton diverged"
            );
            assert!(
                dense.cpdag.same_as(&sparse.cpdag),
                "{name}/{variant:?}: CPDAG diverged"
            );
            assert_eq!(
                JobResultCore::from_pc(&dense, input.n, input.m),
                JobResultCore::from_pc(&sparse, input.n, input.m),
                "{name}/{variant:?}: result core diverged"
            );
        }
    }
}

/// Window budgets are pure memory knobs: any (runs, slots) pair — down
/// to one slot per chunk — produces the identical result, and the peak
/// buffer stays within the budget.
#[test]
fn window_budgets_are_pure_memory_knobs() {
    let sc = scenario("mid-lowm");
    let input = sc.generate();
    let reference = {
        let cfg = cfg_with(&sc, Variant::CupcS, OocConfig::default());
        pc_stable_corr(&input.corr, input.n, input.m, &cfg).unwrap()
    };
    for (window_runs, window_slots) in [(1, 1), (2, 16), (7, 129), (1 << 16, 1 << 20)] {
        for adjacency in [AdjMode::Dense, AdjMode::Sparse] {
            let cfg = cfg_with(
                &sc,
                Variant::CupcS,
                OocConfig {
                    adjacency,
                    window_runs,
                    window_slots,
                },
            );
            let res = pc_stable_corr(&input.corr, input.n, input.m, &cfg).unwrap();
            assert_eq!(
                fingerprint(&res.skeleton),
                fingerprint(&reference.skeleton),
                "runs={window_runs} slots={window_slots} {adjacency:?}"
            );
            assert!(res.cpdag.same_as(&reference.cpdag));
            let bound = window_runs as u64 * std::mem::size_of::<Run>() as u64;
            assert!(
                res.skeleton.ooc.peak_window_bytes <= bound,
                "runs={window_runs}: peak {} exceeds the documented bound {bound}",
                res.skeleton.ooc.peak_window_bytes
            );
        }
    }
}

/// Cross-process identity, end to end: every rank of a 2- and 3-way
/// sharded run reproduces the single-process skeleton bit for bit, and
/// rank 0's orientation yields the identical result core `cupc batch`
/// would emit.
#[test]
fn sharded_ranks_reproduce_the_single_process_result_end_to_end() {
    for (name, world) in [("mid-lowm", 2), ("grn-mid", 3)] {
        let sc = scenario(name);
        let input = sc.generate();
        let ooc = OocConfig {
            adjacency: AdjMode::Auto,
            window_runs: 2,
            window_slots: 16, // force real multi-chunk rounds + exchanges
        };
        let (skels, cfg, corr) = run_sharded(&sc, Variant::CupcS, world, ooc.clone(), "e2e");
        let single = {
            let cfg1 = cfg_with(&sc, Variant::CupcS, ooc).with_threads(1);
            pc_stable_corr(&input.corr, input.n, input.m, &cfg1).unwrap()
        };
        let want = fingerprint(&single.skeleton);
        assert_eq!(skels.len(), world);
        for (rank, skel) in skels.iter().enumerate() {
            assert_eq!(
                fingerprint(skel),
                want,
                "{name}: rank {rank}/{world} skeleton diverged"
            );
        }
        // orient rank 0's skeleton exactly like the shard coordinator
        let rank0 = skels.into_iter().next().unwrap();
        let sharded = finish_orientation(&corr, input.m, &cfg, rank0).unwrap();
        assert_eq!(
            JobResultCore::from_pc(&sharded, input.n, input.m),
            JobResultCore::from_pc(&single, input.n, input.m),
            "{name}: sharded result core diverged from single-process"
        );
    }
}

/// The schedule-factory seam: the gpu-e family, both Fig. 5 baselines
/// (whose factories bake in their γ/β overrides), and the reversed-order
/// schedule all shard to the same bits as their single-process runs.
#[test]
fn every_batched_family_shards_identically() {
    let sc = scenario("sparse-a05");
    let input = sc.generate();
    for variant in [
        Variant::CupcE,
        Variant::Baseline1,
        Variant::Baseline2,
        Variant::Reversed,
    ] {
        let ooc = tiny_windows(AdjMode::Auto);
        let tag = format!("fam{}", cupc::service::job::variant_tag(variant));
        let (skels, _, _) = run_sharded(&sc, variant, 2, ooc.clone(), &tag);
        let single = {
            let cfg = cfg_with(&sc, variant, ooc).with_threads(1);
            cupc::skeleton::run(&input.corr, input.n, input.m, &cfg).unwrap()
        };
        for (rank, skel) in skels.iter().enumerate() {
            assert_eq!(
                fingerprint(skel),
                fingerprint(&single),
                "{variant:?}: rank {rank} diverged"
            );
        }
    }
}

/// At `oocore-2k` scale, `AdjMode::Auto` must actually pick the sparse
/// representation after level 0 — and still match the forced-dense run
/// bitwise. Release-build only (2k variables across two full runs is
/// debug-prohibitive).
#[cfg(not(debug_assertions))]
#[test]
fn oocore_2k_auto_goes_sparse_and_matches_dense() {
    let sc = scenario("oocore-2k");
    let input = sc.generate();
    let auto_cfg = cfg_with(&sc, Variant::CupcS, OocConfig::default());
    let auto = cupc::skeleton::run(&input.corr, input.n, input.m, &auto_cfg).unwrap();
    assert_eq!(
        auto.ooc.adjacency, "sparse",
        "level-0 survivor density must trip the auto threshold at n=2048"
    );
    let dense_cfg = cfg_with(
        &sc,
        Variant::CupcS,
        OocConfig {
            adjacency: AdjMode::Dense,
            ..OocConfig::default()
        },
    );
    let dense = cupc::skeleton::run(&input.corr, input.n, input.m, &dense_cfg).unwrap();
    assert_eq!(fingerprint(&auto), fingerprint(&dense));
}

/// The bounded-memory acceptance run: a synthetic sparse n=10k skeleton
/// completes with the sparse adjacency selected and the streamed buffer
/// inside its documented budget. Release-build only.
#[cfg(not(debug_assertions))]
#[test]
fn oocore_10k_completes_within_the_window_budget() {
    let sc = scenario("oocore-10k");
    let input = sc.generate();
    let mut cfg = cfg_with(&sc, Variant::CupcS, OocConfig::default());
    cfg.threads = cupc::skeleton::available_threads();
    let skel = cupc::skeleton::run(&input.corr, input.n, input.m, &cfg).unwrap();
    assert_eq!(skel.ooc.adjacency, "sparse");
    let bound = cfg.ooc.window_runs as u64 * std::mem::size_of::<Run>() as u64;
    assert!(
        skel.ooc.peak_window_bytes <= bound,
        "peak {} exceeds the documented bound {bound}",
        skel.ooc.peak_window_bytes
    );
    // the run actually pruned: an ER graph at ~2 expected neighbors per
    // node keeps far fewer than the complete graph's 50M edges
    let edges = skel.graph.n_edges();
    assert!(
        edges < 100_000,
        "level loop failed to prune: {edges} edges survived"
    );
    assert!(
        skel.levels.len() <= 3,
        "max_level=2 must cap the loop, got {} levels",
        skel.levels.len()
    );
}
