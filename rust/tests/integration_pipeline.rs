//! Cross-module integration tests on the native path: recovery quality,
//! order-independence properties, CSV round trips, orientation
//! correctness on textbook structures.

use cupc::data::csv::{parse_csv, write_csv};
use cupc::metrics::{shd, skeleton_metrics};
use cupc::prelude::*;
use cupc::sim::{dag::WeightedDag, datasets, sem};
use cupc::util::rng::Pcg;

#[test]
fn recovery_improves_with_samples() {
    let dag = WeightedDag::random_er(40, 0.08, &mut Pcg::seeded(70));
    let truth = dag.skeleton_dense();
    let mut f1s = Vec::new();
    for m in [50usize, 500, 5000] {
        let data = sem::sample(&dag, m, &mut Pcg::seeded(71));
        let res = cupc::api::pc_stable_data(&data, &Config::default()).unwrap();
        let metr = skeleton_metrics(&res.skeleton.graph.snapshot(), &truth, 40);
        f1s.push(metr.f1);
    }
    assert!(
        f1s[2] > f1s[0],
        "more samples must improve recovery: {f1s:?}"
    );
    assert!(f1s[2] > 0.9, "5000 samples should recover well: {f1s:?}");
}

#[test]
fn permutation_invariance_of_skeleton() {
    // relabeling variables must relabel the skeleton identically
    // (PC-stable order-independence, the paper's §2.4 argument).
    let n = 25;
    let dag = WeightedDag::random_er(n, 0.12, &mut Pcg::seeded(80));
    let data = sem::sample(&dag, 600, &mut Pcg::seeded(81));
    let res = cupc::api::pc_stable_data(&data, &Config::default()).unwrap();
    let skel = res.skeleton.graph.snapshot();

    // permute columns of the data
    let mut perm: Vec<usize> = (0..n).collect();
    Pcg::seeded(82).shuffle(&mut perm);
    let mut xp = vec![0.0; data.m * n];
    for s in 0..data.m {
        for v in 0..n {
            xp[s * n + perm[v]] = data.at(s, v);
        }
    }
    let datap = cupc::stats::corr::DataMatrix::new(xp, data.m, n);
    let resp = cupc::api::pc_stable_data(&datap, &Config::default()).unwrap();
    let skelp = resp.skeleton.graph.snapshot();

    for i in 0..n {
        for j in 0..n {
            assert_eq!(
                skel[i * n + j],
                skelp[perm[i] * n + perm[j]],
                "edge ({i},{j}) not permutation-consistent"
            );
        }
    }
}

#[test]
fn csv_roundtrip_preserves_result() {
    let ds = datasets::generate_er(20, 150, 0.15, 5);
    let tmp = std::env::temp_dir().join("cupc_it_roundtrip.csv");
    write_csv(&tmp, &ds.data).unwrap();
    let text = std::fs::read_to_string(&tmp).unwrap();
    let (data2, _) = parse_csv(&text).unwrap();
    std::fs::remove_file(&tmp).ok();

    let r1 = cupc::api::pc_stable_data(&ds.data, &Config::default()).unwrap();
    let r2 = cupc::api::pc_stable_data(&data2, &Config::default()).unwrap();
    // CSV writer uses full f64 formatting; skeletons must coincide
    assert_eq!(r1.skeleton.graph.snapshot(), r2.skeleton.graph.snapshot());
    assert!(r1.cpdag.same_as(&r2.cpdag));
}

#[test]
fn alpha_monotonicity() {
    // stricter alpha (smaller) removes more edges (higher tau).
    let ds = datasets::generate_er(30, 200, 0.15, 6);
    let run_alpha = |alpha: f64| {
        let cfg = Config {
            alpha,
            ..Config::default()
        };
        cupc::api::pc_stable_data(&ds.data, &cfg)
            .unwrap()
            .skeleton
            .graph
            .n_edges()
    };
    let strict = run_alpha(0.001);
    let loose = run_alpha(0.1);
    assert!(
        strict <= loose,
        "alpha=0.001 gives {strict} edges > alpha=0.1 {loose}"
    );
}

#[test]
fn max_level_caps_the_loop() {
    let ds = datasets::generate_er(40, 300, 0.2, 7);
    let cfg = Config {
        max_level: Some(1),
        ..Config::default()
    };
    let res = cupc::api::pc_stable_data(&ds.data, &cfg).unwrap();
    assert!(res.skeleton.levels.len() <= 2, "levels 0 and 1 only");
}

#[test]
fn collider_and_chain_textbook_orientations() {
    // two components: collider 0→2←1 and chain 3→4→5
    let dag = WeightedDag {
        n: 6,
        parents: vec![
            vec![],
            vec![],
            vec![(0, 0.8), (1, 0.8)],
            vec![],
            vec![(3, 0.9)],
            vec![(4, 0.9)],
        ],
    };
    let data = sem::sample(&dag, 8000, &mut Pcg::seeded(90));
    let res = cupc::api::pc_stable_data(&data, &Config::default()).unwrap();
    // collider oriented
    assert!(res.cpdag.is_directed(0, 2));
    assert!(res.cpdag.is_directed(1, 2));
    // chain undirected (Markov-equivalent both ways)
    assert!(res.cpdag.is_undirected(3, 4));
    assert!(res.cpdag.is_undirected(4, 5));
    // no cross-component edges
    for i in 0..3 {
        for j in 3..6 {
            assert!(!res.cpdag.adjacent(i, j));
        }
    }
}

#[test]
fn shd_zero_between_identical_runs() {
    let ds = datasets::generate_er(15, 300, 0.2, 8);
    let a = cupc::api::pc_stable_data(&ds.data, &Config::default()).unwrap();
    let b = cupc::api::pc_stable_data(&ds.data, &Config::default()).unwrap();
    assert_eq!(shd(&a.cpdag, &b.cpdag), 0);
}

#[test]
fn sepsets_are_separating_in_truth_for_strong_signal() {
    // with plenty of samples, any stored sepset must d-separate in the
    // estimated graph's terms: spot-check that removed pairs are indeed
    // non-adjacent and their sepset members were neighbors at removal.
    let ds = datasets::generate_er(25, 3000, 0.1, 9);
    let res = cupc::api::pc_stable_data(&ds.data, &Config::default()).unwrap();
    for ((i, j), s) in res.skeleton.sepsets.sorted_entries() {
        assert!(!res.skeleton.graph.has_edge(i as usize, j as usize));
        for v in s {
            assert!(v as usize != i as usize && v as usize != j as usize);
        }
    }
}
