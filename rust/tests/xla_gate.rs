//! The XLA engine gate: selecting `EngineKind::Xla` must degrade to a
//! *descriptive error* — never a panic — both when the binary was built
//! without the `xla` cargo feature and when the feature is on but the
//! artifacts directory is missing. This is the contract `Config` users
//! (CLI, experiments, library callers) rely on.

use cupc::prelude::*;
use cupc::runtime::engine_from_config;
use std::path::PathBuf;

fn xla_config() -> Config {
    Config {
        engine: EngineKind::Xla,
        artifacts_dir: PathBuf::from("/nonexistent/cupc-artifacts"),
        ..Config::default()
    }
}

#[test]
fn xla_engine_construction_errors_descriptively() {
    let err = match engine_from_config(&xla_config()) {
        Ok(_) => panic!("EngineKind::Xla must not succeed without artifacts/runtime"),
        Err(e) => e,
    };
    let msg = format!("{err:#}").to_lowercase();
    // feature off → points at the missing `xla` feature; feature on →
    // points at the missing manifest. Either way the message is actionable.
    assert!(
        msg.contains("xla") || msg.contains("manifest"),
        "unhelpful error: {msg}"
    );
}

#[test]
fn full_run_with_xla_engine_is_an_error_not_a_panic() {
    // A 3-variable chain correlation; the run must fail cleanly at engine
    // construction, before any CI test executes.
    let corr = vec![1.0, 0.8, 0.56, 0.8, 1.0, 0.7, 0.56, 0.7, 1.0];
    for variant in [Variant::CupcE, Variant::CupcS, Variant::Baseline1, Variant::Baseline2] {
        let cfg = Config {
            variant,
            ..xla_config()
        };
        let res = cupc::api::pc_stable_corr(&corr, 3, 500, &cfg);
        assert!(res.is_err(), "{variant:?} must propagate the engine error");
    }
}

#[test]
fn native_engine_is_always_available() {
    let cfg = Config::default();
    assert_eq!(cfg.engine, EngineKind::Native);
    assert!(engine_from_config(&cfg).is_ok());
}
