//! Cross-engine conformance suite — the paper's central correctness
//! claim (cuPC §2.4, PC-stable order-independence) as an executable gate:
//! over the whole scenario grid, every registered schedule (the
//! `skeleton::family` registry, `ALL_VARIANTS`) must produce
//!
//! * bit-identical skeletons,
//! * identical sepset *key* sets (one entry per removed edge — the keys
//!   are schedule-invariant; the stored set contents are whichever
//!   separating set a schedule finds first, which is legitimately
//!   schedule-dependent — Colombo & Maathuis §4),
//! * semantically valid sepsets (every stored S really separates its
//!   pair at the level-|S| threshold),
//! * identical CPDAGs under `OrientRule::Majority` (the majority census
//!   makes orientation schedule-invariant too),
//! * identical per-level `removed` / `edges_after` counts and level
//!   counts. (Per-level `tests` counts are *not* asserted equal across
//!   schedules: the number of CI tests actually evaluated is exactly the
//!   schedule trade-off the paper studies — γ = 1 vs γ = ∞ in Fig. 5 —
//!   so only determinism of `tests` per variant is checked.)
//!
//! The precision contract behind every bitwise assertion here — where
//! f32 vs f64 is used, which knobs are guaranteed bit-neutral (threads,
//! windows, shards, CI-test kernels), and how `tools/margin_oracle.py`
//! justifies the f32 packing — is written down in `docs/NUMERICS.md`.

use cupc::api::pc_stable_corr;
use cupc::sim::scenarios::{default_grid, Scenario, ScenarioInput, ALL_VARIANTS};
use cupc::skeleton::{OrientRule, Variant};
use cupc::stats::fisher::tau;
use cupc::stats::pcorr::{ci_statistic, CiWorkspace, Corr};

fn run_variant(input: &ScenarioInput, sc: &Scenario, v: Variant) -> cupc::api::PcResult {
    let cfg = sc.config(v);
    pc_stable_corr(&input.corr, input.n, input.m, &cfg)
        .unwrap_or_else(|e| panic!("{} / {v:?} failed: {e:#}", sc.name))
}

#[test]
fn grid_is_large_enough() {
    assert!(default_grid().len() >= 8);
}

/// The headline conformance sweep: every grid point × every variant.
#[test]
fn all_variants_conform_on_the_full_grid() {
    for sc in default_grid() {
        let input = sc.generate();
        let reference = run_variant(&input, &sc, ALL_VARIANTS[0]);
        let ref_skel = reference.skeleton.graph.snapshot();
        let ref_keys: Vec<(u32, u32)> = reference
            .skeleton
            .sepsets
            .sorted_entries()
            .into_iter()
            .map(|(k, _)| k)
            .collect();
        let ref_levels: Vec<(usize, usize, usize)> = reference
            .skeleton
            .levels
            .iter()
            .map(|l| (l.level, l.removed, l.edges_after))
            .collect();

        for &v in &ALL_VARIANTS[1..] {
            let res = run_variant(&input, &sc, v);

            // 1. bit-identical skeleton
            assert_eq!(
                res.skeleton.graph.snapshot(),
                ref_skel,
                "{}: {v:?} skeleton differs from {:?}",
                sc.name,
                ALL_VARIANTS[0]
            );

            // 2. identical sepset keys (same removed pairs)
            let keys: Vec<(u32, u32)> = res
                .skeleton
                .sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect();
            assert_eq!(keys, ref_keys, "{}: {v:?} sepset keys differ", sc.name);

            // 3. schedule-invariant CPDAG under the majority rule
            assert!(
                res.cpdag.same_as(&reference.cpdag),
                "{}: {v:?} majority-CPDAG differs: {:?} vs {:?}",
                sc.name,
                res.cpdag,
                reference.cpdag
            );

            // 3b. the orientation phase's deterministic bookkeeping is
            // schedule-invariant too: the census runs over the (shared)
            // final skeleton to the (shared) deepest level, so triple,
            // census-test and Meek-sweep counts must all agree
            assert_eq!(
                res.orient, reference.orient,
                "{}: {v:?} orientation stats differ",
                sc.name
            );

            // 4. per-level removal bookkeeping matches
            let levels: Vec<(usize, usize, usize)> = res
                .skeleton
                .levels
                .iter()
                .map(|l| (l.level, l.removed, l.edges_after))
                .collect();
            assert_eq!(
                levels, ref_levels,
                "{}: {v:?} per-level removed/edges_after differ",
                sc.name
            );
        }
    }
}

/// The parallel pack→evaluate→apply pipeline must be bit-identical to a
/// single-worker run: same skeleton, same sepset *entries* (contents,
/// not just keys — ordered apply preserves the first-win winner), and
/// the same per-level removed / edges_after *and* tests counts. This is
/// the order-independence gate extended to thread counts; it must never
/// weaken.
#[test]
fn batched_schedules_are_thread_count_invariant() {
    for sc in default_grid() {
        let input = sc.generate();
        for v in [Variant::CupcE, Variant::CupcS, Variant::Reversed] {
            let run_threads = |threads: usize| {
                let mut cfg = sc.config(v);
                cfg.threads = threads;
                pc_stable_corr(&input.corr, input.n, input.m, &cfg)
                    .unwrap_or_else(|e| panic!("{} / {v:?} t={threads} failed: {e:#}", sc.name))
            };
            let r1 = run_threads(1);
            let r4 = run_threads(4);
            assert_eq!(
                r1.skeleton.graph.snapshot(),
                r4.skeleton.graph.snapshot(),
                "{}: {v:?} skeleton differs between threads=1 and threads=4",
                sc.name
            );
            assert_eq!(
                r1.skeleton.sepsets.sorted_entries(),
                r4.skeleton.sepsets.sorted_entries(),
                "{}: {v:?} sepset entries differ between threads=1 and threads=4",
                sc.name
            );
            let levels = |r: &cupc::api::PcResult| -> Vec<(usize, u64, usize, usize)> {
                r.skeleton
                    .levels
                    .iter()
                    .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                    .collect()
            };
            assert_eq!(
                levels(&r1),
                levels(&r4),
                "{}: {v:?} per-level stats differ between threads=1 and threads=4",
                sc.name
            );
            assert!(
                r1.cpdag.same_as(&r4.cpdag),
                "{}: {v:?} CPDAG differs between threads=1 and threads=4",
                sc.name
            );
        }
    }
}

/// The kernel seam's bitwise gate (`docs/NUMERICS.md`): the blocked
/// lane-major kernel preserves the scalar kernel's per-lane f64
/// operation order, so across the FULL grid both kernels must produce
/// bit-identical skeletons, sepset *entries*, per-level stats
/// (including test counts) and CPDAGs — `assert_eq`, no tolerance.
/// Runs at `threads = 2` so the pooled path's per-worker engines are
/// constructed from `Config.kernel` too. CI re-runs the whole grid
/// under `CUPC_KERNEL=scalar` and `=blocked` (the `kernel-conformance`
/// job) to cover the env-selection path end to end.
#[test]
fn scalar_and_blocked_kernels_conform_bitwise_on_the_full_grid() {
    use cupc::stats::kernels::KernelKind;
    for sc in default_grid() {
        let input = sc.generate();
        for v in [Variant::CupcE, Variant::CupcS, Variant::Reversed] {
            let run_kernel = |kernel: KernelKind| {
                let mut cfg = sc.config(v);
                cfg.kernel = kernel;
                cfg.threads = 2;
                pc_stable_corr(&input.corr, input.n, input.m, &cfg).unwrap_or_else(|e| {
                    panic!("{} / {v:?} kernel={} failed: {e:#}", sc.name, kernel.name())
                })
            };
            let rs = run_kernel(KernelKind::Scalar);
            let rb = run_kernel(KernelKind::Blocked);
            assert_eq!(
                rs.skeleton.graph.snapshot(),
                rb.skeleton.graph.snapshot(),
                "{}: {v:?} skeleton differs between kernels",
                sc.name
            );
            assert_eq!(
                rs.skeleton.sepsets.sorted_entries(),
                rb.skeleton.sepsets.sorted_entries(),
                "{}: {v:?} sepset entries differ between kernels",
                sc.name
            );
            let levels = |r: &cupc::api::PcResult| -> Vec<(usize, u64, usize, usize)> {
                r.skeleton
                    .levels
                    .iter()
                    .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                    .collect()
            };
            assert_eq!(
                levels(&rs),
                levels(&rb),
                "{}: {v:?} per-level stats differ between kernels",
                sc.name
            );
            assert!(
                rs.cpdag.same_as(&rb.cpdag),
                "{}: {v:?} CPDAG differs between kernels",
                sc.name
            );
            assert_eq!(
                rs.orient, rb.orient,
                "{}: {v:?} orientation stats differ between kernels",
                sc.name
            );
        }
    }
}

/// The orientation pipeline's determinism gate: CPDAGs — under BOTH the
/// first-sepset rule and the majority census — and the orientation
/// stats (triples, census tests, Meek sweeps) are bit-identical for
/// `threads = 1` and `threads = 4` across the full grid. This covers
/// the sharded v-structure enumeration, the batched census, and the
/// snapshot-per-sweep Meek fixpoint; it must never weaken.
#[test]
fn orientation_is_thread_count_invariant() {
    for sc in default_grid() {
        let input = sc.generate();
        for orient in [OrientRule::Standard, OrientRule::Majority] {
            let run_at = |threads: usize| {
                let mut cfg = sc.config(Variant::CupcS);
                cfg.orient = orient;
                cfg.threads = threads;
                pc_stable_corr(&input.corr, input.n, input.m, &cfg).unwrap_or_else(|e| {
                    panic!("{} / {orient:?} t={threads} failed: {e:#}", sc.name)
                })
            };
            let r1 = run_at(1);
            let r4 = run_at(4);
            assert!(
                r1.cpdag.same_as(&r4.cpdag),
                "{}: {orient:?} CPDAG differs between threads=1 and threads=4",
                sc.name
            );
            assert_eq!(
                r1.orient, r4.orient,
                "{}: {orient:?} orientation stats differ between threads",
                sc.name
            );
            if orient == OrientRule::Standard {
                assert_eq!(
                    r1.orient.census_tests, 0,
                    "{}: first-sepset orientation runs no census",
                    sc.name
                );
            }
        }
    }
}

/// Every sepset key corresponds exactly to a removed pair: keys are the
/// complement of the skeleton's edge set.
#[test]
fn sepset_keys_are_exactly_the_removed_pairs() {
    let grid = default_grid();
    for sc in &grid[..3] {
        let input = sc.generate();
        for v in [Variant::Serial, Variant::CupcE, Variant::CupcS] {
            let res = run_variant(&input, sc, v);
            let snap = res.skeleton.graph.snapshot();
            let mut expected: Vec<(u32, u32)> = Vec::new();
            for i in 0..input.n {
                for j in (i + 1)..input.n {
                    if snap[i * input.n + j] == 0 {
                        expected.push((i as u32, j as u32));
                    }
                }
            }
            let keys: Vec<(u32, u32)> = res
                .skeleton
                .sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect();
            assert_eq!(keys, expected, "{} / {v:?}", sc.name);
        }
    }
}

/// Semantic validity: each stored S really renders its pair independent
/// at the |S|-level threshold. (Checked through the f64 native CI path
/// with a small tolerance absorbing the f32 packing of the GPU-schedule
/// engines.)
#[test]
fn stored_sepsets_are_separating() {
    let grid = default_grid();
    for sc in &grid[..4] {
        let input = sc.generate();
        let view = Corr::new(&input.corr, input.n);
        // same bound as the engines so the checker can never lag the
        // skeleton phase's deepest reachable level
        let mut ws = CiWorkspace::new(cupc::skeleton::engine::NATIVE_MAX_LEVEL);
        for v in [Variant::Serial, Variant::CupcE, Variant::CupcS] {
            let res = run_variant(&input, sc, v);
            for ((i, j), s) in res.skeleton.sepsets.sorted_entries() {
                let ids: Vec<usize> = s.iter().map(|&x| x as usize).collect();
                let z = ci_statistic(&view, i as usize, j as usize, &ids, &mut ws);
                let t = tau(input.m, ids.len(), sc.alpha);
                assert!(
                    z <= t + 1e-4,
                    "{} / {v:?}: stored sepset {ids:?} does not separate ({i},{j}): z={z} tau={t}",
                    sc.name
                );
            }
        }
    }
}

/// Each variant is bit-deterministic run to run, including its CI-test
/// counts (the one per-level statistic that legitimately differs between
/// schedules must still be reproducible within a schedule).
#[test]
fn per_variant_determinism_including_test_counts() {
    let sc = &default_grid()[2];
    let input = sc.generate();
    for &v in &ALL_VARIANTS {
        let a = run_variant(&input, sc, v);
        let b = run_variant(&input, sc, v);
        assert_eq!(
            a.skeleton.graph.snapshot(),
            b.skeleton.graph.snapshot(),
            "{v:?} skeleton not deterministic"
        );
        assert!(a.cpdag.same_as(&b.cpdag), "{v:?} CPDAG not deterministic");
        let tests = |r: &cupc::api::PcResult| -> Vec<u64> {
            r.skeleton.levels.iter().map(|l| l.tests).collect()
        };
        // ParallelCpu's mid-level monitoring makes its test *counts*
        // scheduling-dependent (threads observe removals at different
        // times); every deterministic schedule must reproduce exactly.
        if v != Variant::ParallelCpu {
            assert_eq!(tests(&a), tests(&b), "{v:?} test counts not deterministic");
        }
        // level-0 exhaustively tests every pair under every schedule
        assert_eq!(
            a.skeleton.levels[0].tests,
            (input.n * (input.n - 1) / 2) as u64,
            "{v:?} level-0 test count"
        );
    }
}

/// The cuPC-E γ knob trades wasted tests for parallelism without moving
/// the result — the Fig. 5 baselines are the two extremes.
#[test]
fn gamma_extremes_conform_with_different_test_budgets() {
    let sc = &default_grid()[3];
    let input = sc.generate();
    let b1 = run_variant(&input, sc, Variant::Baseline1);
    let b2 = run_variant(&input, sc, Variant::Baseline2);
    assert_eq!(
        b1.skeleton.graph.snapshot(),
        b2.skeleton.graph.snapshot(),
        "γ=1 and γ=∞ must agree on the skeleton"
    );
    assert!(
        b2.skeleton.total_tests() >= b1.skeleton.total_tests(),
        "full fan-out cannot run fewer tests: {} vs {}",
        b2.skeleton.total_tests(),
        b1.skeleton.total_tests()
    );
}

/// The reversed-order family's efficiency claim (arxiv 2109.04626),
/// asserted rather than just logged: on every *dense* grid point it must
/// spend strictly fewer total CI tests than cuPC-E at the
/// paper-selected γ = 32, while producing the identical skeleton.
/// `tools/schedule_oracle.py` mirrors both schedules in f64 and predicts
/// reversed/cupc-e totals of 4456/11819 (dense-cap2), 6270/13460
/// (dense-a05-cap2) and 3818/7400 (dense-cap3) — strictly fewer on 3/3.
#[test]
fn reversed_order_spends_fewer_tests_than_cupc_e_on_dense_points() {
    let dense = ["dense-cap2", "dense-a05-cap2", "dense-cap3"];
    for name in dense {
        let sc = cupc::sim::scenarios::find(name).expect(name);
        let input = sc.generate();
        let e = run_variant(&input, &sc, Variant::CupcE);
        let r = run_variant(&input, &sc, Variant::Reversed);
        assert_eq!(
            r.skeleton.graph.snapshot(),
            e.skeleton.graph.snapshot(),
            "{name}: reversed skeleton differs from cuPC-E"
        );
        assert!(
            r.skeleton.total_tests() < e.skeleton.total_tests(),
            "{name}: reversed-order must prune cheaper than cuPC-E γ=32: {} vs {}",
            r.skeleton.total_tests(),
            e.skeleton.total_tests()
        );
        // level 0 is the shared exhaustive pair sweep; the savings come
        // from the deeper levels, where the descending windows hit the
        // separating sets sooner
        assert_eq!(r.skeleton.levels[0].tests, e.skeleton.levels[0].tests);
    }
}
