//! ParaLiNGAM conformance gate (ISSUE 10 acceptance criteria).
//!
//! The lingam grid points (`sim::scenarios::lingam_grid`) are seeds on
//! which exact-arithmetic DirectLiNGAM provably recovers the ground
//! truth with wide decision margins — certified offline by
//! `tools/lingam_oracle.py` (root-election gaps ≥ 1e-9, pruning
//! coefficients ≥ 0.01 from the 0.05 threshold). That headroom is what
//! lets this gate pin the oracle's causal orders as *exact* literals
//! and the recovered DAGs as *exactly* the ground truth, and then
//! demand bitwise-identical results across thread counts, both CI-test
//! kernels (which the causal-order family never touches), and
//! warm-vs-cold service caches on a manifest mixing PC and lingam jobs.

use cupc::api::OrderResult;
use cupc::family::FamilyId;
use cupc::service::{render_results, run_batch, BatchOptions, Cache, Manifest};
use cupc::sim::dag::WeightedDag;
use cupc::sim::scenarios::{lingam_grid, Scenario};
use cupc::skeleton::Config;
use cupc::stats::kernels::KernelKind;
use std::collections::BTreeSet;

/// The oracle's causal orders, pinned verbatim from the gated
/// `tools/lingam_oracle.py` run (LINGAM GRID SAFE).
const PINNED_ORDERS: [(&str, &[usize]); 3] = [
    ("lingam-uniform", &[3, 7, 8, 11, 0, 4, 9, 1, 2, 10, 5, 6]),
    ("lingam-laplace", &[3, 1, 6, 5, 2, 4, 7, 8, 0, 9]),
    ("lingam-grn", &[0, 1, 5, 12, 7, 4, 2, 11, 3, 9, 6, 8, 13, 10]),
];

fn pinned_order(name: &str) -> &'static [usize] {
    PINNED_ORDERS
        .iter()
        .find(|(n, _)| *n == name)
        .unwrap_or_else(|| panic!("no pinned order for {name} — update PINNED_ORDERS"))
        .1
}

fn truth_edges(dag: &WeightedDag) -> BTreeSet<(usize, usize)> {
    let mut out = BTreeSet::new();
    for (child, parents) in dag.parents.iter().enumerate() {
        for &(parent, _w) in parents {
            out.insert((parent as usize, child));
        }
    }
    out
}

fn run_point(sc: &Scenario, threads: usize, kernel: KernelKind) -> (WeightedDag, OrderResult) {
    let (dag, data) = sc.generate_data();
    let cfg = Config {
        threads,
        kernel,
        ..Config::default()
    };
    let res = cupc::lingam::run(&data, &cfg)
        .unwrap_or_else(|e| panic!("{}: lingam run failed: {e:#}", sc.name));
    (dag, res)
}

/// Every grid point recovers the oracle's exact causal order and the
/// exact ground-truth DAG (the margins certify exact recovery, so
/// anything else is an implementation divergence, not sampling noise).
#[test]
fn grid_points_recover_the_oracle_order_and_the_exact_truth_dag() {
    let grid = lingam_grid();
    assert_eq!(grid.len(), 3, "the gate must cover every lingam grid point");
    for sc in &grid {
        let (dag, res) = run_point(sc, 1, KernelKind::Scalar);
        assert_eq!(
            res.order,
            pinned_order(sc.name),
            "{}: causal order diverged from the pinned oracle",
            sc.name
        );
        let got: BTreeSet<(usize, usize)> =
            res.edges.iter().map(|&(i, j, _w)| (i, j)).collect();
        assert_eq!(
            got,
            truth_edges(&dag),
            "{}: pruned DAG must equal the ground truth exactly",
            sc.name
        );
        // round accounting: one root elected per round over a shrinking
        // active set of n, n-1, ..., 2 variables
        assert_eq!(res.rounds.len(), sc.n - 1, "{}", sc.name);
        for (r, ls) in res.rounds.iter().enumerate() {
            let k = sc.n - r;
            assert_eq!(ls.level, r, "{}", sc.name);
            assert_eq!(ls.tests, (k * (k - 1) / 2) as u64, "{}", sc.name);
            assert_eq!(ls.removed, 1, "{}", sc.name);
            assert_eq!(ls.edges_after, k - 1, "{}", sc.name);
        }
    }
}

/// Orders, edge weights (bitwise), and per-round stats must be
/// identical for threads ∈ {1, 4} crossed with both CI-test kernels —
/// the causal-order family rides the same executor but owns no
/// kernel-dependent arithmetic, so every cell of the cross must match
/// the (threads=1, scalar) reference exactly.
#[test]
fn results_are_bit_identical_across_threads_and_kernels() {
    for sc in &lingam_grid() {
        let (_, reference) = run_point(sc, 1, KernelKind::Scalar);
        let ref_bits: Vec<(usize, usize, u64)> = reference
            .edges
            .iter()
            .map(|&(i, j, w)| (i, j, w.to_bits()))
            .collect();
        for threads in [1usize, 4] {
            for kernel in [KernelKind::Scalar, KernelKind::Blocked] {
                let (_, res) = run_point(sc, threads, kernel);
                let tag = format!("{} threads={threads} kernel={kernel:?}", sc.name);
                assert_eq!(res.order, reference.order, "{tag}: order");
                let bits: Vec<(usize, usize, u64)> = res
                    .edges
                    .iter()
                    .map(|&(i, j, w)| (i, j, w.to_bits()))
                    .collect();
                assert_eq!(bits, ref_bits, "{tag}: edge weights must agree bitwise");
                let stats = |r: &OrderResult| -> Vec<(usize, u64, usize, usize)> {
                    r.rounds
                        .iter()
                        .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                        .collect()
                };
                assert_eq!(stats(&res), stats(&reference), "{tag}: per-round stats");
            }
        }
    }
}

/// A manifest mixing PC and lingam jobs runs through the unchanged
/// batch scheduler; the rendered results stream is byte-identical
/// between a cold and a warm pass over a shared `--cache-dir`, and the
/// lingam rows carry the DAG-adjacency shape (a non-empty `order`).
#[test]
fn mixed_manifest_is_byte_identical_warm_vs_cold() {
    let text = r#"{"jobs":[
        {"name": "lingam-uniform", "scenario": "lingam-uniform", "variant": "lingam"},
        {"name": "lingam-laplace", "scenario": "lingam-laplace", "variant": "paralingam"},
        {"name": "lingam-grn", "scenario": "lingam-grn", "variant": "lingam"},
        {"name": "pc-on-lingam-data", "scenario": "lingam-laplace", "variant": "cups"},
        {"name": "pc-sparse", "scenario": "sparse-a01", "variant": "cupe"}
    ]}"#;
    let manifest = Manifest::parse(text).unwrap();
    assert!(
        manifest.jobs.iter().any(|j| j.family == FamilyId::Lingam)
            && manifest.jobs.iter().any(|j| j.pc_variant().is_some()),
        "the gate must actually mix both engine kinds"
    );

    let dir = std::env::temp_dir().join(format!("cupc_lingam_conf_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = BatchOptions {
        job_threads: 2,
        threads: 4,
        cache_bytes: 64 << 20,
        cache_dir: Some(dir.clone()),
        disk_bytes: 64 << 20,
        ..BatchOptions::default()
    };
    let render = |cache: &Cache| {
        let out = run_batch(&manifest, &opts, cache).unwrap();
        render_results(&manifest.jobs, &out.reports)
    };
    // cold: nothing cached anywhere
    let cold = render(&Cache::new(64 << 20));
    // warm (memory): the same in-process cache serves every layer
    let warm_mem_cache = Cache::new(64 << 20);
    let first = render(&warm_mem_cache);
    let warm_mem = render(&warm_mem_cache);
    // warm (disk): a fresh in-process cache over the populated cache-dir
    let warm_disk = render(&Cache::new(64 << 20));
    assert_eq!(cold, first);
    assert_eq!(cold, warm_mem, "memory-warm results must be byte-identical");
    assert_eq!(cold, warm_disk, "disk-warm results must be byte-identical");

    for line in cold.lines() {
        let has_order = line.contains("\"order\":[");
        if line.contains("\"variant\":\"lingam\"") {
            assert!(has_order, "lingam rows carry the causal order: {line}");
        } else {
            assert!(!has_order, "PC rows must not grow an order field: {line}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same mixed manifest must be byte-identical across scheduler
/// widths too (threads 1 vs 4, job-threads 1 vs 2) — the acceptance
/// bar for "zero changes to the scheduler/budget layers".
#[test]
fn mixed_manifest_is_byte_identical_across_scheduler_widths() {
    let text = r#"{"jobs":[
        {"name": "lingam-uniform", "scenario": "lingam-uniform", "variant": "lingam"},
        {"name": "pc-sparse", "scenario": "sparse-a01", "variant": "cups"}
    ]}"#;
    let manifest = Manifest::parse(text).unwrap();
    let render = |job_threads: usize, threads: usize| {
        let opts = BatchOptions {
            job_threads,
            threads,
            cache_bytes: 64 << 20,
            ..BatchOptions::default()
        };
        let out = run_batch(&manifest, &opts, &Cache::new(64 << 20)).unwrap();
        render_results(&manifest.jobs, &out.reports)
    };
    let reference = render(1, 1);
    assert_eq!(reference, render(1, 4), "threads must not move a byte");
    assert_eq!(reference, render(2, 4), "job-threads must not move a byte");
}
