//! Seeded structured fuzzing for the untrusted-input surfaces, runnable
//! under plain `cargo test` (no external fuzzing toolchain).
//!
//! Three targets, all deterministic from one seed:
//!
//! * `util::json` — generated documents must survive a
//!   render → parse → re-render fixpoint; generated strings must survive
//!   parse → `escape` → reparse; byte-level mutations of valid
//!   documents must parse or error, never panic or abort;
//! * `service::DiskStore` — a bit-flip corpus over whole entry files:
//!   every single-bit corruption must read as a *miss* (and delete the
//!   entry), never a panic or a wrong payload, and the slot must be
//!   cleanly rewritable afterwards;
//! * `JobResultCore::from_bytes` — truncations and byte mutations of a
//!   valid encoding must decode to `Some(original)` or `None`, never
//!   panic.
//!
//! The seed defaults to a fixed constant so CI is reproducible; set
//! `CUPC_FUZZ_SEED` to explore. Any crash found by a sweep gets pinned
//! as a literal regression case in `regressions_stay_fixed`.

use cupc::service::{DiskStore, JobResultCore};
use cupc::util::json::{escape, Json};
use cupc::util::rng::Pcg;
use std::path::PathBuf;

const DEFAULT_SEED: u64 = 0x5eed_cafe;

fn fuzz_seed() -> u64 {
    std::env::var("CUPC_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

// ── structured JSON generation ──────────────────────────────────────

/// A random scalar-safe string: quotes, backslashes, control bytes,
/// multilingual plane and astral characters — everything `escape` and
/// the parser's surrogate-pair path must cope with.
fn gen_string(rng: &mut Pcg) -> String {
    let len = rng.below(12) as usize;
    let mut s = String::new();
    for _ in 0..len {
        s.push(match rng.below(8) {
            0 => '"',
            1 => '\\',
            2 => char::from_u32(rng.below(0x20) as u32).unwrap(),
            3 => '\u{1F600}', // astral: rendered via a surrogate pair in \u form
            4 => 'é',
            5 => '/',
            6 => char::from_u32(0x20 + rng.below(0x5e) as u32).unwrap(),
            _ => 'a',
        });
    }
    s
}

/// A random number that renders and reparses exactly: integers and
/// dyadic fractions are exact in f64 and in decimal, so the
/// render → parse fixpoint has no rounding escape hatch.
fn gen_number(rng: &mut Pcg) -> f64 {
    let int = rng.below(2_000_001) as f64 - 1_000_000.0;
    let frac = rng.below(256) as f64 / 256.0;
    if rng.bernoulli(0.5) {
        int
    } else {
        int + frac
    }
}

fn gen_value(rng: &mut Pcg, depth: usize) -> Json {
    let top = if depth == 0 { 4 } else { 6 };
    match rng.below(top) {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => Json::Num(gen_number(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => {
            let k = rng.below(4) as usize;
            Json::Arr((0..k).map(|_| gen_value(rng, depth - 1)).collect())
        }
        _ => {
            let k = rng.below(4) as usize;
            Json::Obj(
                (0..k)
                    .map(|i| (format!("k{i}-{}", escape(&gen_string(rng))), gen_value(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

/// Render a [`Json`] value back to text (the crate renders by hand at
/// each call site, so the fuzzer carries its own canonical renderer).
fn render(v: &Json) -> String {
    match v {
        Json::Null => "null".into(),
        Json::Bool(b) => b.to_string(),
        // Rust's f64 Display is shortest-round-trip and never produces
        // exponents for these magnitudes — valid JSON by construction
        Json::Num(x) => x.to_string(),
        Json::Str(s) => format!("\"{}\"", escape(s)),
        Json::Arr(items) => {
            let inner: Vec<String> = items.iter().map(render).collect();
            format!("[{}]", inner.join(","))
        }
        Json::Obj(kv) => {
            let inner: Vec<String> = kv
                .iter()
                .map(|(k, v)| format!("\"{}\":{}", escape(k), render(v)))
                .collect();
            format!("{{{}}}", inner.join(","))
        }
    }
}

/// Generated documents render → parse → re-render to a fixpoint. Object
/// keys here are made unique per container, so parse-order preservation
/// makes the fixpoint exact.
#[test]
fn generated_documents_roundtrip_exactly() {
    let mut rng = Pcg::seeded(fuzz_seed());
    for i in 0..500 {
        let v = gen_value(&mut rng, 4);
        let doc = render(&v);
        let parsed = Json::parse(&doc).unwrap_or_else(|e| panic!("iter {i}: {doc:?}: {e:#}"));
        assert_eq!(render(&parsed), doc, "iter {i}: fixpoint broke");
    }
}

/// parse → escape → reparse over generated strings (the satellite's
/// named target): escaping must be lossless and always reparseable.
#[test]
fn parse_escape_reparse_roundtrips() {
    let mut rng = Pcg::seeded(fuzz_seed() ^ 1);
    for i in 0..1000 {
        let s = gen_string(&mut rng);
        let doc = format!("\"{}\"", escape(&s));
        let parsed = Json::parse(&doc)
            .unwrap_or_else(|e| panic!("iter {i}: escape produced unparseable {doc:?}: {e:#}"));
        assert_eq!(parsed.as_str(), Some(s.as_str()), "iter {i}");
        let again = format!("\"{}\"", escape(parsed.as_str().unwrap()));
        assert_eq!(again, doc, "iter {i}: escape must be deterministic");
    }
}

/// Byte-level mutations of valid documents: the parser must return
/// (Ok or Err), never panic — the daemon feeds it raw network bytes.
#[test]
fn mutated_documents_never_panic_the_parser() {
    let mut rng = Pcg::seeded(fuzz_seed() ^ 2);
    for _ in 0..200 {
        let doc = render(&gen_value(&mut rng, 4));
        let mut bytes = doc.into_bytes();
        for _ in 0..1 + rng.below(4) {
            if bytes.is_empty() {
                break;
            }
            let at = rng.below(bytes.len() as u64) as usize;
            match rng.below(4) {
                0 => bytes[at] = rng.below(256) as u8,
                1 => bytes[at] ^= 1 << rng.below(8),
                2 => {
                    bytes.truncate(at);
                }
                _ => bytes.insert(at, rng.below(256) as u8),
            }
        }
        // lossy conversion mirrors what a UTF-8-validated network frame
        // could still smuggle through; outcome is unchecked — only
        // "no panic" is the property
        let _ = Json::parse(&String::from_utf8_lossy(&bytes));
    }
}

/// Crashes and near-misses found by past sweeps (plus the adversarial
/// corpus the daemon tests use), pinned as literals so they can never
/// regress silently.
#[test]
fn regressions_stay_fixed() {
    // nesting bomb: must error via the depth cap, not overflow the stack
    let bomb = "[".repeat(100_000);
    let corpus: [&str; 13] = [
        // unpaired/truncated surrogate escapes (would panic a naive
        // from_str_radix/from_u32 unwrap chain)
        r#""\uD83D""#,
        r#""\uDC00""#,
        r#""\u12"#,
        "\"\\u12é9\"",
        &bomb,
        // overflow-to-infinity numbers
        "1e999",
        r#"{"alpha":-1e999}"#,
        // scanner runs off a number into EOF
        "-",
        "1e",
        ".",
        // empty and lone tokens
        "",
        ",",
        "\"",
    ];
    for doc in corpus {
        assert!(
            Json::parse(doc).is_err(),
            "{:?} must error",
            &doc[..doc.len().min(40)]
        );
    }
}

// ── DiskStore bit-flip corpus ───────────────────────────────────────

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cupc_fuzz_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn toy_core() -> JobResultCore {
    use cupc::service::report::{LevelRow, OrientRow};
    JobResultCore {
        n: 5,
        m: 64,
        orient: OrientRow {
            triples: 4,
            census_tests: 9,
            meek_sweeps: 2,
        },
        levels: vec![LevelRow {
            level: 0,
            tests: 10,
            removed: 3,
            edges_after: 7,
        }],
        skeleton_edges: vec![(0, 1), (1, 2), (3, 4)],
        directed: vec![(0, 1), (3, 4)],
        undirected: vec![(1, 2)],
        order: vec![4, 0, 2, 1, 3],
    }
}

/// Every single-bit flip anywhere in a stored entry file — header or
/// payload — must read back as a miss that deletes the entry, after
/// which the slot is cleanly rewritable. Never a panic, never a wrong
/// payload. (The store's checksum covers the payload; the header fields
/// are each individually validated.)
#[test]
fn single_bit_flips_in_store_entries_are_always_a_miss() {
    let mut rng = Pcg::seeded(fuzz_seed() ^ 3);
    let dir = tmp_dir("bitflip");
    let store = DiskStore::open(&dir, 1 << 20).unwrap();
    let corr: Vec<f64> = (0..9).map(|i| (i as f64) / 8.0 - 0.5).collect();
    let core = toy_core();
    store.put_corr((11, 22), &corr);
    store.put_result((33, 44), &core);

    let entry_of = |prefix: &str| -> PathBuf {
        std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .find(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(prefix))
            })
            .unwrap_or_else(|| panic!("no {prefix} entry in {}", dir.display()))
    };

    // corr entries
    let path = entry_of("corr-");
    let pristine = std::fs::read(&path).unwrap();
    for at in 0..pristine.len() {
        let mut bad = pristine.clone();
        bad[at] ^= 1 << rng.below(8);
        std::fs::write(&path, &bad).unwrap();
        assert!(
            store.get_corr((11, 22), 9).is_none(),
            "byte {at}: a corrupted corr entry must miss"
        );
        assert!(!path.exists(), "byte {at}: the corrupt entry must be deleted");
        std::fs::write(&path, &pristine).unwrap();
    }
    assert_eq!(store.get_corr((11, 22), 9), Some(corr), "pristine bytes still hit");

    // result entries (exercises JobResultCore::from_bytes behind the
    // checksum as well — a flip can only reach it via a collision,
    // which a 128-bit checksum makes unobservable; the decode guard
    // still exists for key-collision shapes)
    let path = entry_of("res-");
    let pristine = std::fs::read(&path).unwrap();
    for at in 0..pristine.len() {
        let mut bad = pristine.clone();
        bad[at] ^= 1 << rng.below(8);
        std::fs::write(&path, &bad).unwrap();
        assert!(
            store.get_result((33, 44)).is_none(),
            "byte {at}: a corrupted result entry must miss"
        );
        assert!(!path.exists(), "byte {at}: the corrupt entry must be deleted");
        std::fs::write(&path, &pristine).unwrap();
    }
    assert_eq!(store.get_result((33, 44)).as_ref(), Some(&core));

    // truncations at every length, and trailing garbage
    for cut in 0..pristine.len() {
        std::fs::write(&path, &pristine[..cut]).unwrap();
        assert!(store.get_result((33, 44)).is_none(), "cut={cut}");
        std::fs::write(&path, &pristine).unwrap();
    }
    let mut long = pristine.clone();
    long.extend_from_slice(b"garbage");
    std::fs::write(&path, &long).unwrap();
    assert!(store.get_result((33, 44)).is_none(), "trailing garbage is a miss");

    // the slot recovers: recompute-and-store round-trips again
    store.put_result((33, 44), &core);
    assert_eq!(store.get_result((33, 44)).as_ref(), Some(&core));
    let _ = std::fs::remove_dir_all(&dir);
}

/// `JobResultCore::from_bytes` directly (no checksum shield): random
/// mutations and truncations of a valid encoding must return
/// `Some(original)` or `None` — never panic, never a huge allocation.
#[test]
fn result_codec_survives_mutation_fuzzing() {
    let mut rng = Pcg::seeded(fuzz_seed() ^ 4);
    let core = toy_core();
    let bytes = core.to_bytes();
    assert_eq!(JobResultCore::from_bytes(&bytes).as_ref(), Some(&core));
    for cut in 0..bytes.len() {
        assert!(
            JobResultCore::from_bytes(&bytes[..cut]).is_none(),
            "every truncation misses (cut={cut})"
        );
    }
    for i in 0..2000 {
        let mut bad = bytes.clone();
        for _ in 0..1 + rng.below(3) {
            let at = rng.below(bad.len() as u64) as usize;
            if rng.bernoulli(0.5) {
                bad[at] ^= 1 << rng.below(8);
            } else {
                bad[at] = rng.below(256) as u8;
            }
        }
        if let Some(decoded) = JobResultCore::from_bytes(&bad) {
            // a decode that succeeds must be internally consistent
            // enough to re-encode to the same bytes it decoded from
            assert_eq!(decoded.to_bytes(), bad, "iter {i}: decode/encode disagree");
        }
    }
}
