//! `cargo bench --bench fig8_cupcs_config` — Fig. 8: cuPC-S (θ, δ)
//! heat maps vs the selected cuPC-S-64-2.

mod common;
use cupc::experiments::fig8;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("fig8: {:?}", opts);
    let maps = fig8::run(&opts, Some(&["nci60", "dream5-insilico"]))?;
    fig8::print(&maps);
    Ok(())
}
