//! `cargo bench --bench fig9_sharing` — Fig. 9: conditional-set sharing
//! histogram at level 2 of DREAM5-Insilico (local vs global sharing).

mod common;
use cupc::experiments::fig9;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("fig9: {:?}", opts);
    let out = fig9::run(&opts)?;
    fig9::print(&out);
    Ok(())
}
