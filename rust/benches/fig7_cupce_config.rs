//! `cargo bench --bench fig7_cupce_config` — Fig. 7: cuPC-E (β, γ)
//! heat maps vs the selected cuPC-E-2-32 (sparse + dense datasets).

mod common;
use cupc::experiments::fig7;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("fig7: {:?}", opts);
    let maps = fig7::run(&opts, Some(&["nci60", "dream5-insilico"]))?;
    fig7::print(&maps);
    Ok(())
}
