//! `cargo bench --bench engines` — the tracked ns/test baseline for the
//! CI-test kernels (the promoted `micro` probe that used to hide in
//! `skeleton/engine.rs`), the scalar-vs-blocked kernel comparison
//! (ns/test per level for both `stats::kernels` paths, asserting
//! bitwise-identical output first), the dense vs sparse adjacency
//! store on a sparse ER skeleton (ns/test end to end, same result bit
//! for bit), the threads=1 vs threads=N speedup of the parallel
//! pack→evaluate→apply pipeline on the Table-2 minis, the orientation
//! pipeline (ns/triple for v-structures + Meek and ns/test for the
//! majority census, threads 1 vs N), the batch-runner throughput
//! (jobs/sec over the scenario grid at job-threads 1 vs N, cold cache
//! each rep), and the lingam engine family (ns per pairwise measure
//! sweep over the non-Gaussian lingam grid, threads 1 vs N).
//!
//! Writes `BENCH_engines.json` (override with `-- --out path`) so
//! packing/engine/scheduler changes have a tracked baseline to diff
//! against.
//!
//! Flags: `--reps R` (median of R, default 3), `--threads N` (parallel
//! run width, default all cores), `--seed S`, `--full` (all six minis
//! instead of the three fastest), `--out FILE`.

use cupc::experiments::median;
use cupc::family::FamilyId;
use cupc::service::{run_batch, BatchOptions, Cache, DataSource, JobSpec, Manifest};
use cupc::sim::batches::{random_batch, random_s_batch};
use cupc::sim::{datasets, scenarios};
use cupc::skeleton::engine::{CiEngine, NativeEngine};
use cupc::skeleton::{
    available_threads, run as run_skeleton, AdjMode, Config, EngineKind, OocConfig, OrientRule,
    Variant,
};
use cupc::stats::corr::correlation_matrix;
use cupc::util::cli::{bench_argv, Args};
use cupc::util::rng::Pcg;
use cupc::util::timer::{median_time, Timer};

struct KernelRow {
    kernel: &'static str,
    l: usize,
    batch: usize,
    ns_per_test: f64,
}

struct KernelCompareRow {
    op: &'static str,
    l: usize,
    batch: usize,
    ns_scalar: f64,
    ns_blocked: f64,
}

struct AdjacencyRow {
    adjacency: &'static str,
    n: usize,
    edges: usize,
    tests: u64,
    secs: f64,
}

struct PipelineRow {
    dataset: String,
    variant: &'static str,
    threads: usize,
    secs_t1: f64,
    secs_tn: f64,
}

struct BatchRow {
    jobs: usize,
    job_threads: usize,
    secs_jt1: f64,
    secs_jtn: f64,
}

struct LingamRow {
    scenario: &'static str,
    n: usize,
    m: usize,
    /// pairwise measure evaluations (Σ rounds.tests)
    sweeps: u64,
    edges: usize,
    secs_t1: f64,
    secs_tn: f64,
}

struct OrientRowBench {
    phase: &'static str,
    threads: usize,
    /// work units: unshielded triples (vstruct+meek) or census CI tests
    units: u64,
    unit: &'static str,
    secs_t1: f64,
    secs_tn: f64,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(bench_argv());
    let reps = args.get_usize("reps", 3)?;
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the default to the repo root where the baseline is tracked
    let default_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
    let out = args.get_or("out", default_out);
    let threads = args.get_usize("threads", available_threads())?;
    let mut rng = Pcg::seeded(args.get_u64("seed", 0)?);

    // ── kernel ns/test across levels and batch sizes ────────────────
    let mut kernels: Vec<KernelRow> = Vec::new();
    let mut engine = NativeEngine::new();
    {
        let c = vec![0.5f32; 1_000_000];
        let secs = median_time(1, reps, || {
            engine.level0(&c).unwrap();
        });
        kernels.push(KernelRow {
            kernel: "level0",
            l: 0,
            batch: c.len(),
            ns_per_test: secs * 1e9 / c.len() as f64,
        });
    }
    for l in 1..=8usize {
        for &b in &[256usize, 1024, 4096] {
            let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
            let secs = median_time(1, reps, || {
                engine.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
            });
            kernels.push(KernelRow {
                kernel: "ci_e",
                l,
                batch: b,
                ns_per_test: secs * 1e9 / b as f64,
            });
        }
        let k = engine.k();
        for &rows in &[8usize, 32, 128] {
            let (c_ij, m1, m2) = random_s_batch(&mut rng, rows, k, l);
            let valid = vec![k as u32; rows];
            let tests = rows * k;
            let secs = median_time(1, reps, || {
                engine.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid).unwrap();
            });
            kernels.push(KernelRow {
                kernel: "ci_s",
                l,
                batch: rows,
                ns_per_test: secs * 1e9 / tests as f64,
            });
        }
    }
    println!("== engine kernels: ns/test (median of {reps}) ==");
    println!("{:<8} {:>3} {:>7} {:>12}", "kernel", "l", "batch", "ns/test");
    for r in &kernels {
        println!("{:<8} {:>3} {:>7} {:>12.1}", r.kernel, r.l, r.batch, r.ns_per_test);
    }

    // ── scalar vs blocked kernel: ns/test, bitwise-checked first ────
    // Both paths must produce identical bits (the docs/NUMERICS.md
    // contract) — the assert runs before any timing so a divergence
    // can never hide behind a fast number.
    let mut kernel_compare: Vec<KernelCompareRow> = Vec::new();
    {
        use cupc::stats::kernels::KernelKind;
        let mut scalar = NativeEngine::with_kernel(KernelKind::Scalar);
        let mut blocked = NativeEngine::with_kernel(KernelKind::Blocked);
        for l in 1..=8usize {
            let b = 4096usize;
            let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
            let zs = scalar.ci_e(l, b, &c_ij, &m1, &m2)?;
            let zb = blocked.ci_e(l, b, &c_ij, &m1, &m2)?;
            assert_eq!(zs, zb, "kernels must agree bitwise (ci_e l={l})");
            let secs_scalar = median_time(1, reps, || {
                scalar.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
            });
            let secs_blocked = median_time(1, reps, || {
                blocked.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
            });
            kernel_compare.push(KernelCompareRow {
                op: "ci_e",
                l,
                batch: b,
                ns_scalar: secs_scalar * 1e9 / b as f64,
                ns_blocked: secs_blocked * 1e9 / b as f64,
            });
            let k = blocked.k();
            let rows = 128usize;
            let (c_ij, m1, m2) = random_s_batch(&mut rng, rows, k, l);
            let valid = vec![k as u32; rows];
            let zs = scalar.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid)?;
            let zb = blocked.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid)?;
            assert_eq!(zs, zb, "kernels must agree bitwise (ci_s l={l})");
            let tests = (rows * k) as f64;
            let secs_scalar = median_time(1, reps, || {
                scalar.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid).unwrap();
            });
            let secs_blocked = median_time(1, reps, || {
                blocked.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid).unwrap();
            });
            kernel_compare.push(KernelCompareRow {
                op: "ci_s",
                l,
                batch: rows,
                ns_scalar: secs_scalar * 1e9 / tests,
                ns_blocked: secs_blocked * 1e9 / tests,
            });
        }
    }
    println!("\n== scalar vs blocked kernels: ns/test (bitwise-identical output) ==");
    println!(
        "{:<6} {:>3} {:>7} {:>12} {:>12} {:>8}",
        "op", "l", "batch", "scalar", "blocked", "speedup"
    );
    for r in &kernel_compare {
        println!(
            "{:<6} {:>3} {:>7} {:>12.1} {:>12.1} {:>7.2}x",
            r.op,
            r.l,
            r.batch,
            r.ns_scalar,
            r.ns_blocked,
            r.ns_scalar / r.ns_blocked.max(1e-12)
        );
    }

    // ── dense vs sparse adjacency store on a sparse ER skeleton ─────
    // Both runs produce the bit-identical skeleton (gated by
    // tests/oocore_conformance.rs); this row tracks what the CSR store
    // costs/saves per CI test relative to the n×n bitset.
    let adjacency = {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "adjacency-bench",
            n: 1536,
            m: 256,
            topology: datasets::Topology::Er(4.0 / 1536.0),
            seed: 7002,
        });
        let corr = correlation_matrix(&ds.data, threads);
        let mut rows: Vec<AdjacencyRow> = Vec::new();
        for (label, mode) in [("dense", AdjMode::Dense), ("sparse", AdjMode::Sparse)] {
            let cfg = Config {
                variant: Variant::CupcS,
                engine: EngineKind::Native,
                threads,
                ooc: OocConfig { adjacency: mode, ..OocConfig::default() },
                ..Config::default()
            };
            let mut times = Vec::new();
            let mut tests = 0u64;
            let mut edges = 0usize;
            for _ in 0..reps.max(1) {
                let res = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg)?;
                assert_eq!(res.ooc.adjacency, label, "forced mode must be honored");
                tests = res.levels.iter().map(|l| l.tests).sum();
                edges = res.graph.n_edges();
                times.push(res.total_seconds());
            }
            rows.push(AdjacencyRow {
                adjacency: label,
                n: ds.data.n,
                edges,
                tests,
                secs: median(&times),
            });
        }
        println!("\n== adjacency store: dense vs sparse (n=1536 ER, cupc-s) ==");
        println!(
            "{:<8} {:>6} {:>8} {:>10} {:>10} {:>12}",
            "store", "n", "edges", "tests", "secs", "ns/test"
        );
        for r in &rows {
            println!(
                "{:<8} {:>6} {:>8} {:>10} {:>10.4} {:>12.1}",
                r.adjacency,
                r.n,
                r.edges,
                r.tests,
                r.secs,
                r.secs * 1e9 / r.tests.max(1) as f64
            );
        }
        rows
    };

    // ── pipeline speedup on the Table-2 minis ───────────────────────
    let names: Vec<&str> = if args.has_flag("full") {
        datasets::TABLE2_ORDER.to_vec()
    } else {
        vec!["nci60", "mcc", "br51"]
    };
    let mut pipeline: Vec<PipelineRow> = Vec::new();
    println!("\n== pipeline: threads=1 vs threads={threads} on the Table-2 minis ==");
    println!(
        "{:<24} {:<8} {:>10} {:>10} {:>8}",
        "dataset", "variant", "t1 (s)", "tN (s)", "speedup"
    );
    for base in &names {
        let name = format!("{base}-mini");
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, threads);
        for (vname, v) in [
            ("cupc-e", Variant::CupcE),
            ("cupc-s", Variant::CupcS),
            ("reversed", Variant::Reversed),
        ] {
            let time_with = |t: usize| -> anyhow::Result<f64> {
                let cfg = Config {
                    variant: v,
                    engine: EngineKind::Native,
                    threads: t,
                    ..Config::default()
                };
                let mut times = Vec::new();
                for _ in 0..reps.max(1) {
                    let res = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg)?;
                    times.push(res.total_seconds());
                }
                Ok(median(&times))
            };
            let secs_t1 = time_with(1)?;
            let secs_tn = time_with(threads)?;
            println!(
                "{:<24} {:<8} {:>10.4} {:>10.4} {:>7.2}x",
                name,
                vname,
                secs_t1,
                secs_tn,
                secs_t1 / secs_tn.max(1e-12)
            );
            pipeline.push(PipelineRow {
                dataset: name.clone(),
                variant: vname,
                threads,
                secs_t1,
                secs_tn,
            });
        }
    }

    // ── orientation pipeline: ns/triple and census ns/test ──────────
    use cupc::orient::{orient_majority_with, orient_with};
    use cupc::skeleton::pipeline::Executor;
    let orientation = {
        // a dense-ish ER workload so the triple/census windows really
        // shard (deterministic; independent of the kernel RNG above)
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "orient-bench",
            n: 72,
            m: 400,
            topology: datasets::Topology::Er(0.18),
            seed: 7001,
        });
        let corr = correlation_matrix(&ds.data, threads);
        let cfg = Config {
            variant: Variant::CupcS,
            engine: EngineKind::Native,
            threads,
            ..Config::default()
        };
        let skel = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg)?;
        let deepest = skel.levels.last().map(|l| l.level).unwrap_or(0);
        let time_orient = |t: usize| -> anyhow::Result<(f64, u64)> {
            let mut times = Vec::new();
            let mut triples = 0u64;
            for _ in 0..reps.max(1) {
                let mut exec = Executor::pool(t);
                let timer = Timer::start();
                let (_, stats) = orient_with(&mut exec, &skel.graph, &skel.sepsets)?;
                times.push(timer.elapsed_s());
                triples = stats.triples as u64;
            }
            Ok((median(&times), triples))
        };
        let time_census = |t: usize| -> anyhow::Result<(f64, u64)> {
            let mut times = Vec::new();
            let mut tests = 0u64;
            for _ in 0..reps.max(1) {
                let mut exec = Executor::pool(t);
                let timer = Timer::start();
                let (_, stats) = orient_majority_with(
                    &mut exec,
                    &skel.graph,
                    &corr,
                    ds.data.m,
                    cfg.alpha,
                    deepest,
                )?;
                times.push(timer.elapsed_s());
                tests = stats.census_tests;
            }
            Ok((median(&times), tests))
        };
        let (v1, triples) = time_orient(1)?;
        let (vn, _) = time_orient(threads)?;
        let (c1, census_tests) = time_census(1)?;
        let (cn, _) = time_census(threads)?;
        println!("\n== orientation: threads=1 vs threads={threads} (n=72 ER 0.18) ==");
        println!(
            "vstruct+meek    : {triples} triples, t1 {:.4}s tN {:.4}s ({:.2}x), {:.1} ns/triple",
            v1,
            vn,
            v1 / vn.max(1e-12),
            v1 * 1e9 / triples.max(1) as f64
        );
        println!(
            "majority census : {census_tests} tests, t1 {:.4}s tN {:.4}s ({:.2}x), {:.1} ns/test",
            c1,
            cn,
            c1 / cn.max(1e-12),
            c1 * 1e9 / census_tests.max(1) as f64
        );
        vec![
            OrientRowBench {
                phase: "vstruct_meek",
                threads,
                units: triples,
                unit: "triple",
                secs_t1: v1,
                secs_tn: vn,
            },
            OrientRowBench {
                phase: "majority_census",
                threads,
                units: census_tests,
                unit: "test",
                secs_t1: c1,
                secs_tn: cn,
            },
        ]
    };

    // ── batch-runner throughput on the scenario grid ────────────────
    let manifest = Manifest {
        jobs: scenarios::default_grid()
            .into_iter()
            .map(|sc| JobSpec {
                name: sc.name.to_string(),
                source: DataSource::Scenario(sc.name.to_string()),
                family: FamilyId::Pc(Variant::CupcS),
                alpha: sc.alpha,
                max_level: sc.max_level,
                corr: sc.corr,
                orient: OrientRule::Standard,
            })
            .collect(),
    };
    let batch_secs = |job_threads: usize| -> anyhow::Result<f64> {
        let mut times = Vec::new();
        for _ in 0..reps.max(1) {
            // a fresh cache each rep: this measures cold throughput
            let cache = Cache::new(256 << 20);
            let opts = BatchOptions {
                job_threads,
                threads,
                cache_bytes: 256 << 20,
                ..BatchOptions::default()
            };
            let t = Timer::start();
            run_batch(&manifest, &opts, &cache)?;
            times.push(t.elapsed_s());
        }
        Ok(median(&times))
    };
    let secs_jt1 = batch_secs(1)?;
    let secs_jtn = batch_secs(threads)?;
    let batch = BatchRow {
        jobs: manifest.jobs.len(),
        job_threads: threads,
        secs_jt1,
        secs_jtn,
    };
    println!(
        "\n== batch runner: {} scenario-grid jobs, job-threads 1 vs {} ==",
        batch.jobs, batch.job_threads
    );
    println!(
        "jt=1: {:.4}s ({:.1} jobs/s)   jt={}: {:.4}s ({:.1} jobs/s)   speedup {:.2}x",
        secs_jt1,
        batch.jobs as f64 / secs_jt1.max(1e-12),
        batch.job_threads,
        secs_jtn,
        batch.jobs as f64 / secs_jtn.max(1e-12),
        secs_jt1 / secs_jtn.max(1e-12)
    );

    // ── lingam: ns per pairwise measure sweep, threads 1 vs N ───────
    // The causal-order engine's hot spot is the O(k²) pairwise-measure
    // sweep each root-finding round; per-sweep cost is the number the
    // registry's first non-PC family is tracked by. The t1/tN results
    // must agree bitwise (the family's determinism contract) — asserted
    // before any timing.
    let mut lingam: Vec<LingamRow> = Vec::new();
    println!("\n== lingam: ns/measure-sweep, threads=1 vs threads={threads} ==");
    println!(
        "{:<16} {:>4} {:>6} {:>8} {:>6} {:>10} {:>10} {:>12} {:>8}",
        "scenario", "n", "m", "sweeps", "edges", "t1 (s)", "tN (s)", "ns/sweep", "speedup"
    );
    for sc in scenarios::lingam_grid() {
        let (_, data) = sc.generate_data();
        let run_with = |t: usize| -> anyhow::Result<(f64, cupc::api::OrderResult)> {
            let cfg = Config {
                threads: t,
                ..Config::default()
            };
            let mut times = Vec::new();
            let mut last = None;
            for _ in 0..reps.max(1) {
                let res = cupc::lingam::run(&data, &cfg)?;
                times.push(res.seconds);
                last = Some(res);
            }
            Ok((median(&times), last.unwrap()))
        };
        let (secs_t1, r1) = run_with(1)?;
        let (secs_tn, rn) = run_with(threads)?;
        assert_eq!(r1.order, rn.order, "{}: order must be thread-invariant", sc.name);
        let w1: Vec<u64> = r1.edges.iter().map(|e| e.2.to_bits()).collect();
        let wn: Vec<u64> = rn.edges.iter().map(|e| e.2.to_bits()).collect();
        assert_eq!(w1, wn, "{}: edge weights must agree bitwise", sc.name);
        let sweeps: u64 = r1.rounds.iter().map(|r| r.tests).sum();
        println!(
            "{:<16} {:>4} {:>6} {:>8} {:>6} {:>10.4} {:>10.4} {:>12.1} {:>7.2}x",
            sc.name,
            sc.n,
            sc.m,
            sweeps,
            r1.edges.len(),
            secs_t1,
            secs_tn,
            secs_t1 * 1e9 / sweeps.max(1) as f64,
            secs_t1 / secs_tn.max(1e-12)
        );
        lingam.push(LingamRow {
            scenario: sc.name,
            n: sc.n,
            m: sc.m,
            sweeps,
            edges: r1.edges.len(),
            secs_t1,
            secs_tn,
        });
    }

    write_json(
        &out,
        reps,
        threads,
        &kernels,
        &kernel_compare,
        &adjacency,
        &pipeline,
        &orientation,
        &batch,
        &lingam,
    )?;
    println!("\nwrote {out}");
    Ok(())
}

/// Hand-rolled JSON (serde is unavailable offline); schema is consumed
/// by humans and diff tools only.
#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    reps: usize,
    threads: usize,
    kernels: &[KernelRow],
    kernel_compare: &[KernelCompareRow],
    adjacency: &[AdjacencyRow],
    pipeline: &[PipelineRow],
    orientation: &[OrientRowBench],
    batch: &BatchRow,
    lingam: &[LingamRow],
) -> anyhow::Result<()> {
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"schema\": \"cupc-bench-engines/v6\",\n");
    j.push_str(&format!("  \"reps\": {reps},\n"));
    j.push_str(&format!("  \"threads\": {threads},\n"));
    j.push_str("  \"kernels\": [\n");
    for (i, r) in kernels.iter().enumerate() {
        let sep = if i + 1 < kernels.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"l\": {}, \"batch\": {}, \"ns_per_test\": {:.2}}}{sep}\n",
            r.kernel, r.l, r.batch, r.ns_per_test
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"kernel_compare\": [\n");
    for (i, r) in kernel_compare.iter().enumerate() {
        let sep = if i + 1 < kernel_compare.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"op\": \"{}\", \"l\": {}, \"batch\": {}, \"ns_scalar\": {:.2}, \
             \"ns_blocked\": {:.2}, \"speedup\": {:.3}}}{sep}\n",
            r.op,
            r.l,
            r.batch,
            r.ns_scalar,
            r.ns_blocked,
            r.ns_scalar / r.ns_blocked.max(1e-12)
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"adjacency\": [\n");
    for (i, r) in adjacency.iter().enumerate() {
        let sep = if i + 1 < adjacency.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"adjacency\": \"{}\", \"n\": {}, \"edges\": {}, \"tests\": {}, \
             \"seconds\": {:.6}, \"ns_per_test\": {:.2}}}{sep}\n",
            r.adjacency,
            r.n,
            r.edges,
            r.tests,
            r.secs,
            r.secs * 1e9 / r.tests.max(1) as f64
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"pipeline\": [\n");
    for (i, r) in pipeline.iter().enumerate() {
        let sep = if i + 1 < pipeline.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"variant\": \"{}\", \"threads\": {}, \
             \"seconds_threads1\": {:.6}, \"seconds_threadsN\": {:.6}, \"speedup\": {:.3}}}{sep}\n",
            r.dataset,
            r.variant,
            r.threads,
            r.secs_t1,
            r.secs_tn,
            r.secs_t1 / r.secs_tn.max(1e-12)
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"orientation\": [\n");
    for (i, r) in orientation.iter().enumerate() {
        let sep = if i + 1 < orientation.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"phase\": \"{}\", \"threads\": {}, \"units\": {}, \"unit\": \"{}\", \
             \"seconds_threads1\": {:.6}, \"seconds_threadsN\": {:.6}, \
             \"ns_per_unit_t1\": {:.2}, \"speedup\": {:.3}}}{sep}\n",
            r.phase,
            r.threads,
            r.units,
            r.unit,
            r.secs_t1,
            r.secs_tn,
            r.secs_t1 * 1e9 / r.units.max(1) as f64,
            r.secs_t1 / r.secs_tn.max(1e-12)
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"batch\": {{\"jobs\": {}, \"job_threads\": {}, \
         \"seconds_jobthreads1\": {:.6}, \"seconds_jobthreadsN\": {:.6}, \
         \"jobs_per_sec_jt1\": {:.3}, \"jobs_per_sec_jtN\": {:.3}, \"speedup\": {:.3}}}\n",
        batch.jobs,
        batch.job_threads,
        batch.secs_jt1,
        batch.secs_jtn,
        batch.jobs as f64 / batch.secs_jt1.max(1e-12),
        batch.jobs as f64 / batch.secs_jtn.max(1e-12),
        batch.secs_jt1 / batch.secs_jtn.max(1e-12)
    ));
    j.push_str("  ,\"lingam\": [\n");
    for (i, r) in lingam.iter().enumerate() {
        let sep = if i + 1 < lingam.len() { "," } else { "" };
        j.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"n\": {}, \"m\": {}, \"sweeps\": {}, \"edges\": {}, \
             \"seconds_threads1\": {:.6}, \"seconds_threadsN\": {:.6}, \
             \"ns_per_sweep_t1\": {:.2}, \"speedup\": {:.3}}}{sep}\n",
            r.scenario,
            r.n,
            r.m,
            r.sweeps,
            r.edges,
            r.secs_t1,
            r.secs_tn,
            r.secs_t1 * 1e9 / r.sweeps.max(1) as f64,
            r.secs_t1 / r.secs_tn.max(1e-12)
        ));
    }
    j.push_str("  ]\n");
    j.push_str("}\n");
    std::fs::write(path, j)?;
    Ok(())
}
