//! `cargo bench --bench fig5_baselines` — Fig. 5: cuPC-E / cuPC-S vs
//! the two baseline GPU schedules.

mod common;
use cupc::experiments::fig5;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("fig5: {:?}", opts);
    let rows = fig5::run(&opts)?;
    fig5::print(&rows);
    Ok(())
}
