//! Shared bench plumbing: flag parsing for `cargo bench -- --scale ...`.
//! (criterion is unavailable offline; each bench is a harness=false main
//! that regenerates one paper table/figure via cupc::experiments.)
//!
//! All argv access goes through [`cupc::util::cli::bench_argv`], which
//! strips the `--bench` flag cargo injects when dispatching bench
//! binaries — parsing raw `std::env::args` here used to misparse
//! `cargo bench -- --graphs N` invocations.

use cupc::experiments::{ExpOpts, Scale};
use cupc::skeleton::EngineKind;
use cupc::util::cli::{bench_argv, Args};
use std::path::PathBuf;

pub fn opts_from_env() -> ExpOpts {
    let args = Args::parse(bench_argv());
    let scale = match args.get_or("scale", "small").as_str() {
        "paper" => Scale::Paper,
        _ => Scale::Small,
    };
    let engine = match args.get_or("engine", "native").as_str() {
        "xla" => EngineKind::Xla,
        _ => EngineKind::Native,
    };
    ExpOpts {
        scale,
        engine,
        // bench argv comes from the developer's own command line, so a
        // malformed value may terminate the bench — but through the
        // getter's named error, not a parser panic
        reps: args
            .get_usize("reps", 1)
            .unwrap_or_else(|e| panic!("{e:#}")),
        artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
    }
}

#[allow(dead_code)]
pub fn graphs_from_env(default: usize) -> usize {
    Args::parse(bench_argv())
        .get_usize("graphs", default)
        .unwrap_or_else(|e| panic!("{e:#}"))
}
