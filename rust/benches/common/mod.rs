//! Shared bench plumbing: flag parsing for `cargo bench -- --scale ...`.
//! (criterion is unavailable offline; each bench is a harness=false main
//! that regenerates one paper table/figure via cupc::experiments.)

use cupc::experiments::{ExpOpts, Scale};
use cupc::skeleton::EngineKind;
use cupc::util::cli::Args;
use std::path::PathBuf;

pub fn opts_from_env() -> ExpOpts {
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench") // cargo bench appends this
        .collect();
    let args = Args::parse(argv);
    let scale = match args.get_or("scale", "small").as_str() {
        "paper" => Scale::Paper,
        _ => Scale::Small,
    };
    let engine = match args.get_or("engine", "native").as_str() {
        "xla" => EngineKind::Xla,
        _ => EngineKind::Native,
    };
    ExpOpts {
        scale,
        engine,
        reps: args.get_usize("reps", 1),
        artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
    }
}

#[allow(dead_code)]
pub fn graphs_from_env(default: usize) -> usize {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    Args::parse(argv).get_usize("graphs", default)
}
