//! `cargo bench --bench fig10_scalability` — Fig. 10: runtime vs number
//! of variables (a), sample size (b) and graph density (c); 10 random
//! graphs per point, box-plot quartiles.

mod common;
use cupc::experiments::fig10::{self, Sweep};

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    let graphs = common::graphs_from_env(10);
    eprintln!("fig10: {:?} graphs/point={graphs}", opts);
    for sweep in [Sweep::N, Sweep::M, Sweep::D] {
        let points = fig10::run(&opts, sweep, graphs)?;
        fig10::print(&points, sweep);
    }
    Ok(())
}
