//! `cargo bench --bench fig6_levels` — Fig. 6: % of runtime per level.

mod common;
use cupc::experiments::fig6;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("fig6: {:?}", opts);
    let rows = fig6::run(&opts)?;
    fig6::print(&rows);
    Ok(())
}
