//! `cargo bench --bench table2` — regenerates Table 2 (runtimes +
//! speedups of serial / parallel-CPU / cuPC-E / cuPC-S).

mod common;
use cupc::experiments::table2;

fn main() -> anyhow::Result<()> {
    let opts = common::opts_from_env();
    eprintln!("table2: {:?}", opts);
    let rows = table2::run(&opts)?;
    table2::print(&rows);
    Ok(())
}
