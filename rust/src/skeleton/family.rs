//! The PC-family *implementation* table: one row per skeleton schedule
//! carrying its run function and (for batched schedules) its
//! [`RoundSchedule`] factory, so `skeleton::run` and the shard workers
//! dispatch on data instead of matching exhaustively on [`Variant`].
//!
//! Identity metadata — canonical name, aliases, the stable cache tag —
//! lives in the top-level [`crate::family`] registry, which spans both
//! engine kinds (PC schedules and causal-order engines). Adding a PC
//! family is: write the leaf module, append one [`FamilyInfo`] row
//! here, and one [`EngineFamily`](crate::family::EngineFamily) row
//! there; everything else — CLI parsing, manifest parsing, cache keys,
//! report labels — picks it up. The registry tests (here and in
//! `crate::family`) enforce the invariants a new row must keep.
//!
//! ```
//! use cupc::skeleton::{family, Variant};
//!
//! // every variant has exactly one implementation row
//! let info = family::of(Variant::CupcE);
//! assert!(info.deterministic_tests);
//! assert!(info.schedule.is_some());
//!
//! // spellings resolve through the top-level registry
//! assert_eq!(Variant::parse("CUPS"), Some(Variant::CupcS));
//! assert_eq!(Variant::parse("no-such-schedule"), None);
//! ```
//!
//! [`RoundSchedule`]: super::schedule::RoundSchedule

use super::schedule::RoundSchedule;
use super::{Config, SkeletonResult, Variant};
use anyhow::Result;

/// Whole-run entry point of a family (every leaf module exports one).
pub type RunFn = fn(&[f64], usize, usize, &Config) -> Result<SkeletonResult>;

/// Factory for a family's [`RoundSchedule`], for callers that need to
/// drive the level loop themselves (the `cupc shard` workers, which run
/// the schedule through `run_rounds_sharded`). `None` for the
/// coarse-grained families, which have no batched schedule to shard.
pub type ScheduleFn = fn(&Config) -> Box<dyn RoundSchedule>;

/// One registered PC algorithm family (implementation columns only —
/// see the module doc for where the identity columns live).
pub struct FamilyInfo {
    pub variant: Variant,
    /// Whether per-level `tests` counts are bit-reproducible for any
    /// thread count (true for every pipeline-batched schedule and the
    /// serial reference; false for the racy `parcpu`, whose skeleton is
    /// still exact but whose counts are scheduling-dependent).
    pub deterministic_tests: bool,
    pub run: RunFn,
    /// Batched-schedule factory, or `None` for whole-run-only families
    /// (those cannot run under `cupc shard`). Baseline rows bake in
    /// their γ/β overrides so the factory *is* the family, not merely
    /// its module.
    pub schedule: Option<ScheduleFn>,
}

/// Every PC family, in the same order as the top-level registry's PC
/// rows (tags 0..6 there; enforced by
/// `family::tests::pc_rows_mirror_the_skeleton_registry`).
pub const FAMILIES: &[FamilyInfo] = &[
    FamilyInfo {
        variant: Variant::Serial,
        deterministic_tests: true,
        run: super::serial::run,
        schedule: None,
    },
    FamilyInfo {
        variant: Variant::ParallelCpu,
        deterministic_tests: false,
        run: super::parallel_cpu::run,
        schedule: None,
    },
    FamilyInfo {
        variant: Variant::CupcE,
        deterministic_tests: true,
        run: super::gpu_e::run,
        schedule: Some(|cfg| Box::new(super::gpu_e::ESchedule::new(cfg))),
    },
    FamilyInfo {
        variant: Variant::CupcS,
        deterministic_tests: true,
        run: super::gpu_s::run,
        schedule: Some(|cfg| Box::new(super::gpu_s::SSchedule::new(cfg))),
    },
    FamilyInfo {
        variant: Variant::Baseline1,
        deterministic_tests: true,
        run: super::baseline1::run,
        schedule: Some(|cfg| {
            Box::new(super::gpu_e::ESchedule::new(&Config {
                gamma: 1,
                beta: 1,
                ..cfg.clone()
            }))
        }),
    },
    FamilyInfo {
        variant: Variant::Baseline2,
        deterministic_tests: true,
        run: super::baseline2::run,
        schedule: Some(|cfg| {
            Box::new(super::gpu_e::ESchedule::new(&Config {
                gamma: usize::MAX / 2,
                beta: 1,
                ..cfg.clone()
            }))
        }),
    },
    FamilyInfo {
        variant: Variant::Reversed,
        deterministic_tests: true,
        run: super::reversed::run,
        schedule: Some(|_| Box::new(super::reversed::ReversedSchedule::new())),
    },
];

/// The implementation row for a variant. Every `Variant` has exactly
/// one row (enforced by `registry_covers_every_variant`), so this never
/// panics on a constructed `Variant`.
pub fn of(v: Variant) -> &'static FamilyInfo {
    FAMILIES
        .iter()
        .find(|f| f.variant == v)
        .unwrap_or_else(|| panic!("variant {v:?} is not registered in family::FAMILIES"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_variant() {
        // `of` panics if a variant is missing; enumerate them all so
        // adding an enum arm without a registry row fails here.
        for v in [
            Variant::Serial,
            Variant::ParallelCpu,
            Variant::CupcE,
            Variant::CupcS,
            Variant::Baseline1,
            Variant::Baseline2,
            Variant::Reversed,
        ] {
            assert_eq!(of(v).variant, v);
        }
    }

    #[test]
    fn variants_are_unique() {
        for (i, a) in FAMILIES.iter().enumerate() {
            for b in &FAMILIES[i + 1..] {
                assert_ne!(a.variant, b.variant, "duplicate variant row");
            }
        }
    }

    #[test]
    fn schedule_factories_cover_exactly_the_batched_families() {
        for f in FAMILIES {
            let coarse = matches!(f.variant, Variant::Serial | Variant::ParallelCpu);
            assert_eq!(
                f.schedule.is_none(),
                coarse,
                "{:?}: schedule factory presence",
                f.variant
            );
            if let Some(make) = f.schedule {
                // the factory must build without touching the config's
                // thread/engine knobs (workers own those)
                let sched = make(&Config::default());
                assert!(!sched.label().is_empty(), "{:?}", f.variant);
            }
        }
    }
}
