//! The algorithm-family registry: one table describing every skeleton
//! schedule the crate ships, so the layers above `skeleton/` dispatch on
//! data instead of matching exhaustively on [`Variant`].
//!
//! Adding a family is now: write the leaf module (a [`RoundSchedule`]
//! implementation for batched schedules, or a whole-run function for
//! coarse-grained ones), append one [`FamilyInfo`] row here with a fresh
//! `tag`, and everything else — CLI parsing, manifest parsing, cache
//! keys, report labels, `skeleton::run` dispatch — picks it up. The
//! registry tests below enforce the invariants a new row must keep
//! (unique names, aliases and tags; parse/name roundtrip).
//!
//! ```
//! use cupc::skeleton::{family, Variant};
//!
//! // any registered alias resolves, case-insensitively
//! assert_eq!(family::parse("CUPS"), Some(Variant::CupcS));
//! assert_eq!(family::parse("reversed"), Some(Variant::Reversed));
//! assert_eq!(family::parse("no-such-schedule"), None);
//!
//! // and every variant has exactly one registry row of stable metadata
//! let info = family::of(Variant::CupcE);
//! assert_eq!(info.name, "cupc-e");
//! assert!(info.deterministic_tests);
//! assert_eq!(family::FAMILIES.len(), 7);
//! ```
//!
//! [`RoundSchedule`]: super::schedule::RoundSchedule

use super::schedule::RoundSchedule;
use super::{Config, SkeletonResult, Variant};
use anyhow::Result;

/// Whole-run entry point of a family (every leaf module exports one).
pub type RunFn = fn(&[f64], usize, usize, &Config) -> Result<SkeletonResult>;

/// Factory for a family's [`RoundSchedule`], for callers that need to
/// drive the level loop themselves (the `cupc shard` workers, which run
/// the schedule through `run_rounds_sharded`). `None` for the
/// coarse-grained families, which have no batched schedule to shard.
pub type ScheduleFn = fn(&Config) -> Box<dyn RoundSchedule>;

/// One registered algorithm family.
pub struct FamilyInfo {
    pub variant: Variant,
    /// Canonical CLI/report spelling.
    pub name: &'static str,
    /// Accepted `Variant::parse` spellings (lowercase; include `name`).
    pub aliases: &'static [&'static str],
    /// Stable tag for content hashing — cache keys depend on it, so a
    /// tag is **never renumbered or reused**; new families append.
    pub tag: u8,
    /// Whether per-level `tests` counts are bit-reproducible for any
    /// thread count (true for every pipeline-batched schedule and the
    /// serial reference; false for the racy `parcpu`, whose skeleton is
    /// still exact but whose counts are scheduling-dependent).
    pub deterministic_tests: bool,
    pub run: RunFn,
    /// Batched-schedule factory, or `None` for whole-run-only families
    /// (those cannot run under `cupc shard`). Baseline rows bake in
    /// their γ/β overrides so the factory *is* the family, not merely
    /// its module.
    pub schedule: Option<ScheduleFn>,
}

/// Every family, in tag order. Appending here is the single
/// registration step for a new schedule.
pub const FAMILIES: &[FamilyInfo] = &[
    FamilyInfo {
        variant: Variant::Serial,
        name: "serial",
        aliases: &["serial", "stable", "stable.fast"],
        tag: 0,
        deterministic_tests: true,
        run: super::serial::run,
        schedule: None,
    },
    FamilyInfo {
        variant: Variant::ParallelCpu,
        name: "parcpu",
        aliases: &["parcpu", "parallel-cpu", "parallel-pc"],
        tag: 1,
        deterministic_tests: false,
        run: super::parallel_cpu::run,
        schedule: None,
    },
    FamilyInfo {
        variant: Variant::CupcE,
        name: "cupc-e",
        aliases: &["cupe", "cupc-e", "e"],
        tag: 2,
        deterministic_tests: true,
        run: super::gpu_e::run,
        schedule: Some(|cfg| Box::new(super::gpu_e::ESchedule::new(cfg))),
    },
    FamilyInfo {
        variant: Variant::CupcS,
        name: "cupc-s",
        aliases: &["cups", "cupc-s", "s"],
        tag: 3,
        deterministic_tests: true,
        run: super::gpu_s::run,
        schedule: Some(|cfg| Box::new(super::gpu_s::SSchedule::new(cfg))),
    },
    FamilyInfo {
        variant: Variant::Baseline1,
        name: "baseline1",
        aliases: &["baseline1", "b1"],
        tag: 4,
        deterministic_tests: true,
        run: super::baseline1::run,
        schedule: Some(|cfg| {
            Box::new(super::gpu_e::ESchedule::new(&Config {
                gamma: 1,
                beta: 1,
                ..cfg.clone()
            }))
        }),
    },
    FamilyInfo {
        variant: Variant::Baseline2,
        name: "baseline2",
        aliases: &["baseline2", "b2"],
        tag: 5,
        deterministic_tests: true,
        run: super::baseline2::run,
        schedule: Some(|cfg| {
            Box::new(super::gpu_e::ESchedule::new(&Config {
                gamma: usize::MAX / 2,
                beta: 1,
                ..cfg.clone()
            }))
        }),
    },
    FamilyInfo {
        variant: Variant::Reversed,
        name: "reversed",
        aliases: &["reversed", "reversed-order", "rop"],
        tag: 6,
        deterministic_tests: true,
        run: super::reversed::run,
        schedule: Some(|_| Box::new(super::reversed::ReversedSchedule::new())),
    },
];

/// The registry row for a variant. Every `Variant` has exactly one row
/// (enforced by `registry_covers_every_variant`), so this never panics
/// on a constructed `Variant`.
pub fn of(v: Variant) -> &'static FamilyInfo {
    FAMILIES
        .iter()
        .find(|f| f.variant == v)
        .unwrap_or_else(|| panic!("variant {v:?} is not registered in family::FAMILIES"))
}

/// Parse a CLI/manifest spelling (case-insensitive) against every
/// family's alias list.
pub fn parse(s: &str) -> Option<Variant> {
    let lower = s.to_ascii_lowercase();
    FAMILIES
        .iter()
        .find(|f| f.aliases.contains(&lower.as_str()))
        .map(|f| f.variant)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_variant() {
        // `of` panics if a variant is missing; enumerate them all so
        // adding an enum arm without a registry row fails here.
        for v in [
            Variant::Serial,
            Variant::ParallelCpu,
            Variant::CupcE,
            Variant::CupcS,
            Variant::Baseline1,
            Variant::Baseline2,
            Variant::Reversed,
        ] {
            assert_eq!(of(v).variant, v);
        }
    }

    #[test]
    fn names_aliases_and_tags_are_unique() {
        let mut names: Vec<&str> = FAMILIES.iter().map(|f| f.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), FAMILIES.len(), "duplicate canonical name");

        let mut aliases: Vec<&str> = FAMILIES.iter().flat_map(|f| f.aliases.iter().copied()).collect();
        let n_aliases = aliases.len();
        aliases.sort_unstable();
        aliases.dedup();
        assert_eq!(aliases.len(), n_aliases, "an alias maps to two families");

        let mut tags: Vec<u8> = FAMILIES.iter().map(|f| f.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), FAMILIES.len(), "duplicate cache-key tag");
    }

    #[test]
    fn canonical_name_is_an_alias_and_roundtrips() {
        for f in FAMILIES {
            assert!(
                f.aliases.contains(&f.name),
                "{}: canonical name must parse",
                f.name
            );
            assert_eq!(parse(f.name), Some(f.variant));
            assert_eq!(parse(&f.name.to_ascii_uppercase()), Some(f.variant));
        }
        assert_eq!(parse("nope"), None);
    }

    #[test]
    fn schedule_factories_cover_exactly_the_batched_families() {
        for f in FAMILIES {
            let coarse = matches!(f.variant, Variant::Serial | Variant::ParallelCpu);
            assert_eq!(
                f.schedule.is_none(),
                coarse,
                "{}: schedule factory presence",
                f.name
            );
            if let Some(make) = f.schedule {
                // the factory must build without touching the config's
                // thread/engine knobs (workers own those)
                let sched = make(&Config::default());
                assert!(!sched.label().is_empty(), "{}", f.name);
            }
        }
    }

    #[test]
    fn aliases_are_lowercase() {
        for f in FAMILIES {
            for a in f.aliases {
                assert_eq!(*a, a.to_ascii_lowercase(), "{}: alias {a:?}", f.name);
            }
        }
    }
}
