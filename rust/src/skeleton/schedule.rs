//! The algorithm-family seam: a batched skeleton schedule is a
//! [`RoundSchedule`] strategy plugged into one generic level-loop driver,
//! not a hand-copied level loop per variant.
//!
//! The driver ([`run_rounds`] / [`run_rounds_with_engine`]) owns
//! everything PC-stable requires to stay order-independent: the
//! level-synchronous frame (one frozen `G'` snapshot per level, removals
//! applied between rounds), the level-0 pair sweep, the between-level
//! [`WidthPolicy`](super::WidthPolicy) re-lease point, the stop rule and
//! the per-level bookkeeping. A schedule only decides *which CI tests
//! run when*:
//!
//! * [`begin_level`](RoundSchedule::begin_level) — build the level's task
//!   list from the frozen snapshot (per-edge cursors, per-row cursors,
//!   any ordering the family wants);
//! * [`list_round`](RoundSchedule::list_round) — stage 1: emit the
//!   round's live combination windows as [`Run`]s in the schedule's
//!   canonical order;
//! * [`eval_shard`](RoundSchedule::eval_shard) — stage 2 worker body:
//!   pack a shard of those windows and evaluate it on a [`CiEngine`],
//!   returning the independence candidates plus the shard's test count.
//!
//! Because evaluation is pure and the driver applies candidates in
//! canonical slot order (stage 3), every schedule implemented on this
//! trait is bit-deterministic and thread-count invariant *by
//! construction* — the property `tests/conformance_engines.rs` gates.
//!
//! Implementations: [`gpu_e`](super::gpu_e) (cuPC-E and, through its γ
//! knob, the two Fig. 5 baselines), [`gpu_s`](super::gpu_s) (cuPC-S),
//! and [`reversed`](super::reversed) (reversed-order pruning,
//! arxiv 2109.04626). The coarse-grained families
//! ([`serial`](super::serial), [`parallel_cpu`](super::parallel_cpu))
//! predate the batch engines and stay whole-run functions; every family,
//! fine or coarse, is registered in [`family::FAMILIES`](super::family)
//! so no layer outside `skeleton/` matches on [`Variant`](super::Variant)
//! internals.

use super::batch::{Corr32, EBatch, Removals};
use super::comb::{n_sets_edge, CombRangeSkip};
use super::engine::CiEngine;
use super::pipeline::{use_pool, Executor, Run};
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

/// The frozen per-level state every stage reads: the compacted snapshot
/// `G'`, the live adjacency (mutated only between rounds, in stage 3),
/// the f32-packed correlations, the level and its threshold.
pub struct LevelCtx<'a> {
    pub comp: &'a CompactAdj,
    pub graph: &'a AdjMatrix,
    pub corr32: &'a Corr32,
    pub l: usize,
    pub taul: f64,
}

/// A batched skeleton schedule: level iteration stays with the driver,
/// window enumeration and candidate-set construction live here. `Sync`
/// because stage 2 shares the schedule immutably across worker threads.
pub trait RoundSchedule: Sync {
    /// Short name for verbose per-level progress lines.
    fn label(&self) -> &'static str;

    /// Rebuild the schedule's task list from the level's frozen
    /// snapshot. Called once per level, before any round.
    fn begin_level(&mut self, ctx: &LevelCtx<'_>);

    /// True when round `round` is past the schedule's last window (the
    /// driver also stops early when a round lists no live runs).
    fn rounds_done(&self, round: u64) -> bool;

    /// Stage 1 (serial): append round `round`'s live windows to `runs`
    /// in the schedule's canonical order. The concatenation of the runs
    /// *is* the round's canonical slot order for the apply stage.
    fn list_round(&self, ctx: &LevelCtx<'_>, round: u64, runs: &mut Vec<Run>);

    /// Stage 2 (parallel worker body): pack + evaluate one shard of the
    /// round's windows; return the independence candidates (canonical
    /// slot order) and the number of CI tests the shard evaluated. Must
    /// be pure with respect to shared state (it may read the frozen
    /// graph).
    fn eval_shard(
        &self,
        ctx: &LevelCtx<'_>,
        shard: &[Run],
        engine: &mut dyn CiEngine,
    ) -> Result<(Removals, u64)>;
}

/// Drive a full skeleton run for `sched`, pool-or-single like every
/// batched family: pooled native workers when the config allows
/// ([`use_pool`]), otherwise the identical pipeline inline on the
/// configured engine.
pub fn run_rounds(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    if use_pool(cfg) {
        run_impl(corr, n, m, cfg, sched, &mut Executor::Pool { threads: cfg.threads })
    } else {
        let mut engine = crate::runtime::engine_from_config(cfg)?;
        run_impl(corr, n, m, cfg, sched, &mut Executor::Single(engine.as_mut()))
    }
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// driver inline — results are bit-identical to the pool path.
pub fn run_rounds_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    run_impl(corr, n, m, cfg, sched, &mut Executor::Single(engine))
}

fn run_impl(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
    exec: &mut Executor<'_>,
) -> Result<SkeletonResult> {
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let corr32 = Corr32::from_f64(corr, n);
    let mut levels = Vec::new();

    levels.push(exec.run_level0(corr, n, m, cfg, &graph, &sepsets)?);

    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        // between-level re-lease point: a hooked job asks its width
        // policy (e.g. the batch scheduler's elastic lease) how wide to
        // run this level — absorbing workers other jobs released. Width
        // never changes results (ordered apply), only wall-clock time.
        if let Some(hook) = &cfg.width_hook {
            exec.set_width(hook.0.width_for_level(l));
        }
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l, taul };

        sched.begin_level(&ctx);

        let mut tests = 0u64;
        let mut removed = 0usize;
        let mut runs: Vec<Run> = Vec::new();
        let mut round = 0u64;
        while !sched.rounds_done(round) {
            // stage 1 (serial): the round's live windows in the
            // schedule's canonical order; the graph is frozen until the
            // apply stage
            runs.clear();
            sched.list_round(&ctx, round, &mut runs);
            if runs.is_empty() {
                break; // every unexhausted window belongs to a dead task
            }

            // stage 2 (parallel): pack + evaluate, engines per shard;
            // only independence candidates come back (dependent
            // verdicts are no-ops and are dropped with the gather)
            let sched_ref: &dyn RoundSchedule = &*sched;
            let shard_results = exec.run_sharded(&runs, |shard, engine| {
                sched_ref.eval_shard(&ctx, shard, engine)
            })?;

            // stage 3 (serial): everything in flight lands in canonical
            // slot order before the next round
            for (candidates, shard_tests) in &shard_results {
                tests += shard_tests;
                removed += candidates.apply(&graph, &sepsets);
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[{}] level {l}: {tests} tests, removed {removed}, {} edges left",
                sched.label(),
                graph.n_edges()
            );
        }
        l += 1;
    }

    Ok(SkeletonResult { graph, sepsets, levels })
}

/// One live edge's combination cursor within a level — the per-edge task
/// shape shared by cuPC-E, the Fig. 5 baselines and the reversed-order
/// schedule.
pub struct EdgeTask {
    pub i: u32,
    pub j: u32,
    /// position of j inside row i of G'
    pub p: u32,
    /// n'_i
    pub row_len: u32,
    /// C(n'_i − 1, ℓ)
    pub total: u64,
}

/// Build the level's edge-task list from `G'` (ordered pairs, row-major —
/// the same visit order as the CUDA grid) and return it with the largest
/// per-edge set count.
pub fn build_edge_tasks(ctx: &LevelCtx<'_>) -> (Vec<EdgeTask>, u64) {
    let (comp, l) = (ctx.comp, ctx.l);
    let mut tasks: Vec<EdgeTask> = Vec::new();
    for i in 0..comp.n() {
        let row = comp.row(i);
        let nr = row.len();
        if nr < l + 1 {
            continue; // §4.1 case I
        }
        let total = n_sets_edge(nr, l);
        if total == 0 {
            continue;
        }
        for (p, &j) in row.iter().enumerate() {
            tasks.push(EdgeTask {
                i: i as u32,
                j,
                p: p as u32,
                row_len: nr as u32,
                total,
            });
        }
    }
    let max_total = tasks.iter().map(|e| e.total).max().unwrap_or(0);
    (tasks, max_total)
}

/// Worker body shared by the per-edge schedules: pack a shard of
/// combination windows into engine-capacity [`EBatch`]es, evaluate them,
/// and keep only the independence candidates (canonical slot order).
/// Every slot of every run is evaluated, so the shard's test count is
/// its slot count.
pub fn eval_edge_shard(
    tasks: &[EdgeTask],
    ctx: &LevelCtx<'_>,
    shard: &[Run],
    engine: &mut dyn CiEngine,
) -> Result<(Removals, u64)> {
    let l = ctx.l;
    let cap = engine.batch_e().max(1);
    let mut out = Removals::new(l);
    let mut tests = 0u64;
    let mut batch = EBatch::new(l, cap);
    let mut ids = vec![0u32; l];
    for run in shard {
        let task = &tasks[run.task];
        let (i, j) = (task.i as usize, task.j as usize);
        let row = ctx.comp.row(i);
        tests += run.count;
        let mut combs =
            CombRangeSkip::new(task.row_len as usize, l, run.t0, run.count, task.p as usize);
        while let Some(sbuf) = combs.next_comb() {
            for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                *dst = row[pos as usize];
            }
            batch.push(ctx.corr32, i, j, &ids);
            if batch.len() >= cap {
                flush_e(&mut batch, engine, ctx.taul, &mut out)?;
            }
        }
    }
    if !batch.is_empty() {
        flush_e(&mut batch, engine, ctx.taul, &mut out)?;
    }
    Ok((out, tests))
}

fn flush_e(
    batch: &mut EBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    out: &mut Removals,
) -> Result<()> {
    let z = engine.ci_e(batch.l, batch.len(), &batch.c_ij, &batch.m1, &batch.m2)?;
    batch.drain_independent(&z, taul, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_fixture(n: usize, kill: &[(usize, usize)]) -> (AdjMatrix, Corr32, Vec<f64>) {
        let graph = AdjMatrix::complete(n);
        for &(a, b) in kill {
            graph.remove_edge(a, b);
        }
        let mut corr = vec![0.1; n * n];
        for i in 0..n {
            corr[i * n + i] = 1.0;
        }
        let corr32 = Corr32::from_f64(&corr, n);
        (graph, corr32, corr)
    }

    #[test]
    fn edge_tasks_are_row_major_with_correct_totals() {
        let (graph, corr32, _) = ctx_fixture(5, &[(0, 3)]);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, 5);
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l: 2, taul: 1.0 };
        let (tasks, max_total) = build_edge_tasks(&ctx);
        // rows 0 and 3 have 3 neighbors, the rest 4; every live directed
        // edge with nr >= l+1 contributes one task, in row-major order
        assert_eq!(tasks.len(), 2 * graph.n_edges());
        let mut prev = (0u32, 0u32);
        for t in &tasks {
            assert!((t.i, t.p) >= prev, "row-major order violated");
            prev = (t.i, t.p);
            assert_eq!(t.total, n_sets_edge(t.row_len as usize, 2));
            assert_eq!(comp.row(t.i as usize)[t.p as usize], t.j);
        }
        assert_eq!(max_total, n_sets_edge(4, 2));
    }

    #[test]
    fn edge_tasks_skip_short_rows() {
        // at l = 3 a row needs at least 4 neighbors to contribute
        let (graph, corr32, _) = ctx_fixture(5, &[(0, 3), (0, 4)]);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, 5);
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l: 3, taul: 1.0 };
        let (tasks, _) = build_edge_tasks(&ctx);
        assert!(tasks.iter().all(|t| t.i != 0), "row 0 has only 2 neighbors");
        assert!(tasks.iter().all(|t| t.row_len as usize >= 4));
    }
}
