//! The algorithm-family seam: a batched skeleton schedule is a
//! [`RoundSchedule`] strategy plugged into one generic level-loop driver,
//! not a hand-copied level loop per variant.
//!
//! The driver ([`run_rounds`] / [`run_rounds_with_engine`] /
//! [`run_rounds_sharded`]) owns everything PC-stable requires to stay
//! order-independent: the level-synchronous frame (one frozen `G'`
//! snapshot per level, removals applied between rounds), the level-0
//! pair sweep, the between-level [`WidthPolicy`](super::WidthPolicy)
//! re-lease point, the stop rule and the per-level bookkeeping. A
//! schedule only decides *which CI tests run when*:
//!
//! * [`begin_level`](RoundSchedule::begin_level) — build the level's task
//!   list from the frozen snapshot (per-edge cursors, per-row cursors,
//!   any ordering the family wants);
//! * [`visit_round`](RoundSchedule::visit_round) — stage 1: emit the
//!   round's live combination windows as [`Run`]s in the schedule's
//!   canonical order;
//! * [`eval_shard`](RoundSchedule::eval_shard) — stage 2 worker body:
//!   pack a shard of those windows and evaluate it on a [`CiEngine`],
//!   returning the independence candidates plus the shard's test count.
//!
//! Because evaluation is pure and the driver applies candidates in
//! canonical slot order (stage 3), every schedule implemented on this
//! trait is bit-deterministic and thread-count invariant *by
//! construction* — the property `tests/conformance_engines.rs` gates.
//!
//! # Out-of-core execution
//!
//! The driver streams every round through a
//! [`WindowPump`](crate::oocore::stream::WindowPump): emitted windows
//! are chopped into canonical-order chunks bounded by
//! [`Config::ooc`](super::OocConfig), each chunk is sharded through the
//! executor as it completes, and the per-chunk candidate lists apply at
//! round end in chunk order — semantically identical to evaluating the
//! whole round at once (the flight sees the graph frozen at round
//! start either way), but with an O(chunk) run buffer. The adjacency
//! behind [`LevelCtx::graph`] is the [`Adj`] seam: dense matrix or CSR
//! [`SparseAdj`](crate::oocore::sparse::SparseAdj), selected after
//! level 0 (see [`AdjMode`](super::AdjMode)). Under `cupc shard`,
//! chunks are owned round-robin by rank and the per-round results merge
//! through a [`DiskExchange`](crate::oocore::exchange::DiskExchange) —
//! every rank applies the identical merged stream, so all ranks hold
//! the identical graph at every round boundary.
//!
//! Implementations: [`gpu_e`](super::gpu_e) (cuPC-E and, through its γ
//! knob, the two Fig. 5 baselines), [`gpu_s`](super::gpu_s) (cuPC-S),
//! and [`reversed`](super::reversed) (reversed-order pruning,
//! arxiv 2109.04626). The coarse-grained families
//! ([`serial`](super::serial), [`parallel_cpu`](super::parallel_cpu))
//! predate the batch engines and stay whole-run functions; every family,
//! fine or coarse, is registered in [`family::FAMILIES`](super::family)
//! so no layer outside `skeleton/` matches on [`Variant`](super::Variant)
//! internals.

use super::batch::{Corr32, EBatch, Removals};
use super::comb::{n_sets_edge, CombRangeSkip};
use super::engine::CiEngine;
use super::level0::{eval_range, n_pairs, survivors_of_range};
use super::pipeline::{use_pool, Executor, Run};
use super::{should_continue_any, AdjMode, Config, LevelStats, OocStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::oocore::exchange::{
    decode_level_chunk, decode_pairs, encode_level_chunk, encode_pairs, DiskExchange,
};
use crate::oocore::sparse::{Adj, SparseAdj, SPARSE_MIN_N};
use crate::oocore::stream::WindowPump;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

/// The frozen per-level state every stage reads: the compacted snapshot
/// `G'`, the live adjacency (mutated only between rounds, in stage 3),
/// the f32-packed correlations, the level and its threshold.
pub struct LevelCtx<'a> {
    pub comp: &'a CompactAdj,
    pub graph: &'a Adj,
    pub corr32: &'a Corr32,
    pub l: usize,
    pub taul: f64,
}

/// A batched skeleton schedule: level iteration stays with the driver,
/// window enumeration and candidate-set construction live here. `Sync`
/// because stage 2 shares the schedule immutably across worker threads.
pub trait RoundSchedule: Sync {
    /// Short name for verbose per-level progress lines.
    fn label(&self) -> &'static str;

    /// Rebuild the schedule's task list from the level's frozen
    /// snapshot. Called once per level, before any round.
    fn begin_level(&mut self, ctx: &LevelCtx<'_>);

    /// True when round `round` is past the schedule's last window (the
    /// driver also stops early when a round emits no live runs).
    fn rounds_done(&self, round: u64) -> bool;

    /// Stage 1 (serial): emit round `round`'s live windows to `emit` in
    /// the schedule's canonical order. The concatenation of the emitted
    /// runs *is* the round's canonical slot order for the apply stage.
    /// Push-style so the driver can stream chunks through the executor
    /// without materializing the whole round.
    fn visit_round(&self, ctx: &LevelCtx<'_>, round: u64, emit: &mut dyn FnMut(Run));

    /// Round `round`'s windows materialized into `runs` (tests and
    /// small callers; the driver streams through
    /// [`visit_round`](RoundSchedule::visit_round) instead).
    fn list_round(&self, ctx: &LevelCtx<'_>, round: u64, runs: &mut Vec<Run>) {
        self.visit_round(ctx, round, &mut |r| runs.push(r));
    }

    /// Stage 2 (parallel worker body): pack + evaluate one shard of the
    /// round's windows; return the independence candidates (canonical
    /// slot order) and the number of CI tests the shard evaluated. Must
    /// be pure with respect to shared state (it may read the frozen
    /// graph).
    fn eval_shard(
        &self,
        ctx: &LevelCtx<'_>,
        shard: &[Run],
        engine: &mut dyn CiEngine,
    ) -> Result<(Removals, u64)>;
}

/// Drive a full skeleton run for `sched`, pool-or-single like every
/// batched family: pooled native workers when the config allows
/// ([`use_pool`]), otherwise the identical pipeline inline on the
/// configured engine.
pub fn run_rounds(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    if use_pool(cfg) {
        run_impl(corr, n, m, cfg, sched, &mut Executor::pool_with(cfg.threads, cfg.kernel), None)
    } else {
        let mut engine = crate::runtime::engine_from_config(cfg)?;
        run_impl(corr, n, m, cfg, sched, &mut Executor::Single(engine.as_mut()), None)
    }
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// driver inline — results are bit-identical to the pool path.
pub fn run_rounds_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    run_impl(corr, n, m, cfg, sched, &mut Executor::Single(engine), None)
}

/// Cross-process entry point (`cupc shard` workers and the in-process
/// conformance harness): the identical driver with chunk ownership
/// round-robin by rank and per-round merges through `exch`. Every rank
/// returns the complete result, bit-identical to [`run_rounds`].
pub fn run_rounds_sharded(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
    exch: &mut DiskExchange,
) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    if use_pool(cfg) {
        let mut exec = Executor::pool_with(cfg.threads, cfg.kernel);
        run_impl(corr, n, m, cfg, sched, &mut exec, Some(exch))
    } else {
        let mut engine = crate::runtime::engine_from_config(cfg)?;
        run_impl(corr, n, m, cfg, sched, &mut Executor::Single(engine.as_mut()), Some(exch))
    }
}

fn run_impl(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    sched: &mut dyn RoundSchedule,
    exec: &mut Executor<'_>,
    mut exch: Option<&mut DiskExchange>,
) -> Result<SkeletonResult> {
    let (rank, world) = match exch.as_deref() {
        Some(e) => e.topology(),
        None => (0, 1),
    };
    let corr32 = Corr32::from_f64(corr, n);
    let sepsets = SepSets::new();
    let mut levels = Vec::new();
    let mut peak_window = 0u64;

    // ---- level 0: chunked canonical pair sweep -------------------------
    // Chunks of the row-major upper-triangle enumeration are evaluated
    // (owned ones only, under sharding), reduced to their *survivor*
    // lists — O(edges) for the sparse regimes this path targets, where
    // the removal list would be O(n²) — and merged in canonical order.
    let t = Timer::start();
    let total = n_pairs(n);
    let tau0 = tau(m, 0, cfg.alpha);
    let chunk_slots = cfg.ooc.window_slots.max(1);
    let n_chunks0 = total.div_ceil(chunk_slots) as usize;
    let mut owned0: Vec<(u32, Vec<(u32, u32)>)> = Vec::new();
    for seq in 0..n_chunks0 {
        if seq % world != rank {
            continue;
        }
        let t0 = seq as u64 * chunk_slots;
        let count = chunk_slots.min(total - t0);
        let runs = [Run { task: 0, t0, count }];
        let shard_results = exec.run_sharded(&runs, |shard, engine| {
            let mut c = Vec::new();
            for r in shard {
                c.extend(eval_range(corr, n, tau0, r.t0, r.count, engine)?);
            }
            Ok(c)
        })?;
        let mut removed_pairs: Vec<(u32, u32)> = Vec::new();
        for c in shard_results {
            removed_pairs.extend(c);
        }
        owned0.push((seq as u32, survivors_of_range(n, t0, count, &removed_pairs)));
    }
    let survivors: Vec<(u32, u32)> = match exch.as_deref_mut() {
        Some(ex) => {
            let blobs: Vec<(u32, Vec<u8>)> =
                owned0.iter().map(|(s, p)| (*s, encode_pairs(p))).collect();
            drop(owned0);
            let merged = ex.exchange(0, 0, n_chunks0, blobs)?;
            let mut v = Vec::new();
            for b in &merged {
                v.extend(decode_pairs(b)?);
            }
            v
        }
        None => owned0.into_iter().flat_map(|(_, p)| p).collect(),
    };
    let removed0 = (total - survivors.len() as u64) as usize;
    let use_sparse = match cfg.ooc.adjacency {
        AdjMode::Dense => false,
        AdjMode::Sparse => true,
        AdjMode::Auto => {
            n >= SPARSE_MIN_N && (survivors.len() as u64).saturating_mul(4) <= total
        }
    };
    let edges_after0 = survivors.len();
    let graph = if use_sparse {
        // level 0 sepsets by complement: reads are identical to storing
        // each removed pair's empty set explicitly (see graph/sepset.rs)
        let sparse = SparseAdj::from_edges(n, &survivors);
        sepsets.store_empty_complement(n, survivors);
        Adj::Sparse(sparse)
    } else {
        let g = AdjMatrix::complete(n);
        let mut next = survivors.iter().peekable();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if next.peek() == Some(&&(i, j)) {
                    next.next();
                    continue;
                }
                g.remove_edge(i as usize, j as usize);
                sepsets.store(i as usize, j as usize, &[]);
            }
        }
        Adj::Dense(g)
    };
    levels.push(LevelStats {
        level: 0,
        tests: total,
        removed: removed0,
        edges_after: edges_after0,
        seconds: t.elapsed_s(),
    });

    // ---- levels >= 1: streamed rounds ----------------------------------
    let mut l = 1usize;
    while should_continue_any(graph.max_degree(), l, cfg) {
        // between-level re-lease point: a hooked job asks its width
        // policy (e.g. the batch scheduler's elastic lease) how wide to
        // run this level — absorbing workers other jobs released. Width
        // never changes results (ordered apply), only wall-clock time.
        if let Some(hook) = &cfg.width_hook {
            exec.set_width(hook.0.width_for_level(l));
        }
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let comp = graph.compact();
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l, taul };

        sched.begin_level(&ctx);

        let mut tests = 0u64;
        let mut removed = 0usize;
        let mut round = 0u64;
        while !sched.rounds_done(round) {
            // stage 1+2 streamed: the round's live windows are emitted
            // in canonical order, chopped into bounded chunks, and each
            // owned chunk is packed + evaluated as soon as it is full.
            // The graph stays frozen until the apply stage below, so
            // chunk boundaries cannot change any verdict.
            let sched_ref: &dyn RoundSchedule = &*sched;
            let mut pump = WindowPump::new(cfg.ooc.window_runs, cfg.ooc.window_slots);
            let mut owned: Vec<(u32, Removals, u64)> = Vec::new();
            let mut fail: Option<anyhow::Error> = None;
            {
                let mut on_chunk = |seq: u32, runs: Vec<Run>| -> Result<()> {
                    if seq as usize % world != rank {
                        return Ok(());
                    }
                    let shard_results = exec.run_sharded(&runs, |shard, engine| {
                        sched_ref.eval_shard(&ctx, shard, engine)
                    })?;
                    let mut cand = Removals::new(l);
                    let mut chunk_tests = 0u64;
                    for (c, st) in shard_results {
                        chunk_tests += st;
                        cand.append(c);
                    }
                    owned.push((seq, cand, chunk_tests));
                    Ok(())
                };
                {
                    let mut emit = |run: Run| {
                        if fail.is_some() {
                            return;
                        }
                        if let Err(e) = pump.offer(run, &mut on_chunk) {
                            fail = Some(e);
                        }
                    };
                    sched_ref.visit_round(&ctx, round, &mut emit);
                }
                if fail.is_none() {
                    if let Err(e) = pump.finish(&mut on_chunk) {
                        fail = Some(e);
                    }
                }
            }
            if let Some(e) = fail {
                return Err(e);
            }
            peak_window = peak_window.max(pump.peak_bytes());
            let n_chunks = pump.chunks_emitted() as usize;
            if n_chunks == 0 {
                break; // every unexhausted window belongs to a dead task
            }

            // stage 3 (serial): everything in flight lands in canonical
            // chunk-then-slot order before the next round — on every
            // rank, via the exchange when sharded.
            match exch.as_deref_mut() {
                Some(ex) => {
                    let blobs: Vec<(u32, Vec<u8>)> = owned
                        .iter()
                        .map(|(s, r, ct)| (*s, encode_level_chunk(r, *ct)))
                        .collect();
                    drop(owned);
                    let merged = ex.exchange(l as u32, round, n_chunks, blobs)?;
                    for b in &merged {
                        let (cand, ct) = decode_level_chunk(b)?;
                        tests += ct;
                        removed += cand.apply(&graph, &sepsets);
                    }
                }
                None => {
                    for (_, cand, ct) in &owned {
                        tests += *ct;
                        removed += cand.apply(&graph, &sepsets);
                    }
                }
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[{}] level {l}: {tests} tests, removed {removed}, {} edges left ({})",
                sched.label(),
                graph.n_edges(),
                graph.label(),
            );
        }
        l += 1;
    }

    let ooc = OocStats { adjacency: graph.label(), peak_window_bytes: peak_window };
    Ok(SkeletonResult { graph: graph.into_dense(), sepsets, levels, ooc })
}

/// One live edge's combination cursor within a level — the per-edge task
/// shape shared by cuPC-E, the Fig. 5 baselines and the reversed-order
/// schedule.
pub struct EdgeTask {
    pub i: u32,
    pub j: u32,
    /// position of j inside row i of G'
    pub p: u32,
    /// n'_i
    pub row_len: u32,
    /// C(n'_i − 1, ℓ)
    pub total: u64,
}

/// Build the level's edge-task list from `G'` (ordered pairs, row-major —
/// the same visit order as the CUDA grid) and return it with the largest
/// per-edge set count.
pub fn build_edge_tasks(ctx: &LevelCtx<'_>) -> (Vec<EdgeTask>, u64) {
    let (comp, l) = (ctx.comp, ctx.l);
    let mut tasks: Vec<EdgeTask> = Vec::new();
    for i in 0..comp.n() {
        let row = comp.row(i);
        let nr = row.len();
        if nr < l + 1 {
            continue; // §4.1 case I
        }
        let total = n_sets_edge(nr, l);
        if total == 0 {
            continue;
        }
        for (p, &j) in row.iter().enumerate() {
            tasks.push(EdgeTask {
                i: i as u32,
                j,
                p: p as u32,
                row_len: nr as u32,
                total,
            });
        }
    }
    let max_total = tasks.iter().map(|e| e.total).max().unwrap_or(0);
    (tasks, max_total)
}

/// Worker body shared by the per-edge schedules: pack a shard of
/// combination windows into engine-capacity [`EBatch`]es, evaluate them,
/// and keep only the independence candidates (canonical slot order).
/// Every slot of every run is evaluated, so the shard's test count is
/// its slot count.
pub fn eval_edge_shard(
    tasks: &[EdgeTask],
    ctx: &LevelCtx<'_>,
    shard: &[Run],
    engine: &mut dyn CiEngine,
) -> Result<(Removals, u64)> {
    let l = ctx.l;
    let cap = engine.batch_e().max(1);
    let mut out = Removals::new(l);
    let mut tests = 0u64;
    let mut batch = EBatch::new(l, cap);
    let mut ids = vec![0u32; l];
    for run in shard {
        let task = &tasks[run.task];
        let (i, j) = (task.i as usize, task.j as usize);
        let row = ctx.comp.row(i);
        tests += run.count;
        let mut combs =
            CombRangeSkip::new(task.row_len as usize, l, run.t0, run.count, task.p as usize);
        while let Some(sbuf) = combs.next_comb() {
            for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                *dst = row[pos as usize];
            }
            batch.push(ctx.corr32, i, j, &ids);
            if batch.len() >= cap {
                flush_e(&mut batch, engine, ctx.taul, &mut out)?;
            }
        }
    }
    if !batch.is_empty() {
        flush_e(&mut batch, engine, ctx.taul, &mut out)?;
    }
    Ok((out, tests))
}

fn flush_e(
    batch: &mut EBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    out: &mut Removals,
) -> Result<()> {
    let z = engine.ci_e(batch.l, batch.len(), &batch.c_ij, &batch.m1, &batch.m2)?;
    batch.drain_independent(&z, taul, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_fixture(n: usize, kill: &[(usize, usize)]) -> (Adj, Corr32, Vec<f64>) {
        let graph = AdjMatrix::complete(n);
        for &(a, b) in kill {
            graph.remove_edge(a, b);
        }
        let mut corr = vec![0.1; n * n];
        for i in 0..n {
            corr[i * n + i] = 1.0;
        }
        let corr32 = Corr32::from_f64(&corr, n);
        (Adj::Dense(graph), corr32, corr)
    }

    #[test]
    fn edge_tasks_are_row_major_with_correct_totals() {
        let (graph, corr32, _) = ctx_fixture(5, &[(0, 3)]);
        let comp = graph.compact();
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l: 2, taul: 1.0 };
        let (tasks, max_total) = build_edge_tasks(&ctx);
        // rows 0 and 3 have 3 neighbors, the rest 4; every live directed
        // edge with nr >= l+1 contributes one task, in row-major order
        assert_eq!(tasks.len(), 2 * graph.n_edges());
        let mut prev = (0u32, 0u32);
        for t in &tasks {
            assert!((t.i, t.p) >= prev, "row-major order violated");
            prev = (t.i, t.p);
            assert_eq!(t.total, n_sets_edge(t.row_len as usize, 2));
            assert_eq!(comp.row(t.i as usize)[t.p as usize], t.j);
        }
        assert_eq!(max_total, n_sets_edge(4, 2));
    }

    #[test]
    fn edge_tasks_skip_short_rows() {
        // at l = 3 a row needs at least 4 neighbors to contribute
        let (graph, corr32, _) = ctx_fixture(5, &[(0, 3), (0, 4)]);
        let comp = graph.compact();
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l: 3, taul: 1.0 };
        let (tasks, _) = build_edge_tasks(&ctx);
        assert!(tasks.iter().all(|t| t.i != 0), "row 0 has only 2 neighbors");
        assert!(tasks.iter().all(|t| t.row_len as usize >= 4));
    }
}
