//! The CI-test engine abstraction: batched z-statistic evaluation.
//!
//! Two implementations share identical semantics:
//! * [`NativeEngine`] — pure-Rust mirror of the Pallas kernels (f64
//!   internally; always available, used for cross-checking and as the
//!   fallback above the AOT-compiled level range).
//! * `runtime::XlaEngine` — executes the AOT HLO artifacts on the PJRT
//!   CPU client (the production path; see `rust/src/runtime`).
//!
//! Packed-batch layout (matches python/compile/model.py):
//! * ci_e: `c_ij[B]`, `m1[B·2·l]`, `m2[B·l·l]` → `z[B]`
//! * ci_s: `c_ij[R·K]`, `m1[R·K·2·l]`, `m2[R·l·l]` → `z[R·K]`
//! * level0: `c_ij[B]` → `z[B]`
//!
//! The native evaluation itself lives behind the kernel seam in
//! `stats::kernels` (`scalar` reference vs `blocked` lane-major,
//! selectable via `CUPC_KERNEL` — bitwise identical, see
//! `docs/NUMERICS.md`).

use crate::stats::kernels::{self, KernelKind, Scratch};
use anyhow::Result;

/// Batched CI-statistic evaluation. Inputs are f32 (the artifact dtype);
/// outputs are |Fisher z| per test. Any batch length is accepted — the
/// engine handles padding/chunking internally.
pub trait CiEngine {
    /// |z| of raw correlations (level 0).
    fn level0(&mut self, c_ij: &[f32]) -> Result<Vec<f32>>;

    /// cuPC-E batch: one (i,j,S) test per slot; `b` slots.
    fn ci_e(&mut self, l: usize, b: usize, c_ij: &[f32], m1: &[f32], m2: &[f32])
        -> Result<Vec<f32>>;

    /// cuPC-S batch: `rows` conditioning sets × `k` tests each. The
    /// pseudo-inverse of each row's M2 is computed once (the cuPC-S
    /// saving) regardless of engine.
    /// `valid[r]` = number of non-padding slots in row r (len == rows);
    /// engines may skip the padded tail (the XLA kernel ignores this and
    /// computes the full K width — padded verdicts are discarded later).
    #[allow(clippy::too_many_arguments)] // mirrors the kernel ABI
    fn ci_s(
        &mut self,
        l: usize,
        rows: usize,
        k: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
        valid: &[u32],
    ) -> Result<Vec<f32>>;

    /// Highest conditioning-set size this engine supports natively
    /// (the driver falls back to [`NativeEngine`] above it).
    fn max_level(&self) -> usize;

    /// Preferred ci_e batch capacity (packers flush at this size).
    fn batch_e(&self) -> usize;

    /// Preferred ci_s row capacity.
    fn batch_s(&self) -> usize;

    /// Tests per conditioning set in ci_s batches.
    fn k(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Pure-Rust engine mirroring the Pallas kernels. The actual batch
/// evaluation lives behind the kernel seam in `stats::kernels` —
/// this struct owns the workspace, the batch geometry, and the
/// [`KernelKind`] selecting scalar vs blocked evaluation (both are
/// bitwise identical; see `docs/NUMERICS.md`).
pub struct NativeEngine {
    kernel: KernelKind,
    sc: Scratch,
    batch_e: usize,
    batch_s: usize,
    k: usize,
}

pub const NATIVE_MAX_LEVEL: usize = 32;

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// Default geometry, kernel selected by `CUPC_KERNEL` (blocked
    /// when unset).
    pub fn new() -> Self {
        Self::with_kernel(KernelKind::from_env())
    }

    /// Default geometry with an explicit kernel (the in-process A/B
    /// path used by the conformance suite and the bench).
    pub fn with_kernel(kernel: KernelKind) -> Self {
        // Batch geometry matches the AOT artifacts so that schedules
        // (rounds, early-termination points) are identical across engines.
        Self::with_batches_kernel(4096, 256, 32, kernel)
    }

    pub fn with_batches(batch_e: usize, batch_s: usize, k: usize) -> Self {
        Self::with_batches_kernel(batch_e, batch_s, k, KernelKind::from_env())
    }

    pub fn with_batches_kernel(
        batch_e: usize,
        batch_s: usize,
        k: usize,
        kernel: KernelKind,
    ) -> Self {
        NativeEngine {
            kernel,
            sc: Scratch::new(NATIVE_MAX_LEVEL),
            batch_e,
            batch_s,
            k,
        }
    }

    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }
}

impl CiEngine for NativeEngine {
    fn level0(&mut self, c_ij: &[f32]) -> Result<Vec<f32>> {
        Ok(kernels::level0(self.kernel, c_ij))
    }

    fn ci_e(
        &mut self,
        l: usize,
        b: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(c_ij.len(), b);
        debug_assert_eq!(m1.len(), b * 2 * l);
        debug_assert_eq!(m2.len(), b * l * l);
        Ok(kernels::ci_e(self.kernel, l, b, c_ij, m1, m2, &mut self.sc))
    }

    #[allow(clippy::too_many_arguments)]
    fn ci_s(
        &mut self,
        l: usize,
        rows: usize,
        k: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
        valid: &[u32],
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(c_ij.len(), rows * k);
        debug_assert_eq!(m1.len(), rows * k * 2 * l);
        debug_assert_eq!(m2.len(), rows * l * l);
        debug_assert_eq!(valid.len(), rows);
        Ok(kernels::ci_s(
            self.kernel,
            l,
            rows,
            k,
            c_ij,
            m1,
            m2,
            valid,
            &mut self.sc,
        ))
    }

    fn max_level(&self) -> usize {
        NATIVE_MAX_LEVEL
    }

    fn batch_e(&self) -> usize {
        self.batch_e
    }

    fn batch_s(&self) -> usize {
        self.batch_s
    }

    fn k(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Build the engine selected by the config (Xla engines are constructed
/// through `runtime::engine_from_config` to keep this module free of PJRT
/// types; this helper stays for native-only callers and tests).
pub fn native_engine() -> NativeEngine {
    NativeEngine::new()
}

/// Composes a primary engine with a fallback used above the primary's
/// AOT-compiled level range (the XLA artifacts cover ℓ ≤ 8; deeper
/// levels — rare, dense-graph territory — run through the native mirror
/// with identical semantics).
pub struct WithFallback<P, F> {
    pub primary: P,
    pub fallback: F,
}

impl<P: CiEngine, F: CiEngine> CiEngine for WithFallback<P, F> {
    fn level0(&mut self, c_ij: &[f32]) -> Result<Vec<f32>> {
        self.primary.level0(c_ij)
    }

    fn ci_e(
        &mut self,
        l: usize,
        b: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
    ) -> Result<Vec<f32>> {
        if l <= self.primary.max_level() {
            self.primary.ci_e(l, b, c_ij, m1, m2)
        } else {
            self.fallback.ci_e(l, b, c_ij, m1, m2)
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn ci_s(
        &mut self,
        l: usize,
        rows: usize,
        k: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
        valid: &[u32],
    ) -> Result<Vec<f32>> {
        if l <= self.primary.max_level() {
            self.primary.ci_s(l, rows, k, c_ij, m1, m2, valid)
        } else {
            self.fallback.ci_s(l, rows, k, c_ij, m1, m2, valid)
        }
    }

    fn max_level(&self) -> usize {
        self.primary.max_level().max(self.fallback.max_level())
    }

    fn batch_e(&self) -> usize {
        self.primary.batch_e()
    }

    fn batch_s(&self) -> usize {
        self.primary.batch_s()
    }

    fn k(&self) -> usize {
        self.primary.k()
    }

    fn name(&self) -> &'static str {
        "fallback-composed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level0_matches_fisher() {
        let mut e = NativeEngine::new();
        let z = e.level0(&[0.0, 0.5, -0.5, 0.99]).unwrap();
        assert_eq!(z[0], 0.0);
        assert!((z[1] - 0.54930615).abs() < 1e-5);
        assert!((z[1] - z[2]).abs() < 1e-7);
        assert!(z[3] > 2.0);
    }

    #[test]
    fn ci_e_l1_closed_form() {
        // rho(0,1|2) = (c01 - c02*c12)/sqrt((1-c02²)(1-c12²))
        let (c01, c02, c12) = (0.56f32, 0.8f32, 0.7f32);
        let mut e = NativeEngine::new();
        let c_ij = [c01];
        let m1 = [c02, c12]; // C[i,S], C[j,S]
        let m2 = [1.0f32];
        let z = e.ci_e(1, 1, &c_ij, &m1, &m2).unwrap();
        assert!(z[0].abs() < 1e-5, "chain: conditioning kills rho, z={}", z[0]);
    }

    #[test]
    fn ci_s_equals_ci_e_per_test() {
        // same (i,j,S) evaluated via both paths must agree exactly.
        let l = 2;
        let c_ij = [0.3f32, -0.2];
        let m1 = [
            0.5f32, 0.1, 0.4, 0.2, // test 0: C[i,S]=(.5,.1), C[j,S]=(.4,.2)
            0.6, 0.2, 0.1, 0.3, // test 1
        ];
        let m2 = [1.0f32, 0.4, 0.4, 1.0];
        let mut e = NativeEngine::new();
        // ci_s: 1 row, k=2 sharing the same m2
        let z_s = e.ci_s(l, 1, 2, &c_ij, &m1, &m2, &[2]).unwrap();
        // ci_e: 2 slots with m2 duplicated
        let m2_dup = [m2[0], m2[1], m2[2], m2[3], m2[0], m2[1], m2[2], m2[3]];
        let z_e = e.ci_e(l, 2, &c_ij, &m1, &m2_dup).unwrap();
        assert_eq!(z_s, z_e);
    }

    #[test]
    fn batch_geometry_defaults_match_artifacts() {
        let e = NativeEngine::new();
        assert_eq!(e.batch_e(), 4096);
        assert_eq!(e.batch_s(), 256);
        assert_eq!(e.k(), 32);
    }

    #[test]
    fn explicit_kernels_agree_through_the_engine() {
        use crate::sim::batches::random_batch;
        use crate::util::rng::Pcg;
        let (l, b) = (3usize, 13usize);
        let (c_ij, m1, m2) = random_batch(&mut Pcg::seeded(3), b, l);
        let mut scalar = NativeEngine::with_kernel(KernelKind::Scalar);
        let mut blocked = NativeEngine::with_kernel(KernelKind::Blocked);
        assert_eq!(scalar.kernel(), KernelKind::Scalar);
        assert_eq!(blocked.kernel(), KernelKind::Blocked);
        let za = scalar.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        let zb = blocked.ci_e(l, b, &c_ij, &m1, &m2).unwrap();
        assert_eq!(za, zb, "kernels must agree bitwise through the engine");
        // both engines keep the public name: kernel choice is not an
        // engine identity (and never enters cache keys)
        assert_eq!(scalar.name(), "native");
        assert_eq!(blocked.name(), "native");
    }
}

// The coarse `micro_throughput` probe that used to live here was
// promoted to a tracked baseline: `cargo bench --bench engines` measures
// ns/test for level0 / ci_e / ci_s across levels and batch sizes and
// writes BENCH_engines.json (see benches/engines.rs).

#[cfg(test)]
mod fallback_tests {
    use super::*;

    /// Wraps the native engine, counting calls, with a configurable
    /// level ceiling — a stand-in for the AOT-ranged XLA engine.
    struct CountingEngine {
        inner: NativeEngine,
        max_level: usize,
        level0_calls: usize,
        ci_e_calls: usize,
        ci_s_calls: usize,
    }

    impl CountingEngine {
        fn new(max_level: usize) -> Self {
            CountingEngine {
                inner: NativeEngine::new(),
                max_level,
                level0_calls: 0,
                ci_e_calls: 0,
                ci_s_calls: 0,
            }
        }
    }

    impl CiEngine for CountingEngine {
        fn level0(&mut self, c_ij: &[f32]) -> Result<Vec<f32>> {
            self.level0_calls += 1;
            self.inner.level0(c_ij)
        }

        fn ci_e(
            &mut self,
            l: usize,
            b: usize,
            c_ij: &[f32],
            m1: &[f32],
            m2: &[f32],
        ) -> Result<Vec<f32>> {
            self.ci_e_calls += 1;
            self.inner.ci_e(l, b, c_ij, m1, m2)
        }

        #[allow(clippy::too_many_arguments)]
        fn ci_s(
            &mut self,
            l: usize,
            rows: usize,
            k: usize,
            c_ij: &[f32],
            m1: &[f32],
            m2: &[f32],
            valid: &[u32],
        ) -> Result<Vec<f32>> {
            self.ci_s_calls += 1;
            self.inner.ci_s(l, rows, k, c_ij, m1, m2, valid)
        }

        fn max_level(&self) -> usize {
            self.max_level
        }

        fn batch_e(&self) -> usize {
            self.inner.batch_e()
        }

        fn batch_s(&self) -> usize {
            self.inner.batch_s()
        }

        fn k(&self) -> usize {
            self.inner.k()
        }

        fn name(&self) -> &'static str {
            "counting"
        }
    }

    /// A tiny valid ci_e batch at level l: identity M2.
    fn e_batch(l: usize, b: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let c_ij = vec![0.3f32; b];
        let m1 = vec![0.2f32; b * 2 * l];
        let mut m2 = vec![0.0f32; b * l * l];
        for s in 0..b {
            for d in 0..l {
                m2[s * l * l + d * l + d] = 1.0;
            }
        }
        (c_ij, m1, m2)
    }

    #[test]
    fn routes_ci_e_by_level() {
        let mut f = WithFallback {
            primary: CountingEngine::new(2),
            fallback: CountingEngine::new(NATIVE_MAX_LEVEL),
        };
        for l in [1usize, 2, 3, 4] {
            let (c_ij, m1, m2) = e_batch(l, 3);
            f.ci_e(l, 3, &c_ij, &m1, &m2).unwrap();
        }
        assert_eq!(f.primary.ci_e_calls, 2, "l = 1, 2 go to the primary");
        assert_eq!(f.fallback.ci_e_calls, 2, "l = 3, 4 fall back");
    }

    #[test]
    fn routes_ci_s_by_level() {
        let mut f = WithFallback {
            primary: CountingEngine::new(2),
            fallback: CountingEngine::new(NATIVE_MAX_LEVEL),
        };
        for l in [1usize, 2, 3] {
            let (rows, k) = (2usize, 2usize);
            let c_ij = vec![0.3f32; rows * k];
            let m1 = vec![0.2f32; rows * k * 2 * l];
            let mut m2 = vec![0.0f32; rows * l * l];
            for r in 0..rows {
                for d in 0..l {
                    m2[r * l * l + d * l + d] = 1.0;
                }
            }
            let valid = vec![k as u32; rows];
            f.ci_s(l, rows, k, &c_ij, &m1, &m2, &valid).unwrap();
        }
        assert_eq!(f.primary.ci_s_calls, 2, "l = 1, 2 go to the primary");
        assert_eq!(f.fallback.ci_s_calls, 1, "l = 3 falls back");
    }

    #[test]
    fn level0_always_routes_to_primary_and_max_level_composes() {
        let mut f = WithFallback {
            primary: CountingEngine::new(1),
            fallback: CountingEngine::new(NATIVE_MAX_LEVEL),
        };
        f.level0(&[0.1, 0.2]).unwrap();
        assert_eq!(f.primary.level0_calls, 1);
        assert_eq!(f.fallback.level0_calls, 0);
        assert_eq!(f.max_level(), NATIVE_MAX_LEVEL, "driver sees the union");
        assert_eq!(f.batch_e(), f.primary.batch_e(), "geometry is the primary's");
    }

    /// Equicorrelated matrix (all off-diagonals = rho): positive
    /// definite for 0 < rho < 1, and no edge is ever removed at
    /// m = 1000, so the level loop visits every l up to n − 2 — levels
    /// above the primary's ceiling are guaranteed to exercise the
    /// fallback, deterministically and with no RNG.
    fn equi_corr(n: usize, rho: f64) -> Vec<f64> {
        let mut c = vec![rho; n * n];
        for i in 0..n {
            c[i * n + i] = 1.0;
        }
        c
    }

    #[test]
    fn composed_cupc_e_run_matches_pure_native() {
        let (n, m) = (6usize, 1000usize);
        let corr = equi_corr(n, 0.5);
        let cfg = crate::skeleton::Config::default();
        let mut composed = WithFallback {
            primary: CountingEngine::new(1),
            fallback: CountingEngine::new(NATIVE_MAX_LEVEL),
        };
        let res_c =
            crate::skeleton::gpu_e::run_with_engine(&corr, n, m, &cfg, &mut composed).unwrap();
        let mut native = NativeEngine::new();
        let res_n =
            crate::skeleton::gpu_e::run_with_engine(&corr, n, m, &cfg, &mut native).unwrap();
        assert_eq!(res_c.graph.snapshot(), res_n.graph.snapshot());
        assert_eq!(res_c.sepsets.sorted_entries(), res_n.sepsets.sorted_entries());
        let stats = |r: &crate::skeleton::SkeletonResult| -> Vec<(usize, u64)> {
            r.levels.iter().map(|s| (s.level, s.tests)).collect()
        };
        assert_eq!(stats(&res_c), stats(&res_n));
        assert!(composed.primary.ci_e_calls > 0, "level 1 runs on the primary");
        assert!(composed.fallback.ci_e_calls > 0, "levels > 1 fall back");
        assert_eq!(composed.fallback.level0_calls, 0);
    }

    #[test]
    fn composed_cupc_s_run_matches_pure_native() {
        let (n, m) = (6usize, 1000usize);
        let corr = equi_corr(n, 0.5);
        let cfg = crate::skeleton::Config::default();
        let mut composed = WithFallback {
            primary: CountingEngine::new(1),
            fallback: CountingEngine::new(NATIVE_MAX_LEVEL),
        };
        let res_c =
            crate::skeleton::gpu_s::run_with_engine(&corr, n, m, &cfg, &mut composed).unwrap();
        let mut native = NativeEngine::new();
        let res_n =
            crate::skeleton::gpu_s::run_with_engine(&corr, n, m, &cfg, &mut native).unwrap();
        assert_eq!(res_c.graph.snapshot(), res_n.graph.snapshot());
        assert_eq!(res_c.sepsets.sorted_entries(), res_n.sepsets.sorted_entries());
        assert!(composed.primary.ci_s_calls > 0, "level 1 runs on the primary");
        assert!(composed.fallback.ci_s_calls > 0, "levels > 1 fall back");
    }
}
