//! Multi-threaded CPU skeleton — the paper's "Parallel-PC" baseline (T2).
//!
//! PC-stable's order-independence makes each level embarrassingly
//! parallel: rows of G' are sharded across worker threads; removals go
//! through the atomic adjacency (monotone 1→0), and threads observe
//! removals made by others mid-level exactly like cuPC's in-kernel
//! monitoring (§4.1). The *level* result equals the serial one because
//! conditioning sets are drawn from the frozen snapshot.

use super::comb::{n_sets_edge, CombRangeSkip};
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::{independent, tau};
use crate::stats::pcorr::{ci_statistic, CiWorkspace, Corr};
use crate::util::timer::Timer;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let nthreads = cfg.threads.max(1);
    let mut levels = Vec::new();

    // level 0 sharded over pair blocks
    let t0 = Timer::start();
    let tau0 = tau(m, 0, cfg.alpha);
    let tests0 = AtomicU64::new(0);
    let removed0 = AtomicUsize::new(0);
    let next_row = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            scope.spawn(|| {
                let view = Corr::new(corr, n);
                let mut ws = CiWorkspace::new(1);
                loop {
                    let i = next_row.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    for j in (i + 1)..n {
                        tests0.fetch_add(1, Ordering::Relaxed);
                        let z = ci_statistic(&view, i, j, &[], &mut ws);
                        if independent(z, tau0) && graph.remove_edge(i, j) {
                            sepsets.store(i, j, &[]);
                            removed0.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    levels.push(LevelStats {
        level: 0,
        tests: tests0.into_inner(),
        removed: removed0.into_inner(),
        edges_after: graph.n_edges(),
        seconds: t0.elapsed_s(),
    });

    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);
        let tests = AtomicU64::new(0);
        let removed = AtomicUsize::new(0);
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                scope.spawn(|| {
                    let view = Corr::new(corr, n);
                    let mut ws = CiWorkspace::new(crate::skeleton::engine::NATIVE_MAX_LEVEL);
                    let mut ids: Vec<usize> = Vec::with_capacity(l);
                    let mut local_tests = 0u64;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let row = comp.row(i);
                        let nr = row.len();
                        if nr < l + 1 {
                            continue;
                        }
                        for (p, &ju) in row.iter().enumerate() {
                            let j = ju as usize;
                            let total = n_sets_edge(nr, l);
                            let mut combs = CombRangeSkip::new(nr, l, 0, total, p);
                            while let Some(sbuf) = combs.next_comb() {
                                // monitor removals by other threads (§4.1)
                                if !graph.has_edge(i, j) {
                                    break;
                                }
                                ids.clear();
                                ids.extend(sbuf.iter().map(|&x| row[x as usize] as usize));
                                local_tests += 1;
                                let z = ci_statistic(&view, i, j, &ids, &mut ws);
                                if independent(z, taul) && graph.remove_edge(i, j) {
                                    let sv: Vec<u32> =
                                        ids.iter().map(|&x| x as u32).collect();
                                    sepsets.store(i, j, &sv);
                                    removed.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                            }
                        }
                    }
                    tests.fetch_add(local_tests, Ordering::Relaxed);
                });
            }
        });
        levels.push(LevelStats {
            level: l,
            tests: tests.into_inner(),
            removed: removed.into_inner(),
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
        ooc: super::OocStats::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn matches_serial_skeleton() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 60,
            m: 120,
            topology: datasets::Topology::Er(0.06),
            seed: 42,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg_p = Config {
            threads: 4,
            ..Config::default()
        };
        let res_p = run(&c, ds.data.n, ds.data.m, &cfg_p).unwrap();
        let res_s = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg_p).unwrap();
        assert_eq!(
            res_p.graph.snapshot(),
            res_s.graph.snapshot(),
            "order-independence: parallel and serial skeletons must match"
        );
        assert_eq!(res_p.levels.len(), res_s.levels.len());
    }
}
