//! GPU baseline algorithm 2 of Fig. 5: every edge a block, **all CI
//! tests of the edge fully parallel** — no early termination inside the
//! edge's flight. In the batched schedule this is cuPC-E with γ = ∞
//! (the whole combination range packed in a single round), keeping the
//! same compaction and staging; with one round per level there is no
//! intra-level early termination at all — the extreme the paper's Fig. 5
//! penalizes. Inherits gpu_e's multi-threaded pipeline when
//! `Config::threads > 1` on the native engine.

use super::{Config, SkeletonResult};
use anyhow::Result;

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    let cfg2 = Config {
        gamma: usize::MAX / 2,
        beta: 1,
        ..cfg.clone()
    };
    super::gpu_e::run(corr, n, m, &cfg2)
}

/// Engine-injected variant for tests and the bench harness.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn super::engine::CiEngine,
) -> Result<SkeletonResult> {
    let cfg2 = Config {
        gamma: usize::MAX / 2,
        beta: 1,
        ..cfg.clone()
    };
    super::gpu_e::run_with_engine(corr, n, m, &cfg2, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn baseline2_tests_at_least_as_many_as_cupce() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 100,
            topology: datasets::Topology::Er(0.1),
            seed: 13,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let mut e1 = NativeEngine::new();
        let r_b2 = run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e1).unwrap();
        let mut e2 = NativeEngine::new();
        let r_e = crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e2)
            .unwrap();
        assert_eq!(r_b2.graph.snapshot(), r_e.graph.snapshot());
        assert!(
            r_b2.total_tests() >= r_e.total_tests(),
            "full fan-out cannot avoid tests: {} vs {}",
            r_b2.total_tests(),
            r_e.total_tests()
        );
    }
}
