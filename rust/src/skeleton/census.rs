//! Conditional-set sharing census (paper Fig. 9 / §5.5): for a given
//! level, how many rows of `A'_G` contain each distinct conditioning set
//! S? The histogram quantifies how much *global* sharing could save over
//! cuPC-S's local sharing — the paper's argument for local-only.

use crate::graph::compact::CompactAdj;
use std::collections::HashMap;

/// For level `l`, count for every distinct S (drawn as an l-subset of
/// some row) the number of distinct rows whose compacted row contains S.
/// Returns the multiset of those counts (one entry per distinct S).
///
/// Exact enumeration is exponential in l; this is used with l = 2 as in
/// the paper's Fig. 9.
pub fn set_row_counts(comp: &CompactAdj, l: usize) -> Vec<u32> {
    assert_eq!(l, 2, "census implemented for level 2 (paper Fig. 9)");
    let mut counts: HashMap<(u32, u32), u32> = HashMap::new();
    for i in 0..comp.n() {
        let row = comp.row(i);
        for a in 0..row.len() {
            for b in (a + 1)..row.len() {
                *counts.entry((row[a], row[b])).or_insert(0) += 1;
            }
        }
    }
    counts.into_values().collect()
}

/// Histogram of `set_row_counts` with the paper's binning: bins of width
/// `bin_width` over [1, max]; returns (bin_lo, share%) with shares in
/// percent of distinct sets.
pub fn histogram(counts: &[u32], bin_width: u32, max_bins: usize) -> Vec<(u32, f64)> {
    let total = counts.len().max(1) as f64;
    let mut bins = vec![0usize; max_bins];
    for &c in counts {
        let b = (((c.saturating_sub(1)) / bin_width) as usize).min(max_bins - 1);
        bins[b] += 1;
    }
    bins.into_iter()
        .enumerate()
        .map(|(idx, cnt)| (idx as u32 * bin_width + 1, 100.0 * cnt as f64 / total))
        .collect()
}

/// Share (in %) of distinct sets appearing in at most `threshold` rows —
/// the paper's "about 95% of the redundant conditional sets S appear in
/// at most 40 rows".
pub fn share_at_most(counts: &[u32], threshold: u32) -> f64 {
    if counts.is_empty() {
        return 100.0;
    }
    let c = counts.iter().filter(|&&x| x <= threshold).count();
    100.0 * c as f64 / counts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::adj::AdjMatrix;

    #[test]
    fn census_counts_shared_pairs() {
        // star around 0: rows 1..4 all contain {0}; pairs only exist in
        // row 0 = {1,2,3,4}
        let g = AdjMatrix::empty(5);
        for j in 1..5 {
            g.add_edge(0, j);
        }
        let comp = CompactAdj::from_snapshot(&g.snapshot(), 5);
        let counts = set_row_counts(&comp, 2);
        // row 0 contributes C(4,2) = 6 distinct pairs, each in 1 row
        assert_eq!(counts.len(), 6);
        assert!(counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn census_detects_multi_row_sharing() {
        // triangle 0-1-2 plus hub 3 connected to all: pair {3, x} appears
        // in multiple rows
        let g = AdjMatrix::empty(4);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(0, 2);
        for j in 0..3 {
            g.add_edge(3, j);
        }
        let comp = CompactAdj::from_snapshot(&g.snapshot(), 4);
        let counts = set_row_counts(&comp, 2);
        assert!(counts.iter().any(|&c| c >= 2), "counts={counts:?}");
    }

    #[test]
    fn histogram_shares_sum_to_100() {
        let counts = vec![1, 1, 2, 5, 40, 41, 200];
        let h = histogram(&counts, 40, 5);
        let total: f64 = h.iter().map(|(_, s)| s).sum();
        assert!((total - 100.0).abs() < 1e-9);
        assert_eq!(h[0].0, 1);
        assert_eq!(h[1].0, 41);
    }

    #[test]
    fn share_at_most_works() {
        let counts = vec![1, 2, 3, 100];
        assert!((share_at_most(&counts, 40) - 75.0).abs() < 1e-9);
        assert_eq!(share_at_most(&[], 40), 100.0);
    }
}
