//! Serial PC-stable skeleton — the paper's "Stable.fast" baseline (T3):
//! a faithful single-threaded implementation of Algorithm 1 with the
//! native CI test, per-edge early exit, and the same G' snapshot
//! semantics as every other variant.

use super::comb::{n_sets_edge, CombRangeSkip};
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::{independent, tau};
use crate::stats::pcorr::{ci_statistic, CiWorkspace, Corr};
use crate::util::timer::Timer;
use anyhow::Result;

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(super::degenerate_result(n));
    }
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let view = Corr::new(corr, n);
    let mut ws = CiWorkspace::new(crate::skeleton::engine::NATIVE_MAX_LEVEL);
    let mut levels = Vec::new();

    // level 0: raw correlations
    let t0 = Timer::start();
    let tau0 = tau(m, 0, cfg.alpha);
    let mut tests0 = 0u64;
    let mut removed0 = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            tests0 += 1;
            let z = ci_statistic(&view, i, j, &[], &mut ws);
            if independent(z, tau0) {
                graph.remove_edge(i, j);
                sepsets.store(i, j, &[]);
                removed0 += 1;
            }
        }
    }
    levels.push(LevelStats {
        level: 0,
        tests: tests0,
        removed: removed0,
        edges_after: graph.n_edges(),
        seconds: t0.elapsed_s(),
    });

    // levels >= 1
    let mut l = 1usize;
    let mut row_buf: Vec<usize> = Vec::new();
    while should_continue(&graph, l, cfg) {
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);
        let mut tests = 0u64;
        let mut removed = 0usize;
        // ordered pairs: row i of G', each j in the row (the paper's
        // by-row processing; each undirected edge is visited from both
        // endpoints, with different candidate pools)
        for i in 0..n {
            let row = comp.row(i);
            let nr = row.len();
            if nr < l + 1 {
                continue; // early termination case I (§4.1)
            }
            for (p, &ju) in row.iter().enumerate() {
                let j = ju as usize;
                if !graph.has_edge(i, j) {
                    continue; // removed earlier this level
                }
                let total = n_sets_edge(nr, l);
                let mut combs = CombRangeSkip::new(nr, l, 0, total, p);
                while let Some(sbuf) = combs.next_comb() {
                    // map row positions -> variable ids
                    row_buf.clear();
                    row_buf.extend(sbuf.iter().map(|&x| row[x as usize] as usize));
                    tests += 1;
                    let z = ci_statistic(&view, i, j, &row_buf, &mut ws);
                    if independent(z, taul) {
                        graph.remove_edge(i, j);
                        let sv: Vec<u32> = row_buf.iter().map(|&x| x as u32).collect();
                        sepsets.store(i, j, &sv);
                        removed += 1;
                        break; // per-edge early exit (Algorithm 1 line 14)
                    }
                }
            }
        }
        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
        ooc: super::OocStats::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{datasets, sem};
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn chain_graph_recovers_skeleton() {
        // 0 -> 1 -> 2: skeleton 0-1, 1-2, no 0-2
        let dag = crate::sim::dag::WeightedDag {
            n: 3,
            parents: vec![vec![], vec![(0, 0.9)], vec![(1, 0.9)]],
        };
        let data = sem::sample(&dag, 5000, &mut crate::util::rng::Pcg::seeded(3));
        let c = correlation_matrix(&data, 1);
        let cfg = Config::default();
        let res = run(&c, 3, data.m, &cfg).unwrap();
        assert!(res.graph.has_edge(0, 1));
        assert!(res.graph.has_edge(1, 2));
        assert!(!res.graph.has_edge(0, 2));
        assert_eq!(res.sepsets.get(0, 2), Some(vec![1]));
        assert!(res.levels.len() >= 2);
    }

    #[test]
    fn mini_dataset_converges() {
        let ds = datasets::generate(datasets::spec("nci60-mini").unwrap());
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config {
            max_level: Some(3),
            ..Config::default()
        };
        let res = run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        // sane: fewer edges than complete, more than zero
        let complete = ds.data.n * (ds.data.n - 1) / 2;
        let e = res.graph.n_edges();
        assert!(e > 0 && e < complete / 2, "edges={e}");
        assert!(res.total_tests() > 0);
    }
}
