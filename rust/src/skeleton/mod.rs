//! Skeleton discovery — the computationally intensive first step of
//! PC-stable (paper Algorithm 1) and the subject of cuPC.
//!
//! The PC schedules are implemented over a common engine abstraction.
//! Each is an *algorithm family* registered in [`family::FAMILIES`]
//! (the implementation table; identity metadata and the non-PC engine
//! kinds live in the top-level [`crate::family`] registry);
//! the batched ones are [`schedule::RoundSchedule`] strategies driven by
//! one shared level loop, the coarse-grained ones are whole-run
//! functions:
//!
//! * [`serial`] — single-threaded reference (the paper's "Stable.fast").
//! * [`parallel_cpu`] — multi-threaded CPU (the paper's "Parallel-PC").
//! * [`gpu_e`] — the cuPC-E schedule (Algorithm 4): edges × per-edge
//!   conditioning sets, batched through the AOT kernels.
//! * [`gpu_s`] — the cuPC-S schedule (Algorithm 5): conditioning sets
//!   shared across the tests of a row, one pseudo-inverse per set.
//! * [`baseline1`] / [`baseline2`] — the two GPU baselines of Fig. 5,
//!   expressed as degenerate cuPC-E configurations (γ=1 / γ=∞).
//! * [`reversed`] — reversed-order pruning (arxiv 2109.04626): densest
//!   nodes first, descending combination order, one test in flight per
//!   edge — fewer total tests on dense graphs, same skeleton.
//!
//! All schedules produce the *identical* skeleton — and the identical set
//! of removed pairs (sepset keys) — on the same input: PC-stable's
//! order-independence. The stored sepset *contents* are whichever
//! separating set a schedule finds first (schedule-dependent; use
//! [`OrientRule::Majority`] for a schedule-invariant CPDAG). The
//! cross-engine conformance suite (`tests/conformance_engines.rs`)
//! enforces all of this over the `sim::scenarios` grid.
//!
//! The batched schedules run their per-round pack + evaluate work —
//! including the level-0 pair sweep — through the multi-threaded
//! [`pipeline`] when the native engine is selected and
//! `Config::threads > 1` (or a [`WidthPolicy`] hook is installed); the
//! pipeline's ordered-apply stage keeps results bit-identical to a
//! single-threaded run, for any fixed width or between-level re-lease
//! schedule.

pub mod batch;
pub mod baseline1;
pub mod baseline2;
pub mod census;
pub mod comb;
pub mod engine;
pub mod family;
pub mod gpu_e;
pub mod gpu_s;
pub mod level0;
pub mod parallel_cpu;
pub mod pipeline;
pub mod reversed;
pub mod schedule;
pub mod serial;

use crate::graph::adj::AdjMatrix;
use crate::graph::sepset::SepSets;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Which schedule runs the level loop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Variant {
    /// single-threaded CPU reference (pcalg "Stable.fast" analog)
    Serial,
    /// multi-threaded CPU (paper's "Parallel-PC" analog)
    ParallelCpu,
    /// cuPC-E (Algorithm 4)
    CupcE,
    /// cuPC-S (Algorithm 5)
    CupcS,
    /// Fig. 5 baseline 1: per-edge tests sequential (γ = 1)
    Baseline1,
    /// Fig. 5 baseline 2: per-edge tests fully parallel (γ = ∞)
    Baseline2,
    /// reversed-order pruning (arxiv 2109.04626): densest-first,
    /// descending combination order, one test in flight per edge
    Reversed,
}

impl Variant {
    /// Parse a CLI/manifest spelling against the top-level
    /// [`crate::family`] registry's alias lists (case-insensitive).
    /// Resolves PC families only — causal-order spellings (`lingam`)
    /// parse through `crate::family::parse` but return `None` here,
    /// so PC-specific layers reject them with a typed error instead of
    /// silently misrouting.
    pub fn parse(s: &str) -> Option<Variant> {
        crate::family::parse(s).and_then(|id| id.variant())
    }
}

/// Which CI-test backend evaluates batches for the GPU-schedule variants.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EngineKind {
    /// Pure-Rust mirror of the kernels (always available).
    Native,
    /// AOT Pallas/JAX kernels through the XLA PJRT runtime.
    Xla,
}

/// How v-structures are decided in the orientation step.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OrientRule {
    /// first-found sepset per removed edge (classic PC-stable; fast,
    /// but the CPDAG can depend on the schedule)
    Standard,
    /// majority vote over a census of separating sets (Colombo &
    /// Maathuis MPC; schedule-invariant CPDAG)
    Majority,
}

/// Consulted by the batched schedules **between levels** to re-lease the
/// worker width (the ROADMAP "dynamic lease resizing" item): before each
/// level ℓ ≥ 1 the schedule asks the policy for the width to run that
/// level at, so a long tail level can absorb workers that other jobs in
/// a batch have released instead of holding its initial grant for the
/// whole run. The batch service wires this to
/// [`crate::service::ElasticLease`]; level 0 runs at the initial width
/// (the lease taken before the job started). The orientation phase
/// consults the hook once more — with `level = levels.len()`, the
/// "level after the last" — before building the CPDAG, for every
/// variant (see `crate::api::pc_stable_corr`).
///
/// Width changes can only move work between threads, never change what
/// is computed: the pipeline's ordered-apply stage keeps every schedule
/// bit-identical for *any* width sequence (gated by
/// `tests/batch_runner.rs::pathological_re_lease_schedules_are_bit_identical`).
pub trait WidthPolicy: Send + Sync {
    /// Width to run level `level` at (callers clamp to ≥ 1).
    fn width_for_level(&self, level: usize) -> usize;
}

/// Cloneable, Debug-opaque carrier for a [`WidthPolicy`] inside
/// [`Config`] (the policy itself usually holds live scheduler state, so
/// it cannot derive `Debug`).
#[derive(Clone)]
pub struct WidthHook(pub Arc<dyn WidthPolicy>);

impl std::fmt::Debug for WidthHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("WidthHook(..)")
    }
}

/// Which adjacency representation the level-loop driver runs on (see
/// [`crate::oocore::sparse::Adj`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdjMode {
    /// decide after level 0: sparse when the graph is large and the
    /// level-0 survivor density is at or below 25%, dense otherwise
    Auto,
    /// always the dense matrix (the pre-out-of-core behavior)
    Dense,
    /// always the CSR adjacency (test/benchmark forcing)
    Sparse,
}

/// Out-of-core knobs. Every setting is a memory/granularity trade-off
/// only: results are bit-identical for any value (gated by
/// `tests/oocore_conformance.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OocConfig {
    pub adjacency: AdjMode,
    /// max combination windows buffered per streamed chunk
    pub window_runs: usize,
    /// max CI-test slots per streamed chunk (also the cross-process
    /// chunk granularity under `cupc shard`)
    pub window_slots: u64,
}

impl Default for OocConfig {
    fn default() -> Self {
        // sized so typical rounds fit one chunk: the single-process
        // default path then shards exactly the same run list per round
        // as the pre-streaming driver did
        OocConfig {
            adjacency: AdjMode::Auto,
            window_runs: 1 << 16,
            window_slots: 1 << 20,
        }
    }
}

/// Run configuration. The β/γ (cuPC-E) and θ/δ (cuPC-S) knobs carry the
/// paper's meaning translated to the batch engine: γ = conditioning sets
/// in flight per edge per round, θ×δ = conditioning sets in flight per
/// row per round. β (edges per CUDA block) is kept for CLI/experiment
/// parity but is order-neutral here: β-groups were always packed
/// consecutively, so the slot order equals flat edge order and only
/// γ shapes the rounds.
#[derive(Clone, Debug)]
pub struct Config {
    pub alpha: f64,
    /// hard cap on the level loop (None: run to the PC-stable stop rule)
    pub max_level: Option<usize>,
    pub variant: Variant,
    pub engine: EngineKind,
    /// Worker threads. `ParallelCpu` shards rows across this many
    /// threads; the batched schedules (`CupcE`, `CupcS`, `Reversed` and
    /// the Fig. 5 baselines) shard each round's pack + evaluate stage across this
    /// many scoped workers when the native engine is selected (see
    /// [`pipeline`]) — results are bit-identical for any value. With an
    /// injected/XLA engine the batched schedules run single-engine and
    /// this knob is ignored. The orientation phase
    /// (`crate::orient`) always runs through the pooled pipeline at
    /// this width, for **every** variant and engine — CPDAGs are
    /// bit-identical for any value there too.
    pub threads: usize,
    pub beta: usize,
    pub gamma: usize,
    pub theta: usize,
    pub delta: usize,
    pub artifacts_dir: PathBuf,
    /// print per-level progress to stderr
    pub verbose: bool,
    /// v-structure decision rule for the orientation step
    pub orient: OrientRule,
    /// Optional between-level re-lease policy: when set, the batched
    /// schedules consult it before each level ℓ ≥ 1 and run the level at
    /// the returned width (see [`WidthPolicy`]). `None` (the default)
    /// keeps `threads` fixed for the whole run.
    pub width_hook: Option<WidthHook>,
    /// Out-of-core knobs (adjacency representation, streamed-window
    /// budgets). Purely a memory trade-off: results are bit-identical
    /// for any setting, so cache keys ignore it.
    pub ooc: OocConfig,
    /// Which native CI-test kernel evaluates packed batches (see
    /// `stats::kernels` and `docs/NUMERICS.md`). Defaults to the
    /// `CUPC_KERNEL` env selection (blocked when unset). Like
    /// `threads`/`ooc`, this is bitwise-neutral — both kernels produce
    /// identical output — so cache keys ignore it.
    pub kernel: crate::stats::kernels::KernelKind,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            alpha: 0.01,
            max_level: None,
            variant: Variant::CupcS,
            engine: EngineKind::Native,
            threads: available_threads(),
            // paper-selected configs: cuPC-E-2-32 and cuPC-S-64-2
            beta: 2,
            gamma: 32,
            theta: 64,
            delta: 2,
            artifacts_dir: PathBuf::from("artifacts"),
            verbose: false,
            orient: OrientRule::Standard,
            width_hook: None,
            ooc: OocConfig::default(),
            kernel: crate::stats::kernels::KernelKind::from_env(),
        }
    }
}

impl Config {
    /// Copy of this config with the worker-thread count replaced — the
    /// batch service's thread-budget handoff: `service::scheduler` leases
    /// workers from one global [`service::ThreadBudget`] shared by every
    /// in-flight job and runs each job's internal [`pipeline`] at the
    /// leased width. Results are unaffected by construction (the
    /// pipeline's thread-count invariance), so the lease size is purely
    /// a throughput knob.
    ///
    /// [`service::ThreadBudget`]: crate::service::ThreadBudget
    pub fn with_threads(&self, threads: usize) -> Config {
        Config {
            threads: threads.max(1),
            ..self.clone()
        }
    }
}

pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|x| x.get())
        .unwrap_or(1)
}

/// Per-level bookkeeping (drives Fig. 6 and the EXPERIMENTS tables).
#[derive(Clone, Debug, Default)]
pub struct LevelStats {
    pub level: usize,
    /// CI tests actually evaluated
    pub tests: u64,
    /// edges removed in this level
    pub removed: usize,
    /// edges remaining after the level
    pub edges_after: usize,
    /// wall-clock seconds including compaction overheads (as the paper
    /// measures: "the reported runtime of every level includes all the
    /// corresponding overheads such as forming A'_G")
    pub seconds: f64,
}

/// Out-of-core observability for one skeleton run: which adjacency
/// representation the level loop selected and how large the streamed
/// run buffer peaked. Surfaced per job in the batch/serve stats sidecar
/// so the bounded-memory claim is checkable from the outside.
#[derive(Clone, Copy, Debug)]
pub struct OocStats {
    /// "dense" | "sparse" (stable spellings — CI greps these)
    pub adjacency: &'static str,
    /// peak bytes held by the streamed window buffer
    pub peak_window_bytes: u64,
}

impl Default for OocStats {
    fn default() -> Self {
        OocStats {
            adjacency: "dense",
            peak_window_bytes: 0,
        }
    }
}

/// Output of skeleton discovery.
pub struct SkeletonResult {
    pub graph: AdjMatrix,
    pub sepsets: SepSets,
    pub levels: Vec<LevelStats>,
    pub ooc: OocStats,
}

impl SkeletonResult {
    pub fn total_seconds(&self) -> f64 {
        self.levels.iter().map(|l| l.seconds).sum()
    }

    pub fn total_tests(&self) -> u64 {
        self.levels.iter().map(|l| l.tests).sum()
    }
}

/// The PC-stable stop rule (Algorithm 1 line 17): continue while the
/// maximum degree − 1 ≥ next level, plus the optional user cap.
pub fn should_continue(graph: &AdjMatrix, next_level: usize, cfg: &Config) -> bool {
    should_continue_any(graph.max_degree(), next_level, cfg)
}

/// The stop rule on a bare max-degree — shared by every adjacency
/// representation (the out-of-core driver asks it through
/// [`crate::oocore::sparse::Adj::max_degree`], the dense paths through
/// [`should_continue`]).
pub fn should_continue_any(max_degree: usize, next_level: usize, cfg: &Config) -> bool {
    if let Some(cap) = cfg.max_level {
        if next_level > cap {
            return false;
        }
    }
    max_degree > next_level
}

/// The trivial result for degenerate inputs (n < 2): no pairs exist, so
/// every schedule returns an edgeless graph, no sepsets, and a single
/// zero-test level-0 entry without touching an engine. Shared by every
/// schedule entry point so `n = 0` / `n = 1` can never reach the pair
/// enumeration (whose `n·(n−1)/2` capacity math underflows on `n = 0`).
pub fn degenerate_result(n: usize) -> SkeletonResult {
    debug_assert!(n < 2);
    SkeletonResult {
        graph: AdjMatrix::complete(n),
        sepsets: SepSets::new(),
        levels: vec![LevelStats {
            level: 0,
            ..LevelStats::default()
        }],
        ooc: OocStats::default(),
    }
}

/// Dispatch a full skeleton run on a correlation matrix.
///
/// `corr` is row-major n×n, `m` the sample count behind it.
pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    if n < 2 {
        return Ok(degenerate_result(n));
    }
    (family::of(cfg.variant).run)(corr, n, m, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parsing() {
        assert_eq!(Variant::parse("cups"), Some(Variant::CupcS));
        assert_eq!(Variant::parse("CUPC-E"), Some(Variant::CupcE));
        assert_eq!(Variant::parse("serial"), Some(Variant::Serial));
        assert_eq!(Variant::parse("b2"), Some(Variant::Baseline2));
        assert_eq!(Variant::parse("reversed"), Some(Variant::Reversed));
        assert_eq!(Variant::parse("rop"), Some(Variant::Reversed));
        assert_eq!(Variant::parse("nope"), None);
    }

    #[test]
    fn default_config_is_paper_selected() {
        let c = Config::default();
        assert_eq!((c.beta, c.gamma), (2, 32));
        assert_eq!((c.theta, c.delta), (64, 2));
        assert_eq!(c.alpha, 0.01);
    }

    /// Regression: `n = 0` used to underflow-panic in debug builds in
    /// level 0's `n·(n−1)/2` capacity computation; n < 2 now
    /// short-circuits in every schedule.
    #[test]
    fn degenerate_inputs_are_guarded_in_every_variant() {
        for f in family::FAMILIES {
            let v = f.variant;
            for n in [0usize, 1] {
                let corr = vec![1.0; n * n];
                let cfg = Config {
                    variant: v,
                    ..Config::default()
                };
                let res = run(&corr, n, 10, &cfg)
                    .unwrap_or_else(|e| panic!("{v:?} failed on n={n}: {e:#}"));
                assert_eq!(res.graph.n(), n, "{v:?} n={n}");
                assert_eq!(res.graph.n_edges(), 0, "{v:?} n={n}");
                assert!(res.sepsets.is_empty(), "{v:?} n={n}");
                assert_eq!(res.levels.len(), 1, "{v:?} n={n}");
                assert_eq!(res.levels[0].tests, 0, "{v:?} n={n}");
                assert_eq!(res.total_tests(), 0, "{v:?} n={n}");
            }
        }
    }

    #[test]
    fn with_threads_replaces_only_the_width() {
        let base = Config {
            alpha: 0.05,
            max_level: Some(3),
            variant: Variant::CupcE,
            ..Config::default()
        };
        let leased = base.with_threads(7);
        assert_eq!(leased.threads, 7);
        assert_eq!(leased.alpha, base.alpha);
        assert_eq!(leased.max_level, base.max_level);
        assert_eq!(leased.variant, base.variant);
        assert_eq!(base.with_threads(0).threads, 1, "a lease is never empty");
    }

    /// The width hook survives `with_threads` (the service sets both),
    /// and the opaque Debug impl keeps `Config: Debug` usable.
    #[test]
    fn width_hook_is_cloned_and_debug_opaque() {
        struct Fixed(usize);
        impl WidthPolicy for Fixed {
            fn width_for_level(&self, _level: usize) -> usize {
                self.0
            }
        }
        let cfg = Config {
            width_hook: Some(WidthHook(Arc::new(Fixed(3)))),
            ..Config::default()
        };
        let leased = cfg.with_threads(2);
        let hook = leased.width_hook.as_ref().expect("hook survives");
        assert_eq!(hook.0.width_for_level(1), 3);
        assert!(format!("{leased:?}").contains("WidthHook"));
    }

    #[test]
    fn stop_rule() {
        let g = AdjMatrix::complete(4); // max degree 3
        let cfg = Config::default();
        assert!(should_continue(&g, 1, &cfg));
        assert!(should_continue(&g, 2, &cfg));
        assert!(!should_continue(&g, 3, &cfg));
        let capped = Config {
            max_level: Some(1),
            ..Config::default()
        };
        assert!(!should_continue(&g, 2, &capped));
    }
}
