//! cuPC-E (paper Algorithm 4, §3.3) as a batched schedule.
//!
//! The CUDA grid of `n × n'/β` blocks with `γ × β` threads becomes a
//! *round* structure: in round r, every live edge (i, j) contributes its
//! conditioning sets with indices `t ∈ [r·γ, (r+1)·γ)` — γ tests in
//! flight per edge, the paper's first degree of parallelism — while all
//! edges contribute simultaneously — the second degree. Edges are packed
//! in groups of β (the block shape), batches flush at the engine's
//! capacity, and verdicts apply before the next round, which reproduces
//! cuPC-E's early-termination semantics (§4.1 cases: removed edges are
//! skipped at pack time; within a flight the first verdict wins):
//! γ = 1 avoids all unnecessary tests but serializes; γ = ∞ is fully
//! parallel but wasteful — the baselines of Fig. 5.

use super::batch::{Corr32, EBatch};
use super::comb::{n_sets_edge, CombRangeSkip};
use super::engine::CiEngine;
use super::level0::run_level0;
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

/// One live edge's combination cursor within a level.
struct EdgeTask {
    i: u32,
    j: u32,
    /// position of j inside row i of G'
    p: u32,
    /// n'_i
    row_len: u32,
    /// C(n'_i − 1, ℓ)
    total: u64,
}

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    let mut engine = crate::runtime::engine_from_config(cfg)?;
    run_with_engine(corr, n, m, cfg, engine.as_mut())
}

pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let corr32 = Corr32::from_f64(corr, n);
    let mut levels = Vec::new();

    levels.push(run_level0(corr, n, m, cfg, engine, &graph, &sepsets)?);

    let gamma = cfg.gamma.max(1) as u64;
    let beta = cfg.beta.max(1);
    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);

        // Build the edge-task list from G' (ordered pairs, row-major —
        // the same visit order as the CUDA grid).
        let mut tasks: Vec<EdgeTask> = Vec::new();
        for i in 0..n {
            let row = comp.row(i);
            let nr = row.len();
            if nr < l + 1 {
                continue; // §4.1 case I
            }
            let total = n_sets_edge(nr, l);
            if total == 0 {
                continue;
            }
            for (p, &j) in row.iter().enumerate() {
                tasks.push(EdgeTask {
                    i: i as u32,
                    j,
                    p: p as u32,
                    row_len: nr as u32,
                    total,
                });
            }
        }

        let mut tests = 0u64;
        let mut removed = 0usize;
        let mut batch = EBatch::new(l, engine.batch_e());
        let mut ids = vec![0u32; l];
        let max_total = tasks.iter().map(|e| e.total).max().unwrap_or(0);
        let mut round = 0u64;
        while round * gamma < max_total {
            let lo = round * gamma;
            // β-grouped pass over the tasks (pack order = block shape)
            for group in tasks.chunks(beta) {
                for task in group {
                    if lo >= task.total {
                        continue; // this edge's sets are exhausted
                    }
                    let (i, j) = (task.i as usize, task.j as usize);
                    if !graph.has_edge(i, j) {
                        continue; // removed earlier: skip at pack time
                    }
                    let hi = ((round + 1) * gamma).min(task.total);
                    let row = comp.row(i);
                    let mut combs =
                        CombRangeSkip::new(task.row_len as usize, l, lo, hi - lo, task.p as usize);
                    while let Some(sbuf) = combs.next_comb() {
                        for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                            *dst = row[pos as usize];
                        }
                        batch.push(&corr32, i, j, &ids);
                        tests += 1;
                        if batch.len() >= engine.batch_e() {
                            removed += flush(&mut batch, engine, taul, &graph, &sepsets)?;
                        }
                    }
                }
            }
            // end of round: everything in flight lands before round r+1
            if !batch.is_empty() {
                removed += flush(&mut batch, engine, taul, &graph, &sepsets)?;
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[cupc-e] level {l}: {tests} tests, removed {removed}, {} edges left",
                graph.n_edges()
            );
        }
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
    })
}

fn flush(
    batch: &mut EBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> Result<usize> {
    let z = engine.ci_e(batch.l, batch.len(), &batch.c_ij, &batch.m1, &batch.m2)?;
    let (removed, _moot) = batch.apply(&z, taul, graph, sepsets);
    batch.clear();
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_on_er_graph() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_e = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let res_s = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_e.graph.snapshot(),
            res_s.graph.snapshot(),
            "cuPC-E must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn gamma_tradeoff_wastes_tests_but_same_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 100,
            topology: datasets::Topology::Er(0.1),
            seed: 13,
        });
        let c = correlation_matrix(&ds.data, 1);
        let lo = Config {
            gamma: 1,
            ..Config::default()
        };
        let hi = Config {
            gamma: 512,
            ..Config::default()
        };
        let r_lo = run_native(&c, ds.data.n, ds.data.m, &lo);
        let r_hi = run_native(&c, ds.data.n, ds.data.m, &hi);
        assert_eq!(r_lo.graph.snapshot(), r_hi.graph.snapshot());
        assert!(
            r_hi.total_tests() >= r_lo.total_tests(),
            "larger flights cannot test less: {} vs {}",
            r_hi.total_tests(),
            r_lo.total_tests()
        );
    }
}
