//! cuPC-E (paper Algorithm 4, §3.3) as a batched schedule.
//!
//! The CUDA grid of `n × n'/β` blocks with `γ × β` threads becomes a
//! *round* structure: in round r, every live edge (i, j) contributes its
//! conditioning sets with indices `t ∈ [r·γ, (r+1)·γ)` — γ tests in
//! flight per edge, the paper's first degree of parallelism — while all
//! edges contribute simultaneously — the second degree. Each round runs
//! the three-stage [`pipeline`](super::pipeline): the live windows are
//! listed serially in canonical edge order, packed and evaluated in
//! parallel shards (the graph is frozen for the whole flight, exactly
//! the in-kernel semantics), and the verdicts land in canonical slot
//! order before round r + 1 — which reproduces cuPC-E's
//! early-termination semantics (§4.1 cases: edges removed in earlier
//! rounds are skipped at pack time; within a flight the first verdict
//! wins): γ = 1 avoids all unnecessary tests but serializes; γ = ∞ is
//! fully parallel but wasteful — the baselines of Fig. 5. (β grouping is
//! order-neutral in the batched schedule: groups are packed
//! consecutively, so the slot order equals flat edge order.)

use super::batch::{Corr32, EBatch, Removals};
use super::comb::{n_sets_edge, CombRangeSkip};
use super::engine::CiEngine;
use super::pipeline::{use_pool, Executor, Run};
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

/// One live edge's combination cursor within a level.
struct EdgeTask {
    i: u32,
    j: u32,
    /// position of j inside row i of G'
    p: u32,
    /// n'_i
    row_len: u32,
    /// C(n'_i − 1, ℓ)
    total: u64,
}

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    if use_pool(cfg) {
        run_impl(corr, n, m, cfg, &mut Executor::Pool { threads: cfg.threads })
    } else {
        let mut engine = crate::runtime::engine_from_config(cfg)?;
        run_impl(corr, n, m, cfg, &mut Executor::Single(engine.as_mut()))
    }
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// pipeline inline — results are bit-identical to the pool path.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    run_impl(corr, n, m, cfg, &mut Executor::Single(engine))
}

fn run_impl(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    exec: &mut Executor<'_>,
) -> Result<SkeletonResult> {
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let corr32 = Corr32::from_f64(corr, n);
    let mut levels = Vec::new();

    levels.push(exec.run_level0(corr, n, m, cfg, &graph, &sepsets)?);

    let gamma = cfg.gamma.max(1) as u64;
    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        // between-level re-lease point: a hooked job asks its width
        // policy (e.g. the batch scheduler's elastic lease) how wide to
        // run this level — absorbing workers other jobs released. Width
        // never changes results (ordered apply), only wall-clock time.
        if let Some(hook) = &cfg.width_hook {
            exec.set_width(hook.0.width_for_level(l));
        }
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);

        // Build the edge-task list from G' (ordered pairs, row-major —
        // the same visit order as the CUDA grid).
        let mut tasks: Vec<EdgeTask> = Vec::new();
        for i in 0..n {
            let row = comp.row(i);
            let nr = row.len();
            if nr < l + 1 {
                continue; // §4.1 case I
            }
            let total = n_sets_edge(nr, l);
            if total == 0 {
                continue;
            }
            for (p, &j) in row.iter().enumerate() {
                tasks.push(EdgeTask {
                    i: i as u32,
                    j,
                    p: p as u32,
                    row_len: nr as u32,
                    total,
                });
            }
        }

        let mut tests = 0u64;
        let mut removed = 0usize;
        let max_total = tasks.iter().map(|e| e.total).max().unwrap_or(0);
        let mut runs: Vec<Run> = Vec::new();
        let mut round = 0u64;
        while round * gamma < max_total {
            let lo = round * gamma;
            // stage 1 (serial): the round's live windows in canonical
            // pack order; the graph is frozen until the apply stage
            runs.clear();
            for (ti, task) in tasks.iter().enumerate() {
                if lo >= task.total {
                    continue; // this edge's sets are exhausted
                }
                if !graph.has_edge(task.i as usize, task.j as usize) {
                    continue; // removed in an earlier round
                }
                let hi = ((round + 1) * gamma).min(task.total);
                runs.push(Run { task: ti, t0: lo, count: hi - lo });
            }
            if runs.is_empty() {
                break; // every unexhausted window belongs to a dead edge
            }
            tests += runs.iter().map(|r| r.count).sum::<u64>();

            // stage 2 (parallel): pack + evaluate, engines per shard;
            // only independence candidates come back (dependent
            // verdicts are no-ops and are dropped with the gather)
            let shard_results = exec.run_sharded(&runs, |shard, engine| {
                pack_eval(shard, &tasks, &comp, &corr32, l, taul, engine)
            })?;

            // stage 3 (serial): everything in flight lands in canonical
            // slot order before round r + 1
            for candidates in &shard_results {
                removed += candidates.apply(&graph, &sepsets);
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[cupc-e] level {l}: {tests} tests, removed {removed}, {} edges left",
                graph.n_edges()
            );
        }
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
    })
}

/// Worker body: pack a shard of the round's combination windows into
/// engine-capacity batches, evaluate them, and keep only the
/// independence candidates (canonical slot order).
fn pack_eval(
    shard: &[Run],
    tasks: &[EdgeTask],
    comp: &CompactAdj,
    corr32: &Corr32,
    l: usize,
    taul: f64,
    engine: &mut dyn CiEngine,
) -> Result<Removals> {
    let cap = engine.batch_e().max(1);
    let mut out = Removals::new(l);
    let mut batch = EBatch::new(l, cap);
    let mut ids = vec![0u32; l];
    for run in shard {
        let task = &tasks[run.task];
        let (i, j) = (task.i as usize, task.j as usize);
        let row = comp.row(i);
        let mut combs =
            CombRangeSkip::new(task.row_len as usize, l, run.t0, run.count, task.p as usize);
        while let Some(sbuf) = combs.next_comb() {
            for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                *dst = row[pos as usize];
            }
            batch.push(corr32, i, j, &ids);
            if batch.len() >= cap {
                flush(&mut batch, engine, taul, &mut out)?;
            }
        }
    }
    if !batch.is_empty() {
        flush(&mut batch, engine, taul, &mut out)?;
    }
    Ok(out)
}

fn flush(
    batch: &mut EBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    out: &mut Removals,
) -> Result<()> {
    let z = engine.ci_e(batch.l, batch.len(), &batch.c_ij, &batch.m1, &batch.m2)?;
    batch.drain_independent(&z, taul, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::skeleton::EngineKind;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_on_er_graph() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_e = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let res_s = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_e.graph.snapshot(),
            res_s.graph.snapshot(),
            "cuPC-E must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn gamma_tradeoff_wastes_tests_but_same_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 100,
            topology: datasets::Topology::Er(0.1),
            seed: 13,
        });
        let c = correlation_matrix(&ds.data, 1);
        let lo = Config {
            gamma: 1,
            ..Config::default()
        };
        let hi = Config {
            gamma: 512,
            ..Config::default()
        };
        let r_lo = run_native(&c, ds.data.n, ds.data.m, &lo);
        let r_hi = run_native(&c, ds.data.n, ds.data.m, &hi);
        assert_eq!(r_lo.graph.snapshot(), r_hi.graph.snapshot());
        assert!(
            r_hi.total_tests() >= r_lo.total_tests(),
            "larger flights cannot test less: {} vs {}",
            r_hi.total_tests(),
            r_lo.total_tests()
        );
    }

    /// The tentpole determinism contract at module level: the pool path
    /// must be bit-identical to the single-engine path, including
    /// per-level test counts.
    #[test]
    fn pool_path_matches_single_engine_bitwise() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 48,
            m: 200,
            topology: datasets::Topology::Er(0.12),
            seed: 17,
        });
        let c = correlation_matrix(&ds.data, 1);
        let pooled_cfg = Config {
            variant: super::super::Variant::CupcE,
            engine: EngineKind::Native,
            threads: 4,
            ..Config::default()
        };
        assert!(use_pool(&pooled_cfg));
        let pooled = run(&c, ds.data.n, ds.data.m, &pooled_cfg).unwrap();
        let single = run_native(&c, ds.data.n, ds.data.m, &pooled_cfg);
        assert_eq!(pooled.graph.snapshot(), single.graph.snapshot());
        assert_eq!(
            pooled.sepsets.sorted_entries(),
            single.sepsets.sorted_entries(),
            "sepset contents must be thread-count invariant"
        );
        let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
            r.levels
                .iter()
                .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                .collect()
        };
        assert_eq!(stats(&pooled), stats(&single));
    }
}
