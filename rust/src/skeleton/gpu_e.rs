//! cuPC-E (paper Algorithm 4, §3.3) as a batched [`RoundSchedule`].
//!
//! The CUDA grid of `n × n'/β` blocks with `γ × β` threads becomes a
//! *round* structure: in round r, every live edge (i, j) contributes its
//! conditioning sets with indices `t ∈ [r·γ, (r+1)·γ)` — γ tests in
//! flight per edge, the paper's first degree of parallelism — while all
//! edges contribute simultaneously — the second degree. Each round runs
//! the three-stage [`pipeline`](super::pipeline) via the
//! [`schedule`](super::schedule) driver: the live windows are listed
//! serially in canonical edge order, packed and evaluated in parallel
//! shards (the graph is frozen for the whole flight, exactly the
//! in-kernel semantics), and the verdicts land in canonical slot order
//! before round r + 1 — which reproduces cuPC-E's early-termination
//! semantics (§4.1 cases: edges removed in earlier rounds are skipped at
//! pack time; within a flight the first verdict wins): γ = 1 avoids all
//! unnecessary tests but serializes; γ = ∞ is fully parallel but
//! wasteful — the baselines of Fig. 5. (β grouping is order-neutral in
//! the batched schedule: groups are packed consecutively, so the slot
//! order equals flat edge order.)

use super::engine::CiEngine;
use super::pipeline::Run;
use super::schedule::{
    build_edge_tasks, eval_edge_shard, run_rounds, run_rounds_with_engine, EdgeTask, LevelCtx,
    RoundSchedule,
};
use super::{Config, SkeletonResult};
use crate::skeleton::batch::Removals;
use anyhow::Result;

/// The cuPC-E schedule: ascending combination windows of γ sets in
/// flight per live edge per round.
pub struct ESchedule {
    gamma: u64,
    tasks: Vec<EdgeTask>,
    max_total: u64,
}

impl ESchedule {
    pub fn new(cfg: &Config) -> ESchedule {
        ESchedule {
            // saturating arithmetic throughout: Baseline2 runs this
            // schedule at γ = usize::MAX / 2 (the "fully parallel" γ=∞)
            gamma: cfg.gamma.max(1) as u64,
            tasks: Vec::new(),
            max_total: 0,
        }
    }
}

impl RoundSchedule for ESchedule {
    fn label(&self) -> &'static str {
        "cupc-e"
    }

    fn begin_level(&mut self, ctx: &LevelCtx<'_>) {
        let (tasks, max_total) = build_edge_tasks(ctx);
        self.tasks = tasks;
        self.max_total = max_total;
    }

    fn rounds_done(&self, round: u64) -> bool {
        round.saturating_mul(self.gamma) >= self.max_total
    }

    fn visit_round(&self, ctx: &LevelCtx<'_>, round: u64, emit: &mut dyn FnMut(Run)) {
        let lo = round.saturating_mul(self.gamma);
        for (ti, task) in self.tasks.iter().enumerate() {
            if lo >= task.total {
                continue; // this edge's sets are exhausted
            }
            if !ctx.graph.has_edge(task.i as usize, task.j as usize) {
                continue; // removed in an earlier round
            }
            let hi = round
                .saturating_add(1)
                .saturating_mul(self.gamma)
                .min(task.total);
            emit(Run { task: ti, t0: lo, count: hi - lo });
        }
    }

    fn eval_shard(
        &self,
        ctx: &LevelCtx<'_>,
        shard: &[Run],
        engine: &mut dyn CiEngine,
    ) -> Result<(Removals, u64)> {
        eval_edge_shard(&self.tasks, ctx, shard, engine)
    }
}

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    run_rounds(corr, n, m, cfg, &mut ESchedule::new(cfg))
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// pipeline inline — results are bit-identical to the pool path.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    run_rounds_with_engine(corr, n, m, cfg, &mut ESchedule::new(cfg), engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::skeleton::pipeline::use_pool;
    use crate::skeleton::EngineKind;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_on_er_graph() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_e = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let res_s = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_e.graph.snapshot(),
            res_s.graph.snapshot(),
            "cuPC-E must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn gamma_tradeoff_wastes_tests_but_same_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 100,
            topology: datasets::Topology::Er(0.1),
            seed: 13,
        });
        let c = correlation_matrix(&ds.data, 1);
        let lo = Config {
            gamma: 1,
            ..Config::default()
        };
        let hi = Config {
            gamma: 512,
            ..Config::default()
        };
        let r_lo = run_native(&c, ds.data.n, ds.data.m, &lo);
        let r_hi = run_native(&c, ds.data.n, ds.data.m, &hi);
        assert_eq!(r_lo.graph.snapshot(), r_hi.graph.snapshot());
        assert!(
            r_hi.total_tests() >= r_lo.total_tests(),
            "larger flights cannot test less: {} vs {}",
            r_hi.total_tests(),
            r_lo.total_tests()
        );
    }

    /// The tentpole determinism contract at module level: the pool path
    /// must be bit-identical to the single-engine path, including
    /// per-level test counts.
    #[test]
    fn pool_path_matches_single_engine_bitwise() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 48,
            m: 200,
            topology: datasets::Topology::Er(0.12),
            seed: 17,
        });
        let c = correlation_matrix(&ds.data, 1);
        let pooled_cfg = Config {
            variant: super::super::Variant::CupcE,
            engine: EngineKind::Native,
            threads: 4,
            ..Config::default()
        };
        assert!(use_pool(&pooled_cfg));
        let pooled = run(&c, ds.data.n, ds.data.m, &pooled_cfg).unwrap();
        let single = run_native(&c, ds.data.n, ds.data.m, &pooled_cfg);
        assert_eq!(pooled.graph.snapshot(), single.graph.snapshot());
        assert_eq!(
            pooled.sepsets.sorted_entries(),
            single.sepsets.sorted_entries(),
            "sepset contents must be thread-count invariant"
        );
        let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
            r.levels
                .iter()
                .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                .collect()
        };
        assert_eq!(stats(&pooled), stats(&single));
    }
}
