//! The multi-threaded pack → evaluate → apply pipeline shared by the
//! batched schedules (cuPC-E, cuPC-S, the Fig. 5 baselines, and
//! reversed-order pruning). The [`Executor`] is schedule-agnostic: which
//! windows exist in a round and how a shard is packed belong to the
//! [`RoundSchedule`](super::schedule::RoundSchedule) strategy; this
//! module only splits, runs, and re-orders.
//!
//! cuPC's speedup story is the parallel CI-test grid; with AOT batch
//! kernels the CUDA grid becomes *rounds* (gpu_e/gpu_s), and the per-slot
//! work — combination enumeration plus the M1/M2 gather — is the CPU-side
//! hot spot. This module shards that work across scoped worker threads
//! (no external deps) while keeping every schedule bit-deterministic:
//!
//! 1. **Stage 1 (serial, O(#tasks))** — the schedule lists the round's
//!    live combination windows as [`Run`]s in canonical pack order. The
//!    graph is read here and then *frozen* until stage 3.
//! 2. **Stage 2 (parallel)** — [`Executor::run_sharded`] splits the runs
//!    into contiguous shards balanced by slot count; each worker packs
//!    its shard into thread-local batches, evaluates them through its own
//!    [`NativeEngine`], and keeps only the *independence candidates*
//!    (slots whose |z| ≤ τ) — dependent verdicts can never change state,
//!    so they are dropped with the heavy M1/M2 buffers per flush,
//!    bounding a round's deferred-apply memory at the candidate count
//!    rather than the test count.
//! 3. **Stage 3 (serial)** — candidates are applied in canonical slot
//!    order (shards concatenated in order), so "first independent
//!    verdict wins" resolves identically for every thread count.
//!
//! Determinism contract: CI evaluation is a pure function of the packed
//! slot, and the adjacency is only mutated in stage 3, so skeletons,
//! sepset contents, per-level removed/edges_after *and* per-level test
//! counts are bit-identical for `threads = 1` and `threads = N`. Batch
//! capacity and shard boundaries affect only wall-clock time. The
//! cross-engine conformance suite pins this down
//! (`tests/conformance_engines.rs::batched_schedules_are_thread_count_invariant`).
//!
//! Engines that cannot be constructed per worker (the XLA PJRT engine
//! owns client state) keep the single-engine path: [`Executor::Single`]
//! runs the identical pipeline inline with the injected engine.
//!
//! The executor is not skeleton-specific: the orientation pipeline
//! (`crate::orient`) dispatches its unshielded-triple enumeration,
//! majority-census CI batches ([`Executor::run_sharded`] windows) and
//! per-sweep Meek rule checks ([`Executor::run_weighted`] atomic tasks)
//! through the same pool, width hooks included — so a batch job's
//! elastic lease covers orientation too.

use super::engine::{CiEngine, NativeEngine};
use super::level0::{apply_candidates, eval_range, n_pairs, run_level0};
use super::{Config, EngineKind, LevelStats};
use crate::graph::adj::AdjMatrix;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::stats::kernels::KernelKind;
use crate::util::timer::Timer;
use anyhow::Result;

/// A contiguous chunk of one task's combination range within a round:
/// combination indices `[t0, t0 + count)` of the task at index `task` in
/// the round's task list. Slots inside a run follow lexicographic
/// combination order and runs are emitted in canonical task order, so
/// the concatenation of all runs *is* the round's canonical slot order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    pub task: usize,
    pub t0: u64,
    pub count: u64,
}

/// Minimum slots per worker shard: below this, spawning a thread costs
/// more than the gather it parallelizes. Never affects results.
pub const MIN_SHARD_SLOTS: u64 = 512;

/// Does this config take the worker-pool path? Per-worker engines are
/// only constructible for the native backend; injected engines (XLA)
/// run the identical pipeline single-engine. A width hook forces the
/// pool even at `threads = 1`: a job that starts narrow under a
/// contended budget must be able to widen between levels, and only the
/// pool path can change width ([`Executor::set_width`]).
pub fn use_pool(cfg: &Config) -> bool {
    cfg.engine == EngineKind::Native && (cfg.threads > 1 || cfg.width_hook.is_some())
}

/// Partition `runs` into at most `parts` contiguous shards balanced by
/// slot count, splitting a run mid-range where a boundary falls inside
/// it. Shard boundaries never affect results (evaluation is pure and the
/// apply stage replays canonical order) — only load balance.
pub fn split_runs(runs: &[Run], parts: usize) -> Vec<Vec<Run>> {
    let total: u64 = runs.iter().map(|r| r.count).sum();
    if total == 0 {
        return Vec::new();
    }
    let max_parts = total.div_ceil(MIN_SHARD_SLOTS).max(1);
    let parts = (parts.max(1) as u64).min(max_parts);
    let per = total.div_ceil(parts);
    let mut shards: Vec<Vec<Run>> = Vec::with_capacity(parts as usize);
    let mut cur: Vec<Run> = Vec::new();
    let mut cur_slots = 0u64;
    for &run in runs {
        let mut rest = run;
        loop {
            let room = per - cur_slots;
            if rest.count <= room {
                cur_slots += rest.count;
                cur.push(rest);
                break;
            }
            if room > 0 {
                cur.push(Run {
                    task: rest.task,
                    t0: rest.t0,
                    count: room,
                });
            }
            shards.push(std::mem::take(&mut cur));
            cur_slots = 0;
            rest = Run {
                task: rest.task,
                t0: rest.t0 + room,
                count: rest.count - room,
            };
        }
        if cur_slots == per {
            shards.push(std::mem::take(&mut cur));
            cur_slots = 0;
        }
    }
    if !cur.is_empty() {
        shards.push(cur);
    }
    shards
}

/// How a round's shards get evaluated.
pub enum Executor<'e> {
    /// One engine, inline: the `threads = 1` path and the path any
    /// injected engine (XLA, test mocks) uses.
    Single(&'e mut dyn CiEngine),
    /// Up to `threads` scoped workers, each owning a fresh
    /// [`NativeEngine`] (a few KiB of scratch — cheap per round)
    /// running the selected CI-test `kernel` (bitwise-neutral; see
    /// `stats::kernels`).
    Pool { threads: usize, kernel: KernelKind },
}

impl Executor<'_> {
    /// A worker pool at `threads` width running the env-selected kernel
    /// (`CUPC_KERNEL`, blocked when unset).
    pub fn pool<'e>(threads: usize) -> Executor<'e> {
        Executor::pool_with(threads, KernelKind::from_env())
    }

    /// A worker pool with an explicit kernel — the path `Config.kernel`
    /// takes, and what in-process kernel A/B tests use.
    pub fn pool_with<'e>(threads: usize, kernel: KernelKind) -> Executor<'e> {
        Executor::Pool { threads, kernel }
    }

    /// Current worker width (1 for the single-engine path).
    pub fn width(&self) -> usize {
        match self {
            Executor::Single(_) => 1,
            Executor::Pool { threads, .. } => *threads,
        }
    }

    /// Re-target the pool width for subsequent rounds — the between-level
    /// re-lease point ([`super::WidthPolicy`]). A no-op on the
    /// single-engine path (an injected engine cannot be replicated).
    /// Width only moves work between shards; results are bit-identical
    /// for any width sequence.
    pub fn set_width(&mut self, w: usize) {
        if let Executor::Pool { threads, .. } = self {
            *threads = w.max(1);
        }
    }

    /// Shard `runs` and evaluate every shard with `work`, returning the
    /// shard results in canonical shard order. `work` must be pure with
    /// respect to shared state (it may read the frozen graph).
    pub fn run_sharded<T, F>(&mut self, runs: &[Run], work: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&[Run], &mut dyn CiEngine) -> Result<T> + Sync,
    {
        match self {
            Executor::Single(engine) => Ok(vec![work(runs, &mut **engine)?]),
            Executor::Pool { threads, kernel } => {
                let kernel = *kernel;
                let shards = split_runs(runs, *threads);
                if shards.len() <= 1 {
                    // too little work to pay for a spawn
                    let mut engine = NativeEngine::with_kernel(kernel);
                    let shard = shards.first().map(|s| &s[..]).unwrap_or(&[]);
                    return Ok(vec![work(shard, &mut engine)?]);
                }
                let results: Vec<Result<T>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = shards
                        .iter()
                        .map(|shard| {
                            let work = &work;
                            scope.spawn(move || {
                                let mut engine = NativeEngine::with_kernel(kernel);
                                work(shard, &mut engine)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("pipeline worker panicked"))
                        .collect()
                });
                results.into_iter().collect()
            }
        }
    }

    /// Shard `weights.len()` *atomic* tasks across the pool, balanced by
    /// weight — the generalization the orientation pipeline uses for work
    /// units that cannot be split mid-task (a Meek rule check on one
    /// edge, say), where the weight is only a load-balance hint. Each
    /// worker receives the task *indices* assigned to its shard, in
    /// canonical order; concatenating the shard results in order restores
    /// canonical task order. A task whose weight straddles a shard
    /// boundary is executed exactly once, by the shard holding its
    /// weight-0 prefix (splits keep `t0 = 0` on the first piece).
    pub fn run_weighted<T, F>(&mut self, weights: &[u64], work: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&[usize], &mut dyn CiEngine) -> Result<T> + Sync,
    {
        let runs: Vec<Run> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| Run {
                task: i,
                t0: 0,
                count: w.max(1),
            })
            .collect();
        self.run_sharded(&runs, move |shard, engine| {
            let ids: Vec<usize> = shard
                .iter()
                .filter(|r| r.t0 == 0)
                .map(|r| r.task)
                .collect();
            work(&ids, engine)
        })
    }

    /// Level 0 through whichever engine the executor owns. The pool path
    /// shards the canonical pair sweep across the same workers the
    /// deeper levels use ([`eval_range`] windows, balanced by slot
    /// count) and applies the independence candidates serially in
    /// canonical order — bit-identical to the single-engine sweep, and
    /// sized so small inputs still collapse to one shard
    /// ([`MIN_SHARD_SLOTS`]).
    pub fn run_level0(
        &mut self,
        corr: &[f64],
        n: usize,
        m: usize,
        cfg: &Config,
        graph: &AdjMatrix,
        sepsets: &SepSets,
    ) -> Result<LevelStats> {
        if let Executor::Single(engine) = self {
            return run_level0(corr, n, m, cfg, &mut **engine, graph, sepsets);
        }
        let t = Timer::start();
        let total = n_pairs(n);
        if total == 0 {
            return Ok(LevelStats {
                level: 0,
                seconds: t.elapsed_s(),
                ..LevelStats::default()
            });
        }
        let tau0 = tau(m, 0, cfg.alpha);
        let runs = [Run {
            task: 0,
            t0: 0,
            count: total,
        }];
        let shard_results = self.run_sharded(&runs, |shard, engine| {
            let mut cands = Vec::new();
            for r in shard {
                cands.extend(eval_range(corr, n, tau0, r.t0, r.count, engine)?);
            }
            Ok(cands)
        })?;
        let mut removed = 0;
        for cands in &shard_results {
            removed += apply_candidates(graph, sepsets, cands);
        }
        Ok(LevelStats {
            level: 0,
            tests: total,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(shards: &[Vec<Run>]) -> Vec<u64> {
        shards
            .iter()
            .map(|s| s.iter().map(|r| r.count).sum())
            .collect()
    }

    fn flatten(shards: &[Vec<Run>]) -> Vec<(usize, u64)> {
        // expand to (task, t) slot list to check order preservation
        let mut v = Vec::new();
        for shard in shards {
            for r in shard {
                for t in r.t0..r.t0 + r.count {
                    v.push((r.task, t));
                }
            }
        }
        v
    }

    #[test]
    fn split_preserves_canonical_slot_order() {
        let runs = vec![
            Run { task: 0, t0: 0, count: 700 },
            Run { task: 1, t0: 3, count: 900 },
            Run { task: 2, t0: 0, count: 500 },
        ];
        let want = flatten(&[runs.clone()]);
        for parts in [1usize, 2, 3, 4, 7] {
            let shards = split_runs(&runs, parts);
            assert!(shards.len() <= parts.max(1), "parts={parts}");
            assert_eq!(flatten(&shards), want, "parts={parts}");
            for s in slots(&shards) {
                assert!(s > 0, "empty shard at parts={parts}");
            }
        }
    }

    #[test]
    fn split_balances_by_slot_count() {
        let runs = vec![
            Run { task: 0, t0: 0, count: 4000 },
            Run { task: 1, t0: 0, count: 50 },
        ];
        let shards = split_runs(&runs, 4);
        assert_eq!(shards.len(), 4);
        let s = slots(&shards);
        // ceil(4050/4) = 1013 per shard, last takes the remainder
        assert_eq!(s, vec![1013, 1013, 1013, 1011]);
        // the big run was split mid-range
        assert!(shards[0][0].count < 4000);
    }

    #[test]
    fn split_respects_min_shard_slots() {
        let runs = vec![Run { task: 0, t0: 0, count: 100 }];
        // far too little work for 8 shards: everything lands in one
        let shards = split_runs(&runs, 8);
        assert_eq!(shards.len(), 1);
        assert_eq!(slots(&shards), vec![100]);
    }

    #[test]
    fn split_empty_is_empty() {
        assert!(split_runs(&[], 4).is_empty());
        let zero = vec![Run { task: 0, t0: 0, count: 0 }];
        assert!(split_runs(&zero, 4).is_empty());
    }

    #[test]
    fn pool_selection_rules() {
        let mut cfg = Config {
            threads: 4,
            engine: EngineKind::Native,
            ..Config::default()
        };
        assert!(use_pool(&cfg));
        cfg.threads = 1;
        assert!(!use_pool(&cfg));
        cfg.threads = 4;
        cfg.engine = EngineKind::Xla;
        assert!(!use_pool(&cfg), "injected engines keep the single path");
        // a width hook forces the pool even at threads = 1 (the job may
        // widen between levels), but never for an injected engine
        struct Grow;
        impl crate::skeleton::WidthPolicy for Grow {
            fn width_for_level(&self, _l: usize) -> usize {
                4
            }
        }
        cfg.threads = 1;
        cfg.width_hook = Some(crate::skeleton::WidthHook(std::sync::Arc::new(Grow)));
        assert!(!use_pool(&cfg), "still single for XLA");
        cfg.engine = EngineKind::Native;
        assert!(use_pool(&cfg), "hooked native jobs must be resizable");
    }

    #[test]
    fn set_width_retargets_only_the_pool() {
        let mut pool = Executor::pool(2);
        assert_eq!(pool.width(), 2);
        pool.set_width(5);
        assert_eq!(pool.width(), 5);
        pool.set_width(0);
        assert_eq!(pool.width(), 1, "width is clamped to ≥ 1");
        let mut engine = NativeEngine::new();
        let mut single = Executor::Single(&mut engine);
        single.set_width(7);
        assert_eq!(single.width(), 1, "single path cannot widen");
    }

    #[test]
    fn executor_runs_every_shard_in_order() {
        let runs: Vec<Run> = (0..6)
            .map(|i| Run { task: i, t0: 0, count: 700 })
            .collect();
        let mut exec = Executor::pool(3);
        let got = exec
            .run_sharded(&runs, |shard, engine| {
                assert_eq!(engine.name(), "native");
                Ok(shard.to_vec())
            })
            .unwrap();
        let rejoined: Vec<Run> = got.into_iter().flatten().collect();
        assert_eq!(flatten(&[rejoined]), flatten(&[runs]));
    }

    /// Level 0 sharded through the pool must be bit-identical to the
    /// single-engine sweep: same removals, same (empty) sepsets, same
    /// test count. A large-ish n forces genuinely multiple shards
    /// (n_pairs must exceed MIN_SHARD_SLOTS).
    #[test]
    fn pool_level0_matches_single_engine() {
        use crate::util::rng::Pcg;
        let n = 64; // 2016 pairs > MIN_SHARD_SLOTS → real sharding
        assert!(super::super::level0::n_pairs(n) > MIN_SHARD_SLOTS);
        let mut rng = Pcg::seeded(41);
        let mut corr = vec![0.0; n * n];
        for i in 0..n {
            corr[i * n + i] = 1.0;
            for j in (i + 1)..n {
                let c = rng.uniform_in(-0.7, 0.7);
                corr[i * n + j] = c;
                corr[j * n + i] = c;
            }
        }
        let m = 150;
        let cfg = Config::default();
        let run_with = |exec: &mut Executor<'_>| {
            let graph = AdjMatrix::complete(n);
            let sepsets = SepSets::new();
            let stats = exec
                .run_level0(&corr, n, m, &cfg, &graph, &sepsets)
                .unwrap();
            (graph.snapshot(), sepsets.sorted_entries(), stats)
        };
        let mut engine = NativeEngine::new();
        let mut single = Executor::Single(&mut engine);
        let (snap_s, seps_s, stats_s) = run_with(&mut single);
        for threads in [2usize, 4] {
            let mut pool = Executor::pool(threads);
            let (snap_p, seps_p, stats_p) = run_with(&mut pool);
            assert_eq!(snap_p, snap_s, "threads={threads}");
            assert_eq!(seps_p, seps_s, "threads={threads}");
            assert_eq!(stats_p.tests, stats_s.tests);
            assert_eq!(stats_p.removed, stats_s.removed);
            assert_eq!(stats_p.edges_after, stats_s.edges_after);
        }
        assert!(stats_s.removed > 0, "workload must actually remove edges");
    }

    /// Weighted atomic tasks run exactly once each, in canonical order,
    /// for any pool width — even when a task's weight straddles a shard
    /// boundary (the split pieces with t0 > 0 must not re-execute it).
    #[test]
    fn run_weighted_executes_every_task_exactly_once_in_order() {
        // wildly unbalanced weights force mid-task splits at most widths
        let weights: Vec<u64> = vec![3000, 1, 1, 2000, 700, 1, 5000, 1];
        let want: Vec<usize> = (0..weights.len()).collect();
        for threads in [1usize, 2, 3, 4, 7] {
            let mut exec = Executor::pool(threads);
            let got = exec
                .run_weighted(&weights, |ids, _| Ok(ids.to_vec()))
                .unwrap();
            let flat: Vec<usize> = got.into_iter().flatten().collect();
            assert_eq!(flat, want, "threads={threads}");
        }
    }

    #[test]
    fn run_weighted_zero_weight_tasks_still_run() {
        let weights = vec![0u64; 5];
        let mut exec = Executor::pool(4);
        let got = exec
            .run_weighted(&weights, |ids, _| Ok(ids.to_vec()))
            .unwrap();
        let flat: Vec<usize> = got.into_iter().flatten().collect();
        assert_eq!(flat, vec![0, 1, 2, 3, 4]);
        // and an empty task list is a clean no-op
        let empty = Executor::pool(4)
            .run_weighted(&[], |ids: &[usize], _| Ok(ids.to_vec()))
            .unwrap();
        let flat: Vec<usize> = empty.into_iter().flatten().collect();
        assert!(flat.is_empty());
    }

    #[test]
    fn executor_propagates_worker_errors() {
        let runs: Vec<Run> = (0..4)
            .map(|i| Run { task: i, t0: 0, count: 600 })
            .collect();
        let mut exec = Executor::pool(4);
        let res: Result<Vec<()>> = exec.run_sharded(&runs, |shard, _| {
            if shard.iter().any(|r| r.task == 2) {
                anyhow::bail!("boom on task 2")
            }
            Ok(())
        });
        let err = res.expect_err("worker error must propagate");
        assert!(format!("{err:#}").contains("boom"));
    }
}
