//! Lexicographic combination indexing — the paper's Algorithm 6
//! (Buckles–Lybanon, TOMS algorithm 515).
//!
//! `comb_at(n, l, t)` returns the t-th combination (0-based values) of
//! choosing `l` elements from `{0..n-1}` in lexicographic order, without
//! enumerating. cuPC calls this per-thread to derive its conditioning set
//! on the fly; here the batch packers call it per batch slot, which keeps
//! the packer stateless and trivially shardable — the same property the
//! paper exploits.
//!
//! The cuPC-E variant `comb_at_skip` additionally skips a forbidden
//! position `p` (the index of Vj inside the row), matching §4.2's
//! "increment all values ≥ p".

/// Binomial coefficient with saturation (fits experiment scales; u128
/// intermediate to delay overflow).
pub fn binom(n: usize, k: usize) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    for i in 0..k {
        num = num * (n - i) as u128 / (i + 1) as u128;
        if num > u64::MAX as u128 {
            return u64::MAX;
        }
    }
    num as u64
}

/// t-th lexicographic l-combination of {0,..,n-1} into `out` (ascending).
/// Implements the paper's Algorithm 6 (1-based internally, shifted to
/// 0-based on output, exactly as §4.2 describes for cuPC-S).
///
/// Walking `t` over `0..binom(n, l)` enumerates every combination in
/// lexicographic order with no shared state — the property that lets
/// batch packers shard slots freely:
///
/// ```
/// use cupc::skeleton::comb::{binom, comb_at};
///
/// let mut out = [0u32; 2];
/// let all: Vec<[u32; 2]> = (0..binom(4, 2))
///     .map(|t| {
///         comb_at(4, 2, t, &mut out);
///         out
///     })
///     .collect();
/// assert_eq!(all, [[0, 1], [0, 2], [0, 3], [1, 2], [1, 3], [2, 3]]);
/// ```
pub fn comb_at(n: usize, l: usize, t: u64, out: &mut [u32]) {
    debug_assert!(l <= n, "comb_at: l={l} > n={n}");
    debug_assert!(t < binom(n, l), "comb_at: t={t} out of range");
    debug_assert_eq!(out.len(), l);
    let mut sum: u64 = 0;
    let mut prev: usize = 0; // O_t[c-1], 1-based value
    for c in 0..l {
        let mut v = prev; // O_t[c] starts from O_t[c-1]
        loop {
            v += 1;
            let add = binom(n - v, l - (c + 1));
            sum += add;
            if sum > t {
                sum -= add;
                break;
            }
        }
        out[c] = (v - 1) as u32; // shift to 0-based
        prev = v;
    }
}

/// cuPC-E variant: t-th combination of l elements drawn from row
/// positions {0..row_len-1} **excluding** position `p` (where Vj sits).
/// Equivalent to `comb_at(row_len - 1, l, t)` followed by incrementing
/// every value ≥ p (paper §4.2 last paragraph).
pub fn comb_at_skip(row_len: usize, l: usize, t: u64, p: usize, out: &mut [u32]) {
    comb_at(row_len - 1, l, t, out);
    for v in out.iter_mut() {
        if *v as usize >= p {
            *v += 1;
        }
    }
}

/// Iterator over a contiguous range of lexicographic combinations.
///
/// `comb_at` costs O(t · l) per call (the paper's GPU threads pay this
/// once per thread, in parallel); calling it per *test* in a sequential
/// packer is quadratic in the range length. The iterator seeds with one
/// `comb_at` and then advances by the O(1)-amortized lexicographic
/// successor — the §Perf hot-path fix for level-1-heavy workloads.
pub struct CombRange {
    n: usize,
    l: usize,
    cur: Vec<u32>,
    remaining: u64,
    fresh: bool,
}

impl CombRange {
    /// Combinations t ∈ [t0, t0 + count) of l elements from {0..n-1}.
    pub fn new(n: usize, l: usize, t0: u64, count: u64) -> Self {
        let mut cur = vec![0u32; l];
        if count > 0 {
            comb_at(n, l, t0, &mut cur);
        }
        CombRange {
            n,
            l,
            cur,
            remaining: count,
            fresh: true,
        }
    }

    /// Advance to the next combination; returns the current one or None.
    pub fn next_comb(&mut self) -> Option<&[u32]> {
        if self.remaining == 0 {
            return None;
        }
        if self.fresh {
            self.fresh = false;
        } else {
            // lexicographic successor: bump the rightmost bumpable digit
            let l = self.l;
            let mut c = l;
            loop {
                debug_assert!(c > 0, "advanced past the last combination");
                c -= 1;
                if self.cur[c] < (self.n - l + c) as u32 {
                    self.cur[c] += 1;
                    for d in (c + 1)..l {
                        self.cur[d] = self.cur[d - 1] + 1;
                    }
                    break;
                }
            }
        }
        self.remaining -= 1;
        Some(&self.cur)
    }
}

/// Range iterator for the cuPC-E skip-p variant: combinations are drawn
/// in the reduced (row_len − 1) space and remapped around position p.
pub struct CombRangeSkip {
    inner: CombRange,
    p: u32,
    out: Vec<u32>,
}

impl CombRangeSkip {
    pub fn new(row_len: usize, l: usize, t0: u64, count: u64, p: usize) -> Self {
        CombRangeSkip {
            inner: CombRange::new(row_len - 1, l, t0, count),
            p: p as u32,
            out: vec![0u32; l],
        }
    }

    pub fn next_comb(&mut self) -> Option<&[u32]> {
        let p = self.p;
        let cur = self.inner.next_comb()?;
        for (dst, &v) in self.out.iter_mut().zip(cur) {
            *dst = if v >= p { v + 1 } else { v };
        }
        Some(&self.out)
    }
}

/// Number of conditioning sets for one edge in cuPC-E at level l:
/// C(n'_i − 1, l)  (paper §3.3).
pub fn n_sets_edge(row_len: usize, l: usize) -> u64 {
    if row_len == 0 {
        return 0;
    }
    binom(row_len - 1, l)
}

/// Number of conditioning sets for one row in cuPC-S at level l:
/// C(n'_i, l)  (paper §3.4).
pub fn n_sets_row(row_len: usize, l: usize) -> u64 {
    binom(row_len, l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(6, 0), 1);
        assert_eq!(binom(6, 6), 1);
        assert_eq!(binom(4, 5), 0);
        assert_eq!(binom(52, 5), 2_598_960);
    }

    #[test]
    fn paper_example_n3_l2() {
        // O_0=[1,2], O_1=[1,3], O_2=[2,3] (1-based) → 0-based.
        let mut out = [0u32; 2];
        comb_at(3, 2, 0, &mut out);
        assert_eq!(out, [0, 1]);
        comb_at(3, 2, 1, &mut out);
        assert_eq!(out, [0, 2]);
        comb_at(3, 2, 2, &mut out);
        assert_eq!(out, [1, 2]);
    }

    #[test]
    fn full_enumeration_is_lexicographic_bijection() {
        // property test across several (n, l)
        for (n, l) in [(5, 2), (6, 3), (7, 1), (8, 4), (6, 6)] {
            let total = binom(n, l);
            let mut prev: Option<Vec<u32>> = None;
            let mut seen = std::collections::HashSet::new();
            for t in 0..total {
                let mut out = vec![0u32; l];
                comb_at(n, l, t, &mut out);
                // strictly ascending elements in range
                for w in out.windows(2) {
                    assert!(w[0] < w[1]);
                }
                assert!(*out.last().unwrap() < n as u32);
                // lexicographically increasing over t
                if let Some(p) = &prev {
                    assert!(*p < out, "t={t} not lex-ordered for n={n} l={l}");
                }
                assert!(seen.insert(out.clone()), "duplicate at t={t}");
                prev = Some(out);
            }
            assert_eq!(seen.len() as u64, total);
        }
    }

    #[test]
    fn skip_variant_never_contains_p() {
        for row_len in [3usize, 5, 8] {
            for l in 1..(row_len - 1) {
                for p in 0..row_len {
                    let total = binom(row_len - 1, l);
                    for t in 0..total {
                        let mut out = vec![0u32; l];
                        comb_at_skip(row_len, l, t, p, &mut out);
                        assert!(
                            !out.contains(&(p as u32)),
                            "row_len={row_len} l={l} p={p} t={t} out={out:?}"
                        );
                        for &v in &out {
                            assert!((v as usize) < row_len);
                        }
                        for w in out.windows(2) {
                            assert!(w[0] < w[1]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn skip_variant_is_bijection() {
        let row_len = 6;
        let l = 2;
        let p = 3;
        let total = binom(row_len - 1, l);
        let mut seen = std::collections::HashSet::new();
        for t in 0..total {
            let mut out = vec![0u32; l];
            comb_at_skip(row_len, l, t, p, &mut out);
            seen.insert(out);
        }
        assert_eq!(seen.len() as u64, total);
    }

    #[test]
    fn set_counters() {
        assert_eq!(n_sets_edge(6, 2), binom(5, 2));
        assert_eq!(n_sets_edge(0, 2), 0);
        assert_eq!(n_sets_row(6, 2), 15); // paper Fig. 4: C(6,2) = 15
    }

    #[test]
    fn comb_range_matches_comb_at() {
        for (n, l) in [(6usize, 2usize), (8, 3), (5, 1), (7, 7)] {
            let total = binom(n, l);
            for t0 in [0u64, 1, total / 2, total.saturating_sub(1)] {
                let count = (total - t0).min(5);
                let mut it = CombRange::new(n, l, t0, count);
                for t in t0..t0 + count {
                    let mut want = vec![0u32; l];
                    comb_at(n, l, t, &mut want);
                    let got = it.next_comb().unwrap();
                    assert_eq!(got, &want[..], "n={n} l={l} t={t}");
                }
                assert!(it.next_comb().is_none());
            }
        }
    }

    #[test]
    fn comb_range_skip_matches_comb_at_skip() {
        let (row_len, l, p) = (7usize, 3usize, 2usize);
        let total = binom(row_len - 1, l);
        let mut it = CombRangeSkip::new(row_len, l, 0, total, p);
        for t in 0..total {
            let mut want = vec![0u32; l];
            comb_at_skip(row_len, l, t, p, &mut want);
            let got = it.next_comb().unwrap();
            assert_eq!(got, &want[..], "t={t}");
        }
        assert!(it.next_comb().is_none());
    }

    #[test]
    fn comb_range_empty() {
        let mut it = CombRange::new(5, 2, 0, 0);
        assert!(it.next_comb().is_none());
    }

    /// Full enumeration over a neighbor set yields exactly C(n, l)
    /// combinations, in lexicographic order, with no duplicates — the
    /// invariant the batch packers rely on to shard work.
    #[test]
    fn range_enumeration_is_exactly_binom_ordered_unique() {
        for (n, l) in [(5usize, 2usize), (6, 3), (8, 1), (9, 4), (7, 5)] {
            let total = binom(n, l);
            let mut it = CombRange::new(n, l, 0, total);
            let mut seen = std::collections::HashSet::new();
            let mut prev: Option<Vec<u32>> = None;
            let mut count = 0u64;
            while let Some(c) = it.next_comb() {
                count += 1;
                let c = c.to_vec();
                for w in c.windows(2) {
                    assert!(w[0] < w[1], "not strictly ascending: {c:?}");
                }
                assert!(*c.last().unwrap() < n as u32);
                if let Some(p) = &prev {
                    assert!(*p < c, "order violation at #{count} for n={n} l={l}");
                }
                assert!(seen.insert(c.clone()), "duplicate {c:?}");
                prev = Some(c);
            }
            assert_eq!(count, total, "n={n} l={l}: expected C(n,l) combinations");
        }
    }

    /// Edge case n == l: the single combination is the whole set.
    #[test]
    fn n_equals_l_single_full_combination() {
        for n in [1usize, 2, 4, 7] {
            assert_eq!(binom(n, n), 1);
            let mut out = vec![0u32; n];
            comb_at(n, n, 0, &mut out);
            let want: Vec<u32> = (0..n as u32).collect();
            assert_eq!(out, want);

            let mut it = CombRange::new(n, n, 0, 1);
            assert_eq!(it.next_comb().unwrap(), &want[..]);
            assert!(it.next_comb().is_none());
        }
    }

    /// Edge case l == 0: exactly one combination — the empty set (the
    /// level-0 CI test's conditioning set).
    #[test]
    fn l_zero_single_empty_combination() {
        for n in [1usize, 3, 10] {
            assert_eq!(binom(n, 0), 1);
            let mut out: Vec<u32> = vec![];
            comb_at(n, 0, 0, &mut out);
            assert!(out.is_empty());

            let mut it = CombRange::new(n, 0, 0, 1);
            let first = it.next_comb().expect("one empty combination");
            assert!(first.is_empty());
            assert!(it.next_comb().is_none());
        }
    }

    /// The skip-p iterator enumerates exactly C(row_len − 1, l) sets for
    /// every position p — the per-edge count cuPC-E assigns to threads.
    #[test]
    fn skip_variant_count_matches_n_sets_edge() {
        let (row_len, l) = (7usize, 3usize);
        for p in 0..row_len {
            let total = n_sets_edge(row_len, l);
            assert_eq!(total, binom(row_len - 1, l));
            let mut it = CombRangeSkip::new(row_len, l, 0, total, p);
            let mut count = 0u64;
            while it.next_comb().is_some() {
                count += 1;
            }
            assert_eq!(count, total, "p={p}");
        }
    }

    /// The reversed-order schedule's access pattern: single-slot windows
    /// walked from t = total − 1 down to 0. Concatenating those windows
    /// and reversing must reproduce the full ascending enumeration —
    /// i.e. descending access is a pure reindexing, hitting every
    /// combination exactly once with no seam at any window boundary.
    #[test]
    fn descending_single_slot_windows_cover_the_ascending_enumeration() {
        for (n, l) in [(6usize, 2usize), (7, 3), (5, 4)] {
            let total = binom(n, l);
            let mut descending: Vec<Vec<u32>> = Vec::new();
            for round in 0..total {
                let mut it = CombRange::new(n, l, total - 1 - round, 1);
                descending.push(it.next_comb().unwrap().to_vec());
                assert!(it.next_comb().is_none(), "window width is exactly 1");
            }
            descending.reverse();
            let mut it = CombRange::new(n, l, 0, total);
            let mut ascending: Vec<Vec<u32>> = Vec::new();
            while let Some(c) = it.next_comb() {
                ascending.push(c.to_vec());
            }
            assert_eq!(descending, ascending, "n={n} l={l}");
        }
    }

    /// Same property for the skip-p space the per-edge schedules use,
    /// with windows wider than one slot and boundaries landing mid-range
    /// (the shape `pipeline::split_runs` produces at high l, where a
    /// single edge's window is split across shards).
    #[test]
    fn descending_skip_windows_split_anywhere_still_cover_everything() {
        let (row_len, l, p) = (8usize, 4usize, 3usize);
        let total = binom(row_len - 1, l); // 35 sets at l = row_len/2
        for width in [1u64, 2, 3, 16, total] {
            let mut covered: Vec<Vec<u32>> = Vec::new();
            // windows [total-w, total), [total-2w, total-w), ... like the
            // descending flight, each window enumerated ascending inside
            let mut hi = total;
            while hi > 0 {
                let lo = hi.saturating_sub(width);
                let mut it = CombRangeSkip::new(row_len, l, lo, hi - lo, p);
                let mut window: Vec<Vec<u32>> = Vec::new();
                while let Some(c) = it.next_comb() {
                    window.push(c.to_vec());
                }
                assert_eq!(window.len() as u64, hi - lo);
                covered.splice(0..0, window);
                hi = lo;
            }
            assert_eq!(covered.len() as u64, total, "width={width}");
            let mut want: Vec<Vec<u32>> = Vec::new();
            let mut it = CombRangeSkip::new(row_len, l, 0, total, p);
            while let Some(c) = it.next_comb() {
                want.push(c.to_vec());
            }
            assert_eq!(covered, want, "width={width}");
        }
    }

    /// High-order edge cases the reversed schedule leans on: l = deg − 1
    /// (one combination per edge) and the top index t = total − 1, which
    /// must be the lexicographic maximum {n−l, …, n−1}.
    #[test]
    fn high_order_top_index_is_the_lexicographic_maximum() {
        for (n, l) in [(6usize, 5usize), (8, 7), (9, 4), (5, 2)] {
            let total = binom(n, l);
            let mut out = vec![0u32; l];
            comb_at(n, l, total - 1, &mut out);
            let want: Vec<u32> = ((n - l) as u32..n as u32).collect();
            assert_eq!(out, want, "n={n} l={l}");
        }
        // l = row_len − 1 in the skip space: exactly one set per edge —
        // every row position except p — so the descending walk and the
        // ascending walk are the same single window
        for p in 0..6usize {
            let (row_len, l) = (6usize, 5usize);
            assert_eq!(n_sets_edge(row_len, l), 1);
            let mut out = vec![0u32; l];
            comb_at_skip(row_len, l, 0, p, &mut out);
            let want: Vec<u32> = (0..row_len as u32).filter(|&v| v != p as u32).collect();
            assert_eq!(out, want, "p={p}");
        }
    }

    #[test]
    fn fig3_example() {
        // paper Fig. 3(d): row 2 = {0,1,3,4,5,6}, j=5 at position p=4,
        // l=2 → 10 combinations from the 5 remaining elements; when t=9
        // (last), P={3,5} i.e. 0-based positions {3,5} → S={V4, V6}.
        let row: Vec<u32> = vec![0, 1, 3, 4, 5, 6];
        let p = 4; // position of j=5
        let mut out = [0u32; 2];
        comb_at_skip(6, 2, 9, p, &mut out);
        assert_eq!(out, [3, 5]);
        let s: Vec<u32> = out.iter().map(|&x| row[x as usize]).collect();
        assert_eq!(s, vec![4, 6]);
    }
}
