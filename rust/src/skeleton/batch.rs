//! Batch packing: the L3 gather stage.
//!
//! Packed buffers feed the runtime-selectable CI-test kernels in
//! [`crate::stats::kernels`] (see `docs/NUMERICS.md` for the f64→f32
//! narrowing contract this packing relies on).
//!
//! cuPC stages a row of `A'_G` in GPU shared memory and lets threads
//! gather `M0/M1/M2` from the resident correlation matrix. With AOT
//! kernels of static shape, the gather moves here: the packer reads the
//! f32 correlation matrix and emits densely packed `c_ij / M1 / M2`
//! buffers plus per-slot metadata, and the apply step replays verdicts
//! in deterministic order (first independent verdict wins — the batched
//! analogue of the paper's in-kernel edge-removal race, made
//! deterministic).

use crate::graph::adj::{AdjMatrix, EdgeRemove};
use crate::graph::sepset::SepSets;
use crate::stats::fisher::independent;
use anyhow::{bail, Result};

/// f32 copy of the correlation matrix (the artifact dtype).
pub struct Corr32 {
    pub c: Vec<f32>,
    pub n: usize,
}

impl Corr32 {
    pub fn from_f64(corr: &[f64], n: usize) -> Self {
        assert_eq!(corr.len(), n * n);
        Corr32 {
            c: corr.iter().map(|&x| x as f32).collect(),
            n,
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.c[i * self.n + j]
    }
}

/// One packed cuPC-E test slot: edge (i, j) with conditioning set S.
#[derive(Clone, Debug)]
pub struct SlotMeta {
    pub i: u32,
    pub j: u32,
}

/// Packed batch for the ci_e kernels.
pub struct EBatch {
    pub l: usize,
    pub c_ij: Vec<f32>,
    pub m1: Vec<f32>,
    pub m2: Vec<f32>,
    pub meta: Vec<SlotMeta>,
    /// conditioning-set variable ids, l per slot
    pub svals: Vec<u32>,
}

impl EBatch {
    pub fn new(l: usize, cap: usize) -> Self {
        EBatch {
            l,
            c_ij: Vec::with_capacity(cap),
            m1: Vec::with_capacity(cap * 2 * l),
            m2: Vec::with_capacity(cap * l * l),
            meta: Vec::with_capacity(cap),
            svals: Vec::with_capacity(cap * l),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.meta.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    pub fn clear(&mut self) {
        self.c_ij.clear();
        self.m1.clear();
        self.m2.clear();
        self.meta.clear();
        self.svals.clear();
    }

    /// Gather one test (i, j | S) from the correlation matrix.
    pub fn push(&mut self, corr: &Corr32, i: usize, j: usize, s: &[u32]) {
        debug_assert_eq!(s.len(), self.l);
        self.c_ij.push(corr.at(i, j));
        for &sv in s {
            self.m1.push(corr.at(i, sv as usize));
        }
        for &sv in s {
            self.m1.push(corr.at(j, sv as usize));
        }
        for &sa in s {
            for &sb in s {
                self.m2.push(corr.at(sa as usize, sb as usize));
            }
        }
        self.meta.push(SlotMeta {
            i: i as u32,
            j: j as u32,
        });
        self.svals.extend_from_slice(s);
    }

    /// Apply verdicts in slot order: the first independent verdict for a
    /// still-present edge removes it and stores S. Returns (#removed,
    /// #tests-that-were-already-moot). `z.len() >= self.len()` (engines
    /// may return padded tails).
    pub fn apply(
        &self,
        z: &[f32],
        tau: f64,
        graph: &AdjMatrix,
        sepsets: &SepSets,
    ) -> (usize, usize) {
        apply_e_slots(self.l, z, &self.meta, &self.svals, tau, graph, sepsets)
    }

    /// Filter the evaluated batch's *independence candidates* (slots
    /// with |z| ≤ τ) into `out` in canonical slot order, then clear the
    /// batch for reuse. Dependent verdicts can never change state, so
    /// the parallel pipeline drops them — and the heavy M1/M2 gather —
    /// as soon as z is known, bounding the deferred-apply memory of a
    /// round at the number of candidates instead of the number of tests.
    pub fn drain_independent(&mut self, z: &[f32], tau: f64, out: &mut Removals) {
        debug_assert!(z.len() >= self.len());
        debug_assert_eq!(out.l, self.l);
        for (idx, meta) in self.meta.iter().enumerate() {
            if independent(z[idx] as f64, tau) {
                out.meta.push(meta.clone());
                out.svals
                    .extend_from_slice(&self.svals[idx * self.l..(idx + 1) * self.l]);
            }
        }
        self.clear();
    }
}

/// Independence candidates detached from evaluated batches: (i, j, S)
/// entries in canonical slot order whose test said independent. Shared
/// by the cuPC-E and cuPC-S pipelines (see
/// [`EBatch::drain_independent`] / [`SBatch::drain_independent`]).
pub struct Removals {
    l: usize,
    meta: Vec<SlotMeta>,
    /// conditioning-set variable ids, l per retained entry
    svals: Vec<u32>,
}

impl Removals {
    pub fn new(l: usize) -> Self {
        Removals {
            l,
            meta: Vec::new(),
            svals: Vec::new(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.meta.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    /// Conditioning-set size of the retained entries.
    #[inline]
    pub fn l(&self) -> usize {
        self.l
    }

    /// Append another candidate list (same level) in order — how the
    /// driver concatenates a chunk's per-shard results back into the
    /// chunk's canonical slot order.
    pub fn append(&mut self, other: Removals) {
        debug_assert_eq!(self.l, other.l);
        self.meta.extend(other.meta);
        self.svals.extend(other.svals);
    }

    /// Apply in canonical order: the first entry whose edge is still
    /// present removes it and stores its S (later candidates for the
    /// same edge are moot). Returns the number of edges removed —
    /// identical to replaying the full verdict stream through
    /// [`EBatch::apply`] / [`SBatch::apply`]. Generic over the
    /// adjacency representation (dense matrix, sparse CSR, or the
    /// dispatch enum — see [`EdgeRemove`]).
    pub fn apply(&self, graph: &impl EdgeRemove, sepsets: &SepSets) -> usize {
        let mut removed = 0;
        for (idx, meta) in self.meta.iter().enumerate() {
            let (i, j) = (meta.i as usize, meta.j as usize);
            if graph.remove_edge(i, j) {
                sepsets.store(i, j, &self.svals[idx * self.l..(idx + 1) * self.l]);
                removed += 1;
            }
        }
        removed
    }

    /// Wire codec for the cross-process exchange: `l`, entry count,
    /// then (i, j) pairs and the flat conditioning-set ids, all
    /// little-endian u32. Order-preserving, so a decoded list applies
    /// identically to the original.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(8 + self.meta.len() * 8 + self.svals.len() * 4);
        b.extend_from_slice(&(self.l as u32).to_le_bytes());
        b.extend_from_slice(&(self.meta.len() as u32).to_le_bytes());
        for m in &self.meta {
            b.extend_from_slice(&m.i.to_le_bytes());
            b.extend_from_slice(&m.j.to_le_bytes());
        }
        for s in &self.svals {
            b.extend_from_slice(&s.to_le_bytes());
        }
        b
    }

    pub fn from_bytes(b: &[u8]) -> Result<Removals> {
        let rd_u32 = |b: &[u8], at: usize| -> Result<u32> {
            match b.get(at..at + 4) {
                Some(w) => Ok(u32::from_le_bytes(w.try_into().unwrap())),
                None => bail!("truncated removals blob"),
            }
        };
        let l = rd_u32(b, 0)? as usize;
        let len = rd_u32(b, 4)? as usize;
        let want = 8 + len * 8 + len * l * 4;
        if b.len() != want {
            bail!("removals blob size mismatch: {} != {want}", b.len());
        }
        let mut meta = Vec::with_capacity(len);
        for idx in 0..len {
            meta.push(SlotMeta {
                i: rd_u32(b, 8 + idx * 8)?,
                j: rd_u32(b, 12 + idx * 8)?,
            });
        }
        let base = 8 + len * 8;
        let mut svals = Vec::with_capacity(len * l);
        for k in 0..len * l {
            svals.push(rd_u32(b, base + k * 4)?);
        }
        Ok(Removals { l, meta, svals })
    }
}

/// The shared cuPC-E apply core: slot-ordered first-win removal.
fn apply_e_slots(
    l: usize,
    z: &[f32],
    meta: &[SlotMeta],
    svals: &[u32],
    tau: f64,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> (usize, usize) {
    let mut removed = 0;
    let mut moot = 0;
    for (idx, meta) in meta.iter().enumerate() {
        let (i, j) = (meta.i as usize, meta.j as usize);
        if !graph.has_edge(i, j) {
            moot += 1;
            continue;
        }
        if independent(z[idx] as f64, tau) && graph.remove_edge(i, j) {
            sepsets.store(i, j, &svals[idx * l..(idx + 1) * l]);
            removed += 1;
        }
    }
    (removed, moot)
}

/// Packed batch for the ci_s kernels: `rows` conditioning sets × `k`
/// candidate tests each. Rows may be partially filled; invalid slots are
/// padded with the row's first candidate and masked out in apply.
pub struct SBatch {
    pub l: usize,
    pub k: usize,
    pub c_ij: Vec<f32>,
    pub m1: Vec<f32>,
    pub m2: Vec<f32>,
    /// per-slot metadata; `valid = false` marks padding
    pub meta: Vec<(SlotMeta, bool)>,
    /// conditioning-set variable ids, l per ROW
    pub svals: Vec<u32>,
    /// number of valid (non-padding) slots per row — lets the native
    /// engine skip padding entirely (the XLA kernel computes the full
    /// K width regardless; padded verdicts are discarded in apply)
    pub valid: Vec<u32>,
    rows: usize,
}

impl SBatch {
    pub fn new(l: usize, k: usize, row_cap: usize) -> Self {
        SBatch {
            l,
            k,
            c_ij: Vec::with_capacity(row_cap * k),
            m1: Vec::with_capacity(row_cap * k * 2 * l),
            m2: Vec::with_capacity(row_cap * l * l),
            meta: Vec::with_capacity(row_cap * k),
            svals: Vec::with_capacity(row_cap * l),
            valid: Vec::with_capacity(row_cap),
            rows: 0,
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn clear(&mut self) {
        self.c_ij.clear();
        self.m1.clear();
        self.m2.clear();
        self.meta.clear();
        self.svals.clear();
        self.valid.clear();
        self.rows = 0;
    }

    /// Gather one conditioning set S for anchor i with up to k candidate
    /// partners `js` (all != i and ∉ S). Empty `js` is a no-op.
    pub fn push_row(&mut self, corr: &Corr32, i: usize, s: &[u32], js: &[u32]) {
        debug_assert_eq!(s.len(), self.l);
        debug_assert!(js.len() <= self.k);
        if js.is_empty() {
            return;
        }
        // M2 once per row
        for &sa in s {
            for &sb in s {
                self.m2.push(corr.at(sa as usize, sb as usize));
            }
        }
        self.svals.extend_from_slice(s);
        self.valid.push(js.len() as u32);
        // valid slots gather; padding slots zero-fill (numerically inert)
        for &ju in js {
            let j = ju as usize;
            self.c_ij.push(corr.at(i, j));
            for &sv in s {
                self.m1.push(corr.at(i, sv as usize));
            }
            for &sv in s {
                self.m1.push(corr.at(j, sv as usize));
            }
            self.meta.push((
                SlotMeta {
                    i: i as u32,
                    j: ju,
                },
                true,
            ));
        }
        for _ in js.len()..self.k {
            self.c_ij.push(0.0);
            self.m1.extend(std::iter::repeat(0.0).take(2 * self.l));
            self.meta.push((SlotMeta { i: i as u32, j: 0 }, false));
        }
        self.rows += 1;
    }

    /// Apply verdicts: slot order within valid slots, first win removes.
    pub fn apply(
        &self,
        z: &[f32],
        tau: f64,
        graph: &AdjMatrix,
        sepsets: &SepSets,
    ) -> (usize, usize) {
        apply_s_slots(self.l, self.k, z, &self.meta, &self.svals, tau, graph, sepsets)
    }

    /// Filter the evaluated batch's independence candidates (valid
    /// slots with |z| ≤ τ) into `out` in canonical slot order, then
    /// clear the batch for reuse (the cuPC-S analogue of
    /// [`EBatch::drain_independent`]; the retained entries copy their
    /// row's S, so row structure is not needed at apply time).
    pub fn drain_independent(&mut self, z: &[f32], tau: f64, out: &mut Removals) {
        debug_assert!(z.len() >= self.meta.len());
        debug_assert_eq!(out.l, self.l);
        for (idx, (meta, valid)) in self.meta.iter().enumerate() {
            if !valid {
                continue;
            }
            if independent(z[idx] as f64, tau) {
                let row = idx / self.k;
                out.meta.push(meta.clone());
                out.svals
                    .extend_from_slice(&self.svals[row * self.l..(row + 1) * self.l]);
            }
        }
        self.clear();
    }
}

/// The shared cuPC-S apply core: slot-ordered first-win removal over the
/// valid (non-padding) slots.
#[allow(clippy::too_many_arguments)] // mirrors the packed-batch ABI
fn apply_s_slots(
    l: usize,
    k: usize,
    z: &[f32],
    meta: &[(SlotMeta, bool)],
    svals: &[u32],
    tau: f64,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> (usize, usize) {
    let mut removed = 0;
    let mut moot = 0;
    for (idx, (meta, valid)) in meta.iter().enumerate() {
        if !valid {
            continue;
        }
        let (i, j) = (meta.i as usize, meta.j as usize);
        if !graph.has_edge(i, j) {
            moot += 1;
            continue;
        }
        if independent(z[idx] as f64, tau) && graph.remove_edge(i, j) {
            let row = idx / k;
            sepsets.store(i, j, &svals[row * l..(row + 1) * l]);
            removed += 1;
        }
    }
    (removed, moot)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_corr() -> Corr32 {
        // 4 vars, easy recognizable entries c[i][j] = 0.1*(i+1) + 0.01*(j+1) sym’d
        let n = 4;
        let mut c = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = if i == j {
                    1.0
                } else {
                    0.1 * (i.min(j) + 1) as f64 + 0.01 * (i.max(j) + 1) as f64
                };
                c[i * n + j] = v;
            }
        }
        Corr32::from_f64(&c, n)
    }

    #[test]
    fn ebatch_packs_gathered_blocks() {
        let corr = tiny_corr();
        let mut b = EBatch::new(2, 8);
        b.push(&corr, 0, 1, &[2, 3]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.c_ij[0], corr.at(0, 1));
        // m1 row0 = C[0,2], C[0,3]; row1 = C[1,2], C[1,3]
        assert_eq!(&b.m1[..4], &[
            corr.at(0, 2),
            corr.at(0, 3),
            corr.at(1, 2),
            corr.at(1, 3)
        ]);
        // m2 = [[C22, C23],[C32, C33]]
        assert_eq!(&b.m2[..4], &[1.0, corr.at(2, 3), corr.at(3, 2), 1.0]);
        assert_eq!(&b.svals[..2], &[2, 3]);
    }

    #[test]
    fn ebatch_apply_removes_first_win_only() {
        let corr = tiny_corr();
        let g = AdjMatrix::complete(4);
        let sep = SepSets::new();
        let mut b = EBatch::new(1, 8);
        b.push(&corr, 0, 1, &[2]);
        b.push(&corr, 0, 1, &[3]); // duplicate edge, different S
        let z = vec![0.0f32, 0.0]; // both say independent
        let (removed, moot) = b.apply(&z, 0.1, &g, &sep);
        assert_eq!(removed, 1);
        assert_eq!(moot, 1, "second slot was moot after first removal");
        assert_eq!(sep.get(0, 1), Some(vec![2]), "first S wins");
    }

    #[test]
    fn ebatch_apply_respects_tau() {
        let corr = tiny_corr();
        let g = AdjMatrix::complete(4);
        let sep = SepSets::new();
        let mut b = EBatch::new(1, 8);
        b.push(&corr, 0, 1, &[2]);
        let (removed, _) = b.apply(&[5.0], 0.1, &g, &sep);
        assert_eq!(removed, 0);
        assert!(g.has_edge(0, 1));
        assert!(sep.get(0, 1).is_none());
    }

    #[test]
    fn sbatch_pads_invalid_slots() {
        let corr = tiny_corr();
        let mut b = SBatch::new(1, 4, 8);
        b.push_row(&corr, 0, &[3], &[1, 2]);
        assert_eq!(b.rows(), 1);
        assert_eq!(b.meta.len(), 4);
        assert!(b.meta[0].1 && b.meta[1].1);
        assert!(!b.meta[2].1 && !b.meta[3].1);
        // padding slots are zero-filled (numerically inert)
        assert_eq!(b.c_ij[2], 0.0);
        assert_eq!(b.valid, vec![2]);
        // m2 stored once per row
        assert_eq!(b.m2.len(), 1);
        assert_eq!(b.svals, vec![3]);
    }

    #[test]
    fn sbatch_empty_candidates_is_noop() {
        let corr = tiny_corr();
        let mut b = SBatch::new(2, 4, 8);
        b.push_row(&corr, 0, &[1, 2], &[]);
        assert!(b.is_empty());
    }

    #[test]
    fn drain_independent_matches_batch_apply() {
        // the drained-candidate path must produce the same removals and
        // sepsets as replaying the full verdict stream through apply
        let corr = tiny_corr();
        let mut b = EBatch::new(1, 8);
        b.push(&corr, 0, 1, &[2]);
        b.push(&corr, 0, 2, &[3]); // dependent: dropped at drain time
        b.push(&corr, 0, 1, &[3]); // duplicate edge, moot at apply time
        let z = vec![0.0f32, 5.0, 0.0];
        let g1 = AdjMatrix::complete(4);
        let s1 = SepSets::new();
        let (direct_removed, _) = b.apply(&z, 0.1, &g1, &s1);
        let mut out = Removals::new(1);
        b.drain_independent(&z, 0.1, &mut out);
        assert!(b.is_empty(), "drain clears the batch");
        assert_eq!(b.m1.len(), 0);
        assert_eq!(out.len(), 2, "only the independent slots are retained");
        let g2 = AdjMatrix::complete(4);
        let s2 = SepSets::new();
        assert_eq!(out.apply(&g2, &s2), direct_removed);
        assert_eq!(g1.snapshot(), g2.snapshot());
        assert_eq!(s1.sorted_entries(), s2.sorted_entries());
        assert_eq!(s2.get(0, 1), Some(vec![2]), "first candidate wins");
        assert!(g2.has_edge(0, 2), "dependent verdict must not remove");
    }

    #[test]
    fn sbatch_drain_independent_matches_batch_apply_and_skips_padding() {
        let corr = tiny_corr();
        let mut b = SBatch::new(1, 4, 8);
        b.push_row(&corr, 0, &[3], &[1, 2]);
        // slot 0 independent, slot 1 dependent, padded slots "independent"
        // but invalid and must be ignored
        let z = vec![0.0f32, 5.0, 0.0, 0.0];
        let g1 = AdjMatrix::complete(4);
        let s1 = SepSets::new();
        let (direct_removed, _) = b.apply(&z, 0.1, &g1, &s1);
        let mut out = Removals::new(1);
        b.drain_independent(&z, 0.1, &mut out);
        assert!(b.is_empty(), "drain clears the batch");
        assert_eq!(out.len(), 1, "one valid independent slot");
        let g2 = AdjMatrix::complete(4);
        let s2 = SepSets::new();
        assert_eq!(out.apply(&g2, &s2), direct_removed);
        assert_eq!(g1.snapshot(), g2.snapshot());
        assert_eq!(s1.sorted_entries(), s2.sorted_entries());
        assert_eq!(s2.get(0, 1), Some(vec![3]));
        assert!(g2.has_edge(0, 3), "padded slot must not remove");
    }

    #[test]
    fn removals_roundtrip_through_bytes() {
        let corr = tiny_corr();
        let mut b = EBatch::new(2, 8);
        b.push(&corr, 0, 1, &[2, 3]);
        b.push(&corr, 1, 3, &[0, 2]);
        let mut out = Removals::new(2);
        b.drain_independent(&[0.0, 0.0], 0.1, &mut out);
        assert_eq!(out.len(), 2);
        let back = Removals::from_bytes(&out.to_bytes()).unwrap();
        assert_eq!(back.l(), 2);
        let g1 = AdjMatrix::complete(4);
        let s1 = SepSets::new();
        let g2 = AdjMatrix::complete(4);
        let s2 = SepSets::new();
        assert_eq!(out.apply(&g1, &s1), back.apply(&g2, &s2));
        assert_eq!(g1.snapshot(), g2.snapshot());
        assert_eq!(s1.sorted_entries(), s2.sorted_entries());
        // corrupt blobs are rejected, not misread
        assert!(Removals::from_bytes(&out.to_bytes()[..9]).is_err());
        assert!(Removals::from_bytes(&[]).is_err());
    }

    #[test]
    fn removals_append_preserves_order() {
        let corr = tiny_corr();
        let mk = |i: usize, j: usize| {
            let mut b = EBatch::new(1, 4);
            b.push(&corr, i, j, &[3]);
            let mut r = Removals::new(1);
            b.drain_independent(&[0.0], 0.1, &mut r);
            r
        };
        let mut all = mk(0, 1);
        all.append(mk(0, 2));
        assert_eq!(all.len(), 2);
        let g = AdjMatrix::complete(4);
        let s = SepSets::new();
        assert_eq!(all.apply(&g, &s), 2);
        assert!(!g.has_edge(0, 1) && !g.has_edge(0, 2));
    }

    #[test]
    fn sbatch_apply_ignores_padding() {
        let corr = tiny_corr();
        let g = AdjMatrix::complete(4);
        let sep = SepSets::new();
        let mut b = SBatch::new(1, 4, 8);
        b.push_row(&corr, 0, &[3], &[1]);
        // all 4 slots "independent", but only slot 0 is valid
        let z = vec![0.0f32; 4];
        let (removed, _) = b.apply(&z, 0.1, &g, &sep);
        assert_eq!(removed, 1);
        assert!(!g.has_edge(0, 1));
        assert!(g.has_edge(0, 2), "padded slot must not remove");
        assert_eq!(sep.get(0, 1), Some(vec![3]));
    }
}
