//! cuPC-S (paper Algorithm 5, §3.4) as a batched schedule.
//!
//! Threads are assigned to *conditioning sets*, not edges: for each row i
//! of G', the `C(n'_i, ℓ)` sets S are walked in rounds of θ×δ in flight;
//! each set computes `pinv(C[S,S])` once and applies it to every live
//! candidate j ∈ row(i) \ S (paper key feature V — the dominant saving).
//! Candidates beyond the kernel's K-slot width spill into additional
//! batch rows (re-computing that pinv, the same duplication a CUDA
//! thread avoids by looping — bounded by ⌈n'_i/K⌉). Sharing is *local*
//! (within a row), matching §5.5's analysis that global sharing does not
//! pay for its search.
//!
//! Each round runs the three-stage [`pipeline`](super::pipeline): live
//! set windows are listed serially in canonical row order, packed and
//! evaluated in parallel shards against the frozen graph (candidate
//! lists included — the whole flight sees the state at round start,
//! exactly the in-kernel semantics), and verdicts land in canonical slot
//! order before the next round. Results are bit-identical for any
//! `cfg.threads`.

use super::batch::{Corr32, Removals, SBatch};
use super::comb::{n_sets_row, CombRange};
use super::engine::CiEngine;
use super::pipeline::{use_pool, Executor, Run};
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    if use_pool(cfg) {
        run_impl(corr, n, m, cfg, &mut Executor::Pool { threads: cfg.threads })
    } else {
        let mut engine = crate::runtime::engine_from_config(cfg)?;
        run_impl(corr, n, m, cfg, &mut Executor::Single(engine.as_mut()))
    }
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// pipeline inline — results are bit-identical to the pool path.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    run_impl(corr, n, m, cfg, &mut Executor::Single(engine))
}

fn run_impl(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    exec: &mut Executor<'_>,
) -> Result<SkeletonResult> {
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let corr32 = Corr32::from_f64(corr, n);
    let mut levels = Vec::new();

    levels.push(exec.run_level0(corr, n, m, cfg, &graph, &sepsets)?);

    let flight = (cfg.theta.max(1) * cfg.delta.max(1)) as u64; // sets in flight per row per round
    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        // between-level re-lease point (see gpu_e): width policy decides
        // how wide the level runs; results are width-invariant.
        if let Some(hook) = &cfg.width_hook {
            exec.set_width(hook.0.width_for_level(l));
        }
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);

        let mut tests = 0u64;
        let mut removed = 0usize;

        // rows with enough neighbors, and their set counts
        let rows: Vec<(usize, u64)> = (0..n)
            .filter(|&i| comp.row_len(i) >= l + 1)
            .map(|i| (i, n_sets_row(comp.row_len(i), l)))
            .collect();
        let max_total = rows.iter().map(|&(_, t)| t).max().unwrap_or(0);

        let mut runs: Vec<Run> = Vec::new();
        let mut round = 0u64;
        while round * flight < max_total {
            let lo = round * flight;
            // stage 1 (serial): the round's live set windows in
            // canonical row order; the graph is frozen until apply
            runs.clear();
            for (ri, &(i, total)) in rows.iter().enumerate() {
                if lo >= total {
                    continue;
                }
                // §4.1: skip the whole row if no live edge remains
                if !comp.row(i).iter().any(|&j| graph.has_edge(i, j as usize)) {
                    continue;
                }
                let hi = ((round + 1) * flight).min(total);
                runs.push(Run { task: ri, t0: lo, count: hi - lo });
            }
            if runs.is_empty() {
                break; // every unexhausted row is dead
            }

            // stage 2 (parallel): pack + evaluate against the frozen
            // graph; test counts come back per shard (they depend on
            // the candidate lists, which are deterministic per round),
            // and only independence candidates are retained
            let shard_results = exec.run_sharded(&runs, |shard, engine| {
                pack_eval(shard, &rows, &comp, &corr32, &graph, l, taul, engine)
            })?;

            // stage 3 (serial): canonical-order apply
            for (candidates, shard_tests) in &shard_results {
                tests += shard_tests;
                removed += candidates.apply(&graph, &sepsets);
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[cupc-s] level {l}: {tests} tests, removed {removed}, {} edges left",
                graph.n_edges()
            );
        }
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
    })
}

/// Worker body: pack a shard of the round's set windows into
/// engine-capacity batches, evaluate them, and keep only the
/// independence candidates. Returns those plus the shard's test count
/// (one test per live candidate of each set).
#[allow(clippy::too_many_arguments)] // worker signature mirrors the round state
fn pack_eval(
    shard: &[Run],
    rows: &[(usize, u64)],
    comp: &CompactAdj,
    corr32: &Corr32,
    graph: &AdjMatrix,
    l: usize,
    taul: f64,
    engine: &mut dyn CiEngine,
) -> Result<(Removals, u64)> {
    let k = engine.k().max(1);
    let cap = engine.batch_s().max(1);
    let mut out = Removals::new(l);
    let mut tests = 0u64;
    let mut batch = SBatch::new(l, k, cap);
    let mut ids = vec![0u32; l];
    let mut cand: Vec<u32> = Vec::new();
    for run in shard {
        let (i, _) = rows[run.task];
        let row = comp.row(i);
        let mut combs = CombRange::new(row.len(), l, run.t0, run.count);
        while let Some(sbuf) = combs.next_comb() {
            for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                *dst = row[pos as usize];
            }
            // candidates: row members not in S with live edges
            cand.clear();
            for &ju in row {
                if ids.contains(&ju) {
                    continue;
                }
                if graph.has_edge(i, ju as usize) {
                    cand.push(ju);
                }
            }
            // spill into K-wide rows
            for chunk in cand.chunks(k) {
                batch.push_row(corr32, i, &ids, chunk);
                tests += chunk.len() as u64;
                if batch.rows() >= cap {
                    flush(&mut batch, engine, taul, &mut out)?;
                }
            }
        }
    }
    if !batch.is_empty() {
        flush(&mut batch, engine, taul, &mut out)?;
    }
    Ok((out, tests))
}

fn flush(
    batch: &mut SBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    out: &mut Removals,
) -> Result<()> {
    let z = engine.ci_s(
        batch.l,
        batch.rows(),
        batch.k,
        &batch.c_ij,
        &batch.m1,
        &batch.m2,
        &batch.valid,
    )?;
    batch.drain_independent(&z, taul, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::skeleton::EngineKind;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_skeleton() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let serial = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_s.graph.snapshot(),
            serial.graph.snapshot(),
            "cuPC-S must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn matches_cupc_e_skeleton_and_sepset_keys() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 45,
            m: 200,
            topology: datasets::Topology::Grn(1.6, 6),
            seed: 21,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let mut e = NativeEngine::new();
        let res_e =
            crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e)
                .unwrap();
        assert_eq!(res_s.graph.snapshot(), res_e.graph.snapshot());
        // same removed pairs (sepset contents may differ in S but the
        // key set must coincide)
        let keys = |r: &SkeletonResult| {
            r.sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect::<Vec<_>>()
        };
        assert_eq!(keys(&res_s), keys(&res_e));
    }

    #[test]
    fn theta_delta_config_does_not_change_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 120,
            topology: datasets::Topology::Er(0.1),
            seed: 31,
        });
        let c = correlation_matrix(&ds.data, 1);
        let a = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 32,
                delta: 1,
                ..Config::default()
            },
        );
        let b = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 256,
                delta: 8,
                ..Config::default()
            },
        );
        assert_eq!(a.graph.snapshot(), b.graph.snapshot());
    }

    /// The tentpole determinism contract at module level: the pool path
    /// must be bit-identical to the single-engine path, including
    /// per-level test counts.
    #[test]
    fn pool_path_matches_single_engine_bitwise() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 48,
            m: 200,
            topology: datasets::Topology::Grn(1.8, 6),
            seed: 19,
        });
        let c = correlation_matrix(&ds.data, 1);
        let pooled_cfg = Config {
            variant: crate::skeleton::Variant::CupcS,
            engine: EngineKind::Native,
            threads: 4,
            ..Config::default()
        };
        assert!(use_pool(&pooled_cfg));
        let pooled = run(&c, ds.data.n, ds.data.m, &pooled_cfg).unwrap();
        let single = run_native(&c, ds.data.n, ds.data.m, &pooled_cfg);
        assert_eq!(pooled.graph.snapshot(), single.graph.snapshot());
        assert_eq!(
            pooled.sepsets.sorted_entries(),
            single.sepsets.sorted_entries(),
            "sepset contents must be thread-count invariant"
        );
        let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
            r.levels
                .iter()
                .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                .collect()
        };
        assert_eq!(stats(&pooled), stats(&single));
    }
}
