//! cuPC-S (paper Algorithm 5, §3.4) as a batched [`RoundSchedule`].
//!
//! Threads are assigned to *conditioning sets*, not edges: for each row i
//! of G', the `C(n'_i, ℓ)` sets S are walked in rounds of θ×δ in flight;
//! each set computes `pinv(C[S,S])` once and applies it to every live
//! candidate j ∈ row(i) \ S (paper key feature V — the dominant saving).
//! Candidates beyond the kernel's K-slot width spill into additional
//! batch rows (re-computing that pinv, the same duplication a CUDA
//! thread avoids by looping — bounded by ⌈n'_i/K⌉). Sharing is *local*
//! (within a row), matching §5.5's analysis that global sharing does not
//! pay for its search.
//!
//! Each round runs the three-stage [`pipeline`](super::pipeline) via the
//! [`schedule`](super::schedule) driver: live set windows are listed
//! serially in canonical row order, packed and evaluated in parallel
//! shards against the frozen graph (candidate lists included — the whole
//! flight sees the state at round start, exactly the in-kernel
//! semantics), and verdicts land in canonical slot order before the next
//! round. Results are bit-identical for any `cfg.threads`.

use super::batch::{Removals, SBatch};
use super::comb::{n_sets_row, CombRange};
use super::engine::CiEngine;
use super::pipeline::Run;
use super::schedule::{run_rounds, run_rounds_with_engine, LevelCtx, RoundSchedule};
use super::{Config, SkeletonResult};
use anyhow::Result;

/// The cuPC-S schedule: per-row shared conditioning sets, θ×δ in flight
/// per row per round.
pub struct SSchedule {
    flight: u64,
    /// rows with enough neighbors, and their set counts
    rows: Vec<(usize, u64)>,
    max_total: u64,
}

impl SSchedule {
    pub fn new(cfg: &Config) -> SSchedule {
        SSchedule {
            flight: (cfg.theta.max(1) as u64).saturating_mul(cfg.delta.max(1) as u64),
            rows: Vec::new(),
            max_total: 0,
        }
    }
}

impl RoundSchedule for SSchedule {
    fn label(&self) -> &'static str {
        "cupc-s"
    }

    fn begin_level(&mut self, ctx: &LevelCtx<'_>) {
        let l = ctx.l;
        self.rows = (0..ctx.comp.n())
            .filter(|&i| ctx.comp.row_len(i) >= l + 1)
            .map(|i| (i, n_sets_row(ctx.comp.row_len(i), l)))
            .collect();
        self.max_total = self.rows.iter().map(|&(_, t)| t).max().unwrap_or(0);
    }

    fn rounds_done(&self, round: u64) -> bool {
        round.saturating_mul(self.flight) >= self.max_total
    }

    fn visit_round(&self, ctx: &LevelCtx<'_>, round: u64, emit: &mut dyn FnMut(Run)) {
        let lo = round.saturating_mul(self.flight);
        for (ri, &(i, total)) in self.rows.iter().enumerate() {
            if lo >= total {
                continue;
            }
            // §4.1: skip the whole row if no live edge remains
            if !ctx
                .comp
                .row(i)
                .iter()
                .any(|&j| ctx.graph.has_edge(i, j as usize))
            {
                continue;
            }
            let hi = round
                .saturating_add(1)
                .saturating_mul(self.flight)
                .min(total);
            emit(Run { task: ri, t0: lo, count: hi - lo });
        }
    }

    /// Pack a shard of the round's set windows into engine-capacity
    /// batches, evaluate them, and keep only the independence
    /// candidates. The shard's test count is one test per live candidate
    /// of each set (it depends on the candidate lists, which are
    /// deterministic per round).
    fn eval_shard(
        &self,
        ctx: &LevelCtx<'_>,
        shard: &[Run],
        engine: &mut dyn CiEngine,
    ) -> Result<(Removals, u64)> {
        let l = ctx.l;
        let k = engine.k().max(1);
        let cap = engine.batch_s().max(1);
        let mut out = Removals::new(l);
        let mut tests = 0u64;
        let mut batch = SBatch::new(l, k, cap);
        let mut ids = vec![0u32; l];
        let mut cand: Vec<u32> = Vec::new();
        for run in shard {
            let (i, _) = self.rows[run.task];
            let row = ctx.comp.row(i);
            let mut combs = CombRange::new(row.len(), l, run.t0, run.count);
            while let Some(sbuf) = combs.next_comb() {
                for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                    *dst = row[pos as usize];
                }
                // candidates: row members not in S with live edges
                cand.clear();
                for &ju in row {
                    if ids.contains(&ju) {
                        continue;
                    }
                    if ctx.graph.has_edge(i, ju as usize) {
                        cand.push(ju);
                    }
                }
                // spill into K-wide rows
                for chunk in cand.chunks(k) {
                    batch.push_row(ctx.corr32, i, &ids, chunk);
                    tests += chunk.len() as u64;
                    if batch.rows() >= cap {
                        flush(&mut batch, engine, ctx.taul, &mut out)?;
                    }
                }
            }
        }
        if !batch.is_empty() {
            flush(&mut batch, engine, ctx.taul, &mut out)?;
        }
        Ok((out, tests))
    }
}

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    run_rounds(corr, n, m, cfg, &mut SSchedule::new(cfg))
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// pipeline inline — results are bit-identical to the pool path.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    run_rounds_with_engine(corr, n, m, cfg, &mut SSchedule::new(cfg), engine)
}

fn flush(
    batch: &mut SBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    out: &mut Removals,
) -> Result<()> {
    let z = engine.ci_s(
        batch.l,
        batch.rows(),
        batch.k,
        &batch.c_ij,
        &batch.m1,
        &batch.m2,
        &batch.valid,
    )?;
    batch.drain_independent(&z, taul, out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::skeleton::pipeline::use_pool;
    use crate::skeleton::EngineKind;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_skeleton() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let serial = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_s.graph.snapshot(),
            serial.graph.snapshot(),
            "cuPC-S must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn matches_cupc_e_skeleton_and_sepset_keys() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 45,
            m: 200,
            topology: datasets::Topology::Grn(1.6, 6),
            seed: 21,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let mut e = NativeEngine::new();
        let res_e =
            crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e)
                .unwrap();
        assert_eq!(res_s.graph.snapshot(), res_e.graph.snapshot());
        // same removed pairs (sepset contents may differ in S but the
        // key set must coincide)
        let keys = |r: &SkeletonResult| {
            r.sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect::<Vec<_>>()
        };
        assert_eq!(keys(&res_s), keys(&res_e));
    }

    #[test]
    fn theta_delta_config_does_not_change_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 120,
            topology: datasets::Topology::Er(0.1),
            seed: 31,
        });
        let c = correlation_matrix(&ds.data, 1);
        let a = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 32,
                delta: 1,
                ..Config::default()
            },
        );
        let b = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 256,
                delta: 8,
                ..Config::default()
            },
        );
        assert_eq!(a.graph.snapshot(), b.graph.snapshot());
    }

    /// The tentpole determinism contract at module level: the pool path
    /// must be bit-identical to the single-engine path, including
    /// per-level test counts.
    #[test]
    fn pool_path_matches_single_engine_bitwise() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 48,
            m: 200,
            topology: datasets::Topology::Grn(1.8, 6),
            seed: 19,
        });
        let c = correlation_matrix(&ds.data, 1);
        let pooled_cfg = Config {
            variant: crate::skeleton::Variant::CupcS,
            engine: EngineKind::Native,
            threads: 4,
            ..Config::default()
        };
        assert!(use_pool(&pooled_cfg));
        let pooled = run(&c, ds.data.n, ds.data.m, &pooled_cfg).unwrap();
        let single = run_native(&c, ds.data.n, ds.data.m, &pooled_cfg);
        assert_eq!(pooled.graph.snapshot(), single.graph.snapshot());
        assert_eq!(
            pooled.sepsets.sorted_entries(),
            single.sepsets.sorted_entries(),
            "sepset contents must be thread-count invariant"
        );
        let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
            r.levels
                .iter()
                .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                .collect()
        };
        assert_eq!(stats(&pooled), stats(&single));
    }
}
