//! cuPC-S (paper Algorithm 5, §3.4) as a batched schedule.
//!
//! Threads are assigned to *conditioning sets*, not edges: for each row i
//! of G', the `C(n'_i, ℓ)` sets S are walked in rounds of θ×δ in flight;
//! each set computes `pinv(C[S,S])` once and applies it to every live
//! candidate j ∈ row(i) \ S (paper key feature V — the dominant saving).
//! Candidates beyond the kernel's K-slot width spill into additional
//! batch rows (re-computing that pinv, the same duplication a CUDA
//! thread avoids by looping — bounded by ⌈n'_i/K⌉). Sharing is *local*
//! (within a row), matching §5.5's analysis that global sharing does not
//! pay for its search.

use super::batch::{Corr32, SBatch};
use super::comb::{n_sets_row, CombRange};
use super::engine::CiEngine;
use super::level0::run_level0;
use super::{should_continue, Config, LevelStats, SkeletonResult};
use crate::graph::adj::AdjMatrix;
use crate::graph::compact::CompactAdj;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::tau;
use crate::util::timer::Timer;
use anyhow::Result;

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    let mut engine = crate::runtime::engine_from_config(cfg)?;
    run_with_engine(corr, n, m, cfg, engine.as_mut())
}

pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    let graph = AdjMatrix::complete(n);
    let sepsets = SepSets::new();
    let corr32 = Corr32::from_f64(corr, n);
    let mut levels = Vec::new();

    levels.push(run_level0(corr, n, m, cfg, engine, &graph, &sepsets)?);

    let k = engine.k();
    let flight = (cfg.theta.max(1) * cfg.delta.max(1)) as u64; // sets in flight per row per round
    let mut l = 1usize;
    while should_continue(&graph, l, cfg) {
        let t = Timer::start();
        let taul = tau(m, l, cfg.alpha);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);

        let mut tests = 0u64;
        let mut removed = 0usize;
        let mut batch = SBatch::new(l, k, engine.batch_s());
        let mut ids = vec![0u32; l];
        let mut cand: Vec<u32> = Vec::new();

        // rows with enough neighbors, and their set counts
        let rows: Vec<(usize, u64)> = (0..n)
            .filter(|&i| comp.row_len(i) >= l + 1)
            .map(|i| (i, n_sets_row(comp.row_len(i), l)))
            .collect();
        let max_total = rows.iter().map(|&(_, t)| t).max().unwrap_or(0);

        let mut round = 0u64;
        while round * flight < max_total {
            let lo = round * flight;
            for &(i, total) in &rows {
                if lo >= total {
                    continue;
                }
                let row = comp.row(i);
                // §4.1: skip the whole row if no live edge remains
                if !row.iter().any(|&j| graph.has_edge(i, j as usize)) {
                    continue;
                }
                let hi = ((round + 1) * flight).min(total);
                let mut combs = CombRange::new(row.len(), l, lo, hi - lo);
                while let Some(sbuf) = combs.next_comb() {
                    for (dst, &pos) in ids.iter_mut().zip(sbuf) {
                        *dst = row[pos as usize];
                    }
                    // candidates: row members not in S with live edges
                    cand.clear();
                    for &ju in row {
                        if ids.contains(&ju) {
                            continue;
                        }
                        if graph.has_edge(i, ju as usize) {
                            cand.push(ju);
                        }
                    }
                    // spill into K-wide rows
                    for chunk in cand.chunks(k) {
                        batch.push_row(&corr32, i, &ids, chunk);
                        tests += chunk.len() as u64;
                        if batch.rows() >= engine.batch_s() {
                            removed += flush(&mut batch, engine, taul, &graph, &sepsets)?;
                        }
                    }
                }
            }
            if !batch.is_empty() {
                removed += flush(&mut batch, engine, taul, &graph, &sepsets)?;
            }
            round += 1;
        }

        levels.push(LevelStats {
            level: l,
            tests,
            removed,
            edges_after: graph.n_edges(),
            seconds: t.elapsed_s(),
        });
        if cfg.verbose {
            eprintln!(
                "[cupc-s] level {l}: {tests} tests, removed {removed}, {} edges left",
                graph.n_edges()
            );
        }
        l += 1;
    }

    Ok(SkeletonResult {
        graph,
        sepsets,
        levels,
    })
}

fn flush(
    batch: &mut SBatch,
    engine: &mut dyn CiEngine,
    taul: f64,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> Result<usize> {
    let z = engine.ci_s(
        batch.l,
        batch.rows(),
        batch.k,
        &batch.c_ij,
        &batch.m1,
        &batch.m2,
        &batch.valid,
    )?;
    let (removed, _moot) = batch.apply(&z, taul, graph, sepsets);
    batch.clear();
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_skeleton() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let serial = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_s.graph.snapshot(),
            serial.graph.snapshot(),
            "cuPC-S must produce the PC-stable skeleton"
        );
    }

    #[test]
    fn matches_cupc_e_skeleton_and_sepset_keys() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 45,
            m: 200,
            topology: datasets::Topology::Grn(1.6, 6),
            seed: 21,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_s = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let mut e = NativeEngine::new();
        let res_e =
            crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e)
                .unwrap();
        assert_eq!(res_s.graph.snapshot(), res_e.graph.snapshot());
        // same removed pairs (sepset contents may differ in S but the
        // key set must coincide)
        let keys = |r: &SkeletonResult| {
            r.sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect::<Vec<_>>()
        };
        assert_eq!(keys(&res_s), keys(&res_e));
    }

    #[test]
    fn theta_delta_config_does_not_change_result() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 120,
            topology: datasets::Topology::Er(0.1),
            seed: 31,
        });
        let c = correlation_matrix(&ds.data, 1);
        let a = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 32,
                delta: 1,
                ..Config::default()
            },
        );
        let b = run_native(
            &c,
            ds.data.n,
            ds.data.m,
            &Config {
                theta: 256,
                delta: 8,
                ..Config::default()
            },
        );
        assert_eq!(a.graph.snapshot(), b.graph.snapshot());
    }
}
