//! GPU baseline algorithm 1 of Fig. 5: Parallel-PC ported to the GPU —
//! every row a block, every edge a thread, and **all CI tests of an edge
//! sequential** in its thread. In the batched schedule this is exactly
//! cuPC-E with γ = 1 (one conditioning set in flight per edge per round),
//! keeping the same compaction, gather staging and early termination, as
//! the paper's comparison does — including the multi-threaded
//! pack→evaluate→apply pipeline when `Config::threads > 1` on the
//! native engine.

use super::{Config, SkeletonResult};
use anyhow::Result;

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    let cfg1 = Config {
        gamma: 1,
        beta: 1,
        ..cfg.clone()
    };
    super::gpu_e::run(corr, n, m, &cfg1)
}

/// Engine-injected variant for tests and the bench harness.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn super::engine::CiEngine,
) -> Result<SkeletonResult> {
    let cfg1 = Config {
        gamma: 1,
        beta: 1,
        ..cfg.clone()
    };
    super::gpu_e::run_with_engine(corr, n, m, &cfg1, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn baseline1_minimizes_tests() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 40,
            m: 100,
            topology: datasets::Topology::Er(0.1),
            seed: 13,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let mut e1 = NativeEngine::new();
        let r1 = run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e1).unwrap();
        let mut e2 = NativeEngine::new();
        let r2 = crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e2)
            .unwrap();
        // same skeleton, and the sequential baseline never tests more
        // than the γ=32 flight
        assert_eq!(r1.graph.snapshot(), r2.graph.snapshot());
        assert!(r1.total_tests() <= r2.total_tests());
    }
}
