//! Reversed-order pruning PC (arxiv 2109.04626) as a batched
//! [`RoundSchedule`] — the seventh PC family, and the proof that the
//! [`schedule`](super::schedule) seam is real: this module is the entire
//! algorithm, everything else is registration.
//!
//! The reversed-order idea is to spend CI tests where they pay: dense
//! nodes and high-index conditioning sets prune edges earlier, so fewer
//! tests run overall. Adapted to PC-stable's level-synchronous frame
//! (the outer level loop stays **ascending** — that frame is what makes
//! every family's skeleton bit-identical), the reversal happens *within*
//! each level:
//!
//! * **densest nodes first** — the level's edge tasks are stably sorted
//!   by descending `n'_i` (ties keep row-major order), so the rows most
//!   likely to lose edges are probed at the front of every round;
//! * **descending combination order** — round r evaluates combination
//!   index `total − 1 − r` for each live edge: the highest-index sets
//!   (the ones drawing from the *tail* of the neighbor row — see
//!   [`comb`](super::comb)'s lexicographic layout) run first;
//! * **one test in flight per edge** (γ = 1 semantics) — each verdict
//!   lands before the edge's next test is packed, so a removal cancels
//!   the edge's whole remaining budget; nothing is wasted in flight.
//!
//! The trade-off is the mirror image of cuPC-E's γ: minimal total tests,
//! minimal per-round batch width (one slot per live edge) — fewer,
//! narrower rounds for the engine to amortize. The conformance gate
//! (`tests/conformance_engines.rs`) asserts both halves: bit-identical
//! skeleton/sepset-keys/Majority-CPDAG on the full grid, and strictly
//! fewer total tests than cuPC-E on every dense grid point
//! (cross-checked against `tools/schedule_oracle.py`).

use super::engine::CiEngine;
use super::pipeline::Run;
use super::schedule::{
    build_edge_tasks, eval_edge_shard, run_rounds, run_rounds_with_engine, EdgeTask, LevelCtx,
    RoundSchedule,
};
use super::{Config, SkeletonResult};
use crate::skeleton::batch::Removals;
use anyhow::Result;

/// The reversed-order pruning schedule: densest-first tasks, descending
/// combination indices, one set in flight per edge per round.
pub struct ReversedSchedule {
    tasks: Vec<EdgeTask>,
    max_total: u64,
}

impl ReversedSchedule {
    pub fn new() -> ReversedSchedule {
        ReversedSchedule { tasks: Vec::new(), max_total: 0 }
    }
}

impl Default for ReversedSchedule {
    fn default() -> Self {
        Self::new()
    }
}

impl RoundSchedule for ReversedSchedule {
    fn label(&self) -> &'static str {
        "reversed"
    }

    fn begin_level(&mut self, ctx: &LevelCtx<'_>) {
        let (mut tasks, max_total) = build_edge_tasks(ctx);
        // densest rows first; the stable sort keeps row-major order
        // among equal degrees, so the canonical slot order is still
        // deterministic
        tasks.sort_by_key(|t| std::cmp::Reverse(t.row_len));
        self.tasks = tasks;
        self.max_total = max_total;
    }

    fn rounds_done(&self, round: u64) -> bool {
        round >= self.max_total
    }

    fn visit_round(&self, ctx: &LevelCtx<'_>, round: u64, emit: &mut dyn FnMut(Run)) {
        for (ti, task) in self.tasks.iter().enumerate() {
            if round >= task.total {
                continue; // this edge's sets are exhausted
            }
            if !ctx.graph.has_edge(task.i as usize, task.j as usize) {
                continue; // pruned in an earlier round — budget cancelled
            }
            // walk the combination index space from the top down
            emit(Run { task: ti, t0: task.total - 1 - round, count: 1 });
        }
    }

    fn eval_shard(
        &self,
        ctx: &LevelCtx<'_>,
        shard: &[Run],
        engine: &mut dyn CiEngine,
    ) -> Result<(Removals, u64)> {
        eval_edge_shard(&self.tasks, ctx, shard, engine)
    }
}

pub fn run(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<SkeletonResult> {
    run_rounds(corr, n, m, cfg, &mut ReversedSchedule::new())
}

/// Single-engine entry point (tests, XLA, bench harnesses): the same
/// pipeline inline — results are bit-identical to the pool path.
pub fn run_with_engine(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
) -> Result<SkeletonResult> {
    run_rounds_with_engine(corr, n, m, cfg, &mut ReversedSchedule::new(), engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::adj::AdjMatrix;
    use crate::graph::compact::CompactAdj;
    use crate::skeleton::batch::Corr32;
    use crate::skeleton::comb::n_sets_edge;
    use crate::skeleton::engine::NativeEngine;
    use crate::skeleton::pipeline::use_pool;
    use crate::skeleton::EngineKind;
    use crate::sim::datasets;
    use crate::stats::corr::correlation_matrix;

    fn run_native(corr: &[f64], n: usize, m: usize, cfg: &Config) -> SkeletonResult {
        let mut e = NativeEngine::new();
        run_with_engine(corr, n, m, cfg, &mut e).unwrap()
    }

    #[test]
    fn matches_serial_on_er_graph() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 50,
            m: 150,
            topology: datasets::Topology::Er(0.08),
            seed: 11,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_r = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let res_s = crate::skeleton::serial::run(&c, ds.data.n, ds.data.m, &cfg).unwrap();
        assert_eq!(
            res_r.graph.snapshot(),
            res_s.graph.snapshot(),
            "reversed-order pruning must produce the PC-stable skeleton"
        );
    }

    /// Flight size 1 with cancel-on-removal can never test more than
    /// cuPC-E's ascending γ = 1 schedule *plus* it starts at the
    /// high-index sets — on the same input the totals may differ but the
    /// skeletons and sepset keys cannot.
    #[test]
    fn matches_cupc_e_skeleton_and_sepset_keys() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 45,
            m: 200,
            topology: datasets::Topology::Grn(1.6, 6),
            seed: 21,
        });
        let c = correlation_matrix(&ds.data, 1);
        let cfg = Config::default();
        let res_r = run_native(&c, ds.data.n, ds.data.m, &cfg);
        let mut e = NativeEngine::new();
        let res_e =
            crate::skeleton::gpu_e::run_with_engine(&c, ds.data.n, ds.data.m, &cfg, &mut e)
                .unwrap();
        assert_eq!(res_r.graph.snapshot(), res_e.graph.snapshot());
        let keys = |r: &SkeletonResult| {
            r.sepsets
                .sorted_entries()
                .into_iter()
                .map(|(k, _)| k)
                .collect::<Vec<_>>()
        };
        assert_eq!(keys(&res_r), keys(&res_e));
    }

    /// The schedule's shape, checked directly against the trait: tasks
    /// come out densest-first, and successive rounds walk each edge's
    /// combination indices strictly downward from `total - 1`.
    #[test]
    fn lists_descending_windows_densest_first() {
        let n = 6;
        let graph = AdjMatrix::complete(n);
        graph.remove_edge(0, 1); // rows 0 and 1 are now sparser
        graph.remove_edge(0, 2);
        let mut corr = vec![0.1; n * n];
        for i in 0..n {
            corr[i * n + i] = 1.0;
        }
        let corr32 = Corr32::from_f64(&corr, n);
        let snap = graph.snapshot();
        let comp = CompactAdj::from_snapshot(&snap, n);
        let graph = crate::oocore::sparse::Adj::Dense(graph);
        let l = 2;
        let ctx = LevelCtx { comp: &comp, graph: &graph, corr32: &corr32, l, taul: 1.0 };

        let mut sched = ReversedSchedule::new();
        sched.begin_level(&ctx);
        let mut prev = u32::MAX;
        for t in &sched.tasks {
            assert!(t.row_len <= prev, "tasks must be densest-first");
            prev = t.row_len;
        }
        assert_eq!(sched.max_total, n_sets_edge(5, l));

        let mut runs0 = Vec::new();
        let mut runs1 = Vec::new();
        sched.list_round(&ctx, 0, &mut runs0);
        sched.list_round(&ctx, 1, &mut runs1);
        assert_eq!(runs0.len(), sched.tasks.len(), "round 0: every edge live");
        for r in runs0.iter().chain(&runs1) {
            assert_eq!(r.count, 1, "one set in flight per edge");
        }
        for (a, b) in runs0.iter().zip(&runs1) {
            assert_eq!(a.task, b.task);
            assert_eq!(a.t0, sched.tasks[a.task].total - 1);
            assert_eq!(b.t0, sched.tasks[b.task].total - 2, "strictly descending");
        }
        assert!(!sched.rounds_done(sched.max_total - 1));
        assert!(sched.rounds_done(sched.max_total));
    }

    /// The tentpole determinism contract at module level: the pool path
    /// must be bit-identical to the single-engine path, including
    /// per-level test counts.
    #[test]
    fn pool_path_matches_single_engine_bitwise() {
        let ds = datasets::generate(&datasets::DatasetSpec {
            name: "t",
            n: 48,
            m: 200,
            topology: datasets::Topology::Grn(1.8, 6),
            seed: 19,
        });
        let c = correlation_matrix(&ds.data, 1);
        let pooled_cfg = Config {
            variant: crate::skeleton::Variant::Reversed,
            engine: EngineKind::Native,
            threads: 4,
            ..Config::default()
        };
        assert!(use_pool(&pooled_cfg));
        let pooled = run(&c, ds.data.n, ds.data.m, &pooled_cfg).unwrap();
        let single = run_native(&c, ds.data.n, ds.data.m, &pooled_cfg);
        assert_eq!(pooled.graph.snapshot(), single.graph.snapshot());
        assert_eq!(
            pooled.sepsets.sorted_entries(),
            single.sepsets.sorted_entries(),
            "sepset contents must be thread-count invariant"
        );
        let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
            r.levels
                .iter()
                .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                .collect()
        };
        assert_eq!(stats(&pooled), stats(&single));
    }
}
