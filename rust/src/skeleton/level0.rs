//! Level 0 (paper Algorithm 3): one CI test per pair, no conditioning.
//!
//! The CUDA 2-D grid over the n×n matrix becomes a packed batch of the
//! upper-triangle correlations; τ comparison and removal happen in apply
//! order. Shared by all GPU-schedule variants (serial/threaded CPU
//! engines do level 0 inline).

use super::engine::CiEngine;
use super::{Config, LevelStats};
use crate::graph::adj::AdjMatrix;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::{independent, tau};
use crate::util::timer::Timer;
use anyhow::Result;

/// Run level 0 on the (still complete) graph. Returns its stats.
pub fn run_level0(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> Result<LevelStats> {
    let t = Timer::start();
    if n < 2 {
        // no pairs to test: short-circuit before the n·(n−1)/2 capacity
        // math, which underflows in debug builds when n == 0
        return Ok(LevelStats {
            level: 0,
            seconds: t.elapsed_s(),
            ..LevelStats::default()
        });
    }
    let tau0 = tau(m, 0, cfg.alpha);
    // pack the upper triangle
    let mut c_ij = Vec::with_capacity(n * (n - 1) / 2);
    let mut pairs = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            c_ij.push(corr[i * n + j] as f32);
            pairs.push((i as u32, j as u32));
        }
    }
    let mut removed = 0;
    // chunk through the engine at its preferred batch size
    let chunk = engine.batch_e().max(1);
    for (cs, ps) in c_ij.chunks(chunk).zip(pairs.chunks(chunk)) {
        let z = engine.level0(cs)?;
        for (idx, &(i, j)) in ps.iter().enumerate() {
            if independent(z[idx] as f64, tau0) && graph.remove_edge(i as usize, j as usize) {
                sepsets.store(i as usize, j as usize, &[]);
                removed += 1;
            }
        }
    }
    Ok(LevelStats {
        level: 0,
        tests: c_ij.len() as u64,
        removed,
        edges_after: graph.n_edges(),
        seconds: t.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;

    #[test]
    fn removes_only_weak_correlations() {
        // 3 vars: c01 strong, c02 ~ 0, c12 strong
        let c = vec![1.0, 0.9, 0.001, 0.9, 1.0, 0.8, 0.001, 0.8, 1.0];
        let g = AdjMatrix::complete(3);
        let sep = SepSets::new();
        let cfg = Config::default();
        let mut e = NativeEngine::new();
        let stats = run_level0(&c, 3, 1000, &cfg, &mut e, &g, &sep).unwrap();
        assert_eq!(stats.tests, 3);
        assert_eq!(stats.removed, 1);
        assert!(!g.has_edge(0, 2));
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2));
        assert_eq!(sep.get(0, 2), Some(vec![]));
        assert_eq!(stats.edges_after, 2);
    }

    /// Regression: n = 0 underflowed `n * (n - 1) / 2` in debug builds;
    /// n = 1 has no pairs either. Both must be clean no-ops.
    #[test]
    fn degenerate_inputs_no_pairs_no_panic() {
        let cfg = Config::default();
        for n in [0usize, 1] {
            let corr = vec![1.0; n * n];
            let g = AdjMatrix::complete(n);
            let sep = SepSets::new();
            let mut e = NativeEngine::new();
            let stats = run_level0(&corr, n, 1000, &cfg, &mut e, &g, &sep).unwrap();
            assert_eq!(stats.level, 0, "n={n}");
            assert_eq!(stats.tests, 0, "n={n}");
            assert_eq!(stats.removed, 0, "n={n}");
            assert_eq!(stats.edges_after, 0, "n={n}");
            assert!(sep.is_empty(), "n={n}");
        }
    }

    #[test]
    fn small_m_removes_everything() {
        // tau = inf when m - 3 <= 0: every pair "independent"
        let c = vec![1.0, 0.9, 0.9, 1.0];
        let g = AdjMatrix::complete(2);
        let sep = SepSets::new();
        let cfg = Config::default();
        let mut e = NativeEngine::new();
        let stats = run_level0(&c, 2, 3, &cfg, &mut e, &g, &sep).unwrap();
        assert_eq!(stats.removed, 1);
        assert_eq!(g.n_edges(), 0);
    }
}
