//! Level 0 (paper Algorithm 3): one CI test per pair, no conditioning.
//!
//! Evaluation goes through [`crate::stats::kernels::level0`]; level 0
//! is elementwise, so both kernel paths share the single scalar
//! implementation (see `docs/NUMERICS.md`).
//!
//! The CUDA 2-D grid over the n×n matrix becomes the canonical pair
//! enumeration (row-major upper triangle). [`eval_range`] evaluates any
//! contiguous slot window of that enumeration — the unit the pipeline
//! executor shards across workers — and [`apply_candidates`] replays the
//! independence verdicts in canonical order, so the sharded sweep is
//! bit-identical to the single-engine one. Shared by all GPU-schedule
//! variants (serial/threaded CPU engines do level 0 inline).

use super::engine::CiEngine;
use super::{Config, LevelStats};
use crate::graph::adj::AdjMatrix;
use crate::graph::sepset::SepSets;
use crate::stats::fisher::{independent, tau};
use crate::util::timer::Timer;
use anyhow::Result;

/// Number of unordered pairs — the level-0 test count (0 for n < 2; the
/// guard keeps the `n·(n−1)` product out of underflow territory).
pub fn n_pairs(n: usize) -> u64 {
    if n < 2 {
        0
    } else {
        (n as u64) * (n as u64 - 1) / 2
    }
}

/// Map a canonical pair index `t` (row-major upper triangle: (0,1),
/// (0,2), …, (0,n−1), (1,2), …) to its `(i, j)` pair.
pub fn pair_at(n: usize, t: u64) -> (usize, usize) {
    assert!(t < n_pairs(n), "pair index {t} out of range for n={n}");
    let mut i = 0usize;
    let mut base = 0u64;
    loop {
        let row = (n - 1 - i) as u64;
        if t < base + row {
            return (i, i + 1 + (t - base) as usize);
        }
        base += row;
        i += 1;
    }
}

/// Evaluate canonical pair slots `[t0, t0 + count)` and return the
/// independence candidates in slot order. Pure with respect to the
/// graph; level 0 is an elementwise map, so chunk and shard boundaries
/// never change per-slot verdicts.
pub fn eval_range(
    corr: &[f64],
    n: usize,
    tau0: f64,
    t0: u64,
    count: u64,
    engine: &mut dyn CiEngine,
) -> Result<Vec<(u32, u32)>> {
    let mut out = Vec::new();
    if count == 0 {
        return Ok(out);
    }
    let cap = engine.batch_e().max(1);
    let (mut i, mut j) = pair_at(n, t0);
    let buf_cap = cap.min(count as usize);
    let mut c_buf: Vec<f32> = Vec::with_capacity(buf_cap);
    let mut p_buf: Vec<(u32, u32)> = Vec::with_capacity(buf_cap);
    let mut left = count;
    while left > 0 {
        c_buf.clear();
        p_buf.clear();
        while left > 0 && c_buf.len() < cap {
            c_buf.push(corr[i * n + j] as f32);
            p_buf.push((i as u32, j as u32));
            left -= 1;
            j += 1;
            if j == n {
                i += 1;
                j = i + 1;
            }
        }
        let z = engine.level0(&c_buf)?;
        for (idx, &(a, b)) in p_buf.iter().enumerate() {
            if independent(z[idx] as f64, tau0) {
                out.push((a, b));
            }
        }
    }
    Ok(out)
}

/// Complement of [`eval_range`] over the same window: the pairs of
/// `[t0, t0 + count)` that are *not* in `removed` (which must be the
/// window's candidates in slot order, as `eval_range` returns them).
/// The out-of-core driver exchanges survivor lists — O(edges) in the
/// sparse regimes it targets, where the candidate list is O(n²).
pub fn survivors_of_range(
    n: usize,
    t0: u64,
    count: u64,
    removed: &[(u32, u32)],
) -> Vec<(u32, u32)> {
    let mut out = Vec::with_capacity((count as usize).saturating_sub(removed.len()));
    if count == 0 {
        return out;
    }
    let (mut i, mut j) = pair_at(n, t0);
    let mut skip = removed.iter().peekable();
    for _ in 0..count {
        let pair = (i as u32, j as u32);
        if skip.peek() == Some(&&pair) {
            skip.next();
        } else {
            out.push(pair);
        }
        j += 1;
        if j == n {
            i += 1;
            j = i + 1;
        }
    }
    debug_assert!(skip.next().is_none(), "removed pair outside the window");
    out
}

/// Apply level-0 independence candidates in the order given (canonical
/// slot order when shards are concatenated in order). Returns the number
/// of edges removed.
pub fn apply_candidates(graph: &AdjMatrix, sepsets: &SepSets, candidates: &[(u32, u32)]) -> usize {
    let mut removed = 0;
    for &(i, j) in candidates {
        if graph.remove_edge(i as usize, j as usize) {
            sepsets.store(i as usize, j as usize, &[]);
            removed += 1;
        }
    }
    removed
}

/// Run level 0 on the (still complete) graph through one engine. The
/// multi-worker path shards [`eval_range`] instead — see
/// [`super::pipeline::Executor::run_level0`].
pub fn run_level0(
    corr: &[f64],
    n: usize,
    m: usize,
    cfg: &Config,
    engine: &mut dyn CiEngine,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> Result<LevelStats> {
    let t = Timer::start();
    let total = n_pairs(n);
    if total == 0 {
        // no pairs to test (n < 2): a clean no-op
        return Ok(LevelStats {
            level: 0,
            seconds: t.elapsed_s(),
            ..LevelStats::default()
        });
    }
    let tau0 = tau(m, 0, cfg.alpha);
    let candidates = eval_range(corr, n, tau0, 0, total, engine)?;
    let removed = apply_candidates(graph, sepsets, &candidates);
    Ok(LevelStats {
        level: 0,
        tests: total,
        removed,
        edges_after: graph.n_edges(),
        seconds: t.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::engine::NativeEngine;

    #[test]
    fn removes_only_weak_correlations() {
        // 3 vars: c01 strong, c02 ~ 0, c12 strong
        let c = vec![1.0, 0.9, 0.001, 0.9, 1.0, 0.8, 0.001, 0.8, 1.0];
        let g = AdjMatrix::complete(3);
        let sep = SepSets::new();
        let cfg = Config::default();
        let mut e = NativeEngine::new();
        let stats = run_level0(&c, 3, 1000, &cfg, &mut e, &g, &sep).unwrap();
        assert_eq!(stats.tests, 3);
        assert_eq!(stats.removed, 1);
        assert!(!g.has_edge(0, 2));
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2));
        assert_eq!(sep.get(0, 2), Some(vec![]));
        assert_eq!(stats.edges_after, 2);
    }

    /// Regression: n = 0 underflowed `n * (n - 1) / 2` in debug builds;
    /// n = 1 has no pairs either. Both must be clean no-ops.
    #[test]
    fn degenerate_inputs_no_pairs_no_panic() {
        let cfg = Config::default();
        for n in [0usize, 1] {
            let corr = vec![1.0; n * n];
            let g = AdjMatrix::complete(n);
            let sep = SepSets::new();
            let mut e = NativeEngine::new();
            let stats = run_level0(&corr, n, 1000, &cfg, &mut e, &g, &sep).unwrap();
            assert_eq!(stats.level, 0, "n={n}");
            assert_eq!(stats.tests, 0, "n={n}");
            assert_eq!(stats.removed, 0, "n={n}");
            assert_eq!(stats.edges_after, 0, "n={n}");
            assert!(sep.is_empty(), "n={n}");
        }
    }

    #[test]
    fn small_m_removes_everything() {
        // tau = inf when m - 3 <= 0: every pair "independent"
        let c = vec![1.0, 0.9, 0.9, 1.0];
        let g = AdjMatrix::complete(2);
        let sep = SepSets::new();
        let cfg = Config::default();
        let mut e = NativeEngine::new();
        let stats = run_level0(&c, 2, 3, &cfg, &mut e, &g, &sep).unwrap();
        assert_eq!(stats.removed, 1);
        assert_eq!(g.n_edges(), 0);
    }

    #[test]
    fn pair_enumeration_is_row_major_upper_triangle() {
        assert_eq!(n_pairs(0), 0);
        assert_eq!(n_pairs(1), 0);
        assert_eq!(n_pairs(5), 10);
        let n = 5;
        let mut t = 0u64;
        for i in 0..n {
            for j in (i + 1)..n {
                assert_eq!(pair_at(n, t), (i, j), "t={t}");
                t += 1;
            }
        }
        assert_eq!(t, n_pairs(n));
    }

    #[test]
    fn survivors_complement_the_candidates() {
        let n = 6;
        let total = n_pairs(n);
        // remove a scattered subset, in slot order
        let removed = vec![(0u32, 1u32), (0, 4), (2, 3), (4, 5)];
        let survivors = survivors_of_range(n, 0, total, &removed);
        assert_eq!(survivors.len() as u64, total - removed.len() as u64);
        for &(a, b) in &removed {
            assert!(!survivors.contains(&(a, b)));
        }
        // windowed sweep concatenates to the full sweep
        let mut windowed = Vec::new();
        let mut t0 = 0u64;
        for count in [4u64, 1, 7, 3] {
            let lo = t0;
            let hi = t0 + count;
            let in_window: Vec<(u32, u32)> = (lo..hi)
                .map(|t| pair_at(n, t))
                .map(|(a, b)| (a as u32, b as u32))
                .filter(|p| removed.contains(p))
                .collect();
            windowed.extend(survivors_of_range(n, t0, count, &in_window));
            t0 = hi;
        }
        assert_eq!(t0, total);
        assert_eq!(windowed, survivors);
        assert!(survivors_of_range(n, 3, 0, &[]).is_empty());
    }

    /// The sharding contract: evaluating the canonical sweep as any
    /// split of contiguous windows concatenates to the full sweep's
    /// candidate list, bit for bit.
    #[test]
    fn eval_range_is_split_invariant() {
        use crate::util::rng::Pcg;
        let n = 17;
        let mut rng = Pcg::seeded(31);
        let mut corr = vec![0.0; n * n];
        for i in 0..n {
            corr[i * n + i] = 1.0;
            for j in (i + 1)..n {
                let c = rng.uniform_in(-0.6, 0.6);
                corr[i * n + j] = c;
                corr[j * n + i] = c;
            }
        }
        let m = 120;
        let tau0 = tau(m, 0, 0.01);
        let total = n_pairs(n);
        let mut full_engine = NativeEngine::new();
        let full = eval_range(&corr, n, tau0, 0, total, &mut full_engine).unwrap();
        assert!(!full.is_empty(), "workload too easy to be a meaningful test");
        for parts in [2u64, 3, 7, total] {
            let mut split = Vec::new();
            let per = total.div_ceil(parts);
            let mut t0 = 0u64;
            while t0 < total {
                let count = per.min(total - t0);
                // a fresh engine per window, like a pool worker gets
                let mut e = NativeEngine::new();
                split.extend(eval_range(&corr, n, tau0, t0, count, &mut e).unwrap());
                t0 += count;
            }
            assert_eq!(split, full, "parts={parts}");
        }
    }
}
