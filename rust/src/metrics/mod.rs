//! Structure-recovery metrics: SHD, TDR/precision/recall/F1 on
//! skeletons, plus level-timing aggregation helpers used by the
//! experiment harness.

use crate::graph::cpdag::Cpdag;
use crate::skeleton::LevelStats;

/// Skeleton confusion counts between an estimated dense 0/1 skeleton and
/// the ground truth (both symmetric, n×n).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SkeletonMetrics {
    pub tp: usize,
    pub fp: usize,
    pub fn_: usize,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    /// true discovery rate == precision (paper's TDR)
    pub tdr: f64,
}

pub fn skeleton_metrics(est: &[u8], truth: &[u8], n: usize) -> SkeletonMetrics {
    assert_eq!(est.len(), n * n);
    assert_eq!(truth.len(), n * n);
    let (mut tp, mut fp, mut fn_) = (0usize, 0usize, 0usize);
    for i in 0..n {
        for j in (i + 1)..n {
            let e = est[i * n + j] != 0;
            let t = truth[i * n + j] != 0;
            match (e, t) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, true) => fn_ += 1,
                _ => {}
            }
        }
    }
    let precision = if tp + fp > 0 {
        tp as f64 / (tp + fp) as f64
    } else {
        1.0
    };
    let recall = if tp + fn_ > 0 {
        tp as f64 / (tp + fn_) as f64
    } else {
        1.0
    };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    SkeletonMetrics {
        tp,
        fp,
        fn_,
        precision,
        recall,
        f1,
        tdr: precision,
    }
}

/// Structural Hamming distance between two CPDAGs: number of ordered
/// pairs whose mark differs (missing vs undirected vs each direction),
/// counted once per unordered pair.
pub fn shd(a: &Cpdag, b: &Cpdag) -> usize {
    assert_eq!(a.n(), b.n());
    let n = a.n();
    let code = |g: &Cpdag, i: usize, j: usize| -> u8 {
        if g.is_undirected(i, j) {
            1
        } else if g.is_directed(i, j) {
            2
        } else if g.is_directed(j, i) {
            3
        } else {
            0
        }
    };
    let mut d = 0;
    for i in 0..n {
        for j in (i + 1)..n {
            if code(a, i, j) != code(b, i, j) {
                d += 1;
            }
        }
    }
    d
}

/// Percent of total runtime per level (Fig. 6 rows).
pub fn level_time_shares(levels: &[LevelStats]) -> Vec<(usize, f64)> {
    let total: f64 = levels.iter().map(|l| l.seconds).sum();
    levels
        .iter()
        .map(|l| {
            (
                l.level,
                if total > 0.0 {
                    100.0 * l.seconds / total
                } else {
                    0.0
                },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_recovery() {
        let t = vec![0, 1, 1, 0];
        let m = skeleton_metrics(&t, &t, 2);
        assert_eq!(m.tp, 1);
        assert_eq!(m.fp, 0);
        assert_eq!(m.f1, 1.0);
        assert_eq!(m.tdr, 1.0);
    }

    #[test]
    fn false_positive_counted() {
        let truth = vec![0u8; 9];
        let mut est = vec![0u8; 9];
        est[1] = 1;
        est[3] = 1;
        let m = skeleton_metrics(&est, &truth, 3);
        assert_eq!(m.fp, 1);
        assert_eq!(m.tp, 0);
        assert_eq!(m.precision, 0.0);
    }

    #[test]
    fn shd_counts_mark_differences() {
        let skel = vec![0, 1, 1, 0];
        let a = Cpdag::from_skeleton(&skel, 2);
        let mut b = Cpdag::from_skeleton(&skel, 2);
        assert_eq!(shd(&a, &b), 0);
        b.orient(0, 1);
        assert_eq!(shd(&a, &b), 1);
        let c = Cpdag::new(2); // empty
        assert_eq!(shd(&a, &c), 1);
    }

    #[test]
    fn time_shares_sum_to_100() {
        let levels = vec![
            LevelStats {
                level: 0,
                seconds: 1.0,
                ..Default::default()
            },
            LevelStats {
                level: 1,
                seconds: 3.0,
                ..Default::default()
            },
        ];
        let shares = level_time_shares(&levels);
        assert!((shares[0].1 - 25.0).abs() < 1e-9);
        assert!((shares[1].1 - 75.0).abs() < 1e-9);
    }
}
