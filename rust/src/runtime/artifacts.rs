//! Artifact manifest + compile-once executable cache.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every HLO-text artifact (kind, level, batch geometry). The store
//! parses it (with a small built-in JSON reader — no serde offline),
//! compiles each artifact on first use through the PJRT CPU client and
//! caches the loaded executable for the rest of the process lifetime.

use anyhow::{anyhow, Context, Result};
#[cfg(feature = "xla")]
use anyhow::bail;
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "xla")]
use std::path::PathBuf;

/// Metadata for one artifact, mirroring aot.py's manifest entries.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub kind: String,
    pub l: usize,
    pub b: usize,
    pub k: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub max_level: usize,
    pub b0: usize,
    pub be: usize,
    pub bs: usize,
    pub k: usize,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text)?;
        let obj = v.as_object().ok_or_else(|| anyhow!("manifest: not an object"))?;
        let get_usize = |k: &str| -> Result<usize> {
            obj.get(k)
                .and_then(|x| x.as_f64())
                .map(|f| f as usize)
                .ok_or_else(|| anyhow!("manifest: missing numeric field {k}"))
        };
        let mut artifacts = HashMap::new();
        let arts = obj
            .get("artifacts")
            .and_then(|x| x.as_object())
            .ok_or_else(|| anyhow!("manifest: missing artifacts object"))?;
        for (name, meta) in arts {
            let mo = meta
                .as_object()
                .ok_or_else(|| anyhow!("manifest: artifact {name} not an object"))?;
            let gets = |k: &str| mo.get(k).and_then(|x| x.as_str()).map(|s| s.to_string());
            let getn = |k: &str| mo.get(k).and_then(|x| x.as_f64()).map(|f| f as usize);
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: gets("file").ok_or_else(|| anyhow!("{name}: missing file"))?,
                    kind: gets("kind").ok_or_else(|| anyhow!("{name}: missing kind"))?,
                    l: getn("l").unwrap_or(0),
                    b: getn("b").ok_or_else(|| anyhow!("{name}: missing b"))?,
                    k: getn("k").unwrap_or(0),
                },
            );
        }
        Ok(Manifest {
            max_level: get_usize("max_level")?,
            b0: get_usize("b0")?,
            be: get_usize("be")?,
            bs: get_usize("bs")?,
            k: get_usize("k")?,
            artifacts,
        })
    }
}

/// Compile-once cache of loaded PJRT executables.
#[cfg(feature = "xla")]
pub struct ArtifactStore {
    dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl ArtifactStore {
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    /// Fetch (compiling on first use) the executable for `name`.
    pub fn get(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            let path = self.dir.join(&meta.file);
            if !path.exists() {
                bail!("artifact file missing: {} (run `make artifacts`)", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    /// Number of compiled-and-cached executables (for perf accounting).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Compile every artifact up front — pulls PJRT compilation out of
    /// the level loop so per-level timings measure execution only.
    pub fn compile_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for n in names {
            self.get(&n)?;
        }
        Ok(())
    }

    /// Compile the artifacts PC runs touch on virtually every dataset
    /// (level 0 and conditioning sets up to `max_l`); deeper levels
    /// compile lazily on first use. Keeps startup latency bounded while
    /// still keeping compilation out of the common levels' timings.
    pub fn compile_common(&mut self, max_l: usize) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|(_, meta)| meta.kind == "level0" || meta.l <= max_l)
            .map(|(n, _)| n.clone())
            .collect();
        for n in names {
            self.get(&n)?;
        }
        Ok(())
    }
}

#[cfg(feature = "xla")]
thread_local! {
    /// Process-wide (per-thread) store registry: artifact compilation is
    /// paid once per process, not once per `run_skeleton` call. PJRT
    /// types are not Send, hence thread-local rather than a global.
    static STORES: std::cell::RefCell<HashMap<PathBuf, std::rc::Rc<std::cell::RefCell<ArtifactStore>>>> =
        std::cell::RefCell::new(HashMap::new());
}

/// Fetch (or create + eagerly compile) the shared store for a directory.
#[cfg(feature = "xla")]
pub fn shared_store(dir: &Path) -> Result<std::rc::Rc<std::cell::RefCell<ArtifactStore>>> {
    let key = dir
        .canonicalize()
        .unwrap_or_else(|_| dir.to_path_buf());
    STORES.with(|s| {
        let mut map = s.borrow_mut();
        if let Some(store) = map.get(&key) {
            return Ok(store.clone());
        }
        let mut store = ArtifactStore::open(dir)?;
        store.compile_all()?;
        let rc = std::rc::Rc::new(std::cell::RefCell::new(store));
        map.insert(key, rc.clone());
        Ok(rc)
    })
}

/// Minimal JSON parser (objects, arrays, strings, numbers, bools, null)
/// — sufficient for manifest.json; serde is unavailable offline.
pub(crate) mod json {
    use anyhow::{bail, Result};
    use std::collections::BTreeMap;

    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(BTreeMap<String, Value>),
    }

    impl Value {
        pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
            match self {
                Value::Obj(m) => Some(m),
                _ => None,
            }
        }
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(f) => Some(*f),
                _ => None,
            }
        }
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], p: &mut usize) {
        while *p < b.len() && (b[*p] as char).is_ascii_whitespace() {
            *p += 1;
        }
    }

    fn parse_value(b: &[u8], p: &mut usize) -> Result<Value> {
        skip_ws(b, p);
        if *p >= b.len() {
            bail!("unexpected end of input");
        }
        match b[*p] {
            b'{' => parse_object(b, p),
            b'[' => parse_array(b, p),
            b'"' => Ok(Value::Str(parse_string(b, p)?)),
            b't' => {
                expect(b, p, "true")?;
                Ok(Value::Bool(true))
            }
            b'f' => {
                expect(b, p, "false")?;
                Ok(Value::Bool(false))
            }
            b'n' => {
                expect(b, p, "null")?;
                Ok(Value::Null)
            }
            _ => parse_number(b, p),
        }
    }

    fn expect(b: &[u8], p: &mut usize, word: &str) -> Result<()> {
        if b.len() - *p < word.len() || &b[*p..*p + word.len()] != word.as_bytes() {
            bail!("expected {word} at byte {p}");
        }
        *p += word.len();
        Ok(())
    }

    fn parse_object(b: &[u8], p: &mut usize) -> Result<Value> {
        *p += 1; // {
        let mut map = BTreeMap::new();
        skip_ws(b, p);
        if *p < b.len() && b[*p] == b'}' {
            *p += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            skip_ws(b, p);
            let key = parse_string(b, p)?;
            skip_ws(b, p);
            if *p >= b.len() || b[*p] != b':' {
                bail!("expected ':' at byte {p}");
            }
            *p += 1;
            let val = parse_value(b, p)?;
            map.insert(key, val);
            skip_ws(b, p);
            match b.get(*p) {
                Some(b',') => *p += 1,
                Some(b'}') => {
                    *p += 1;
                    return Ok(Value::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {p}"),
            }
        }
    }

    fn parse_array(b: &[u8], p: &mut usize) -> Result<Value> {
        *p += 1; // [
        let mut arr = Vec::new();
        skip_ws(b, p);
        if *p < b.len() && b[*p] == b']' {
            *p += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(parse_value(b, p)?);
            skip_ws(b, p);
            match b.get(*p) {
                Some(b',') => *p += 1,
                Some(b']') => {
                    *p += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => bail!("expected ',' or ']' at byte {p}"),
            }
        }
    }

    fn parse_string(b: &[u8], p: &mut usize) -> Result<String> {
        if b.get(*p) != Some(&b'"') {
            bail!("expected string at byte {p}");
        }
        *p += 1;
        let mut s = String::new();
        while *p < b.len() {
            match b[*p] {
                b'"' => {
                    *p += 1;
                    return Ok(s);
                }
                b'\\' => {
                    *p += 1;
                    match b.get(*p) {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&b[*p + 1..*p + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('?'));
                            *p += 4;
                        }
                        _ => bail!("bad escape at byte {p}"),
                    }
                    *p += 1;
                }
                c => {
                    // collect UTF-8 bytes verbatim
                    let start = *p;
                    let len = utf8_len(c);
                    s.push_str(std::str::from_utf8(&b[start..start + len])?);
                    *p += len;
                }
            }
        }
        bail!("unterminated string")
    }

    fn utf8_len(first: u8) -> usize {
        match first {
            0x00..=0x7f => 1,
            0xc0..=0xdf => 2,
            0xe0..=0xef => 3,
            _ => 4,
        }
    }

    fn parse_number(b: &[u8], p: &mut usize) -> Result<Value> {
        let start = *p;
        while *p < b.len()
            && matches!(b[*p], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            *p += 1;
        }
        let s = std::str::from_utf8(&b[start..*p])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parses_nested() {
            let v = parse(r#"{"a": 1, "b": {"c": [1, 2.5, "x"], "d": true}, "e": null}"#)
                .unwrap();
            let o = v.as_object().unwrap();
            assert_eq!(o.get("a").unwrap().as_f64(), Some(1.0));
            let b = o.get("b").unwrap().as_object().unwrap();
            assert_eq!(b.get("d").unwrap(), &Value::Bool(true));
            match b.get("c").unwrap() {
                Value::Arr(a) => {
                    assert_eq!(a.len(), 3);
                    assert_eq!(a[2].as_str(), Some("x"));
                }
                _ => panic!(),
            }
        }

        #[test]
        fn parses_escapes() {
            let v = parse(r#""a\nb\t\"q\"""#).unwrap();
            assert_eq!(v.as_str(), Some("a\nb\t\"q\""));
        }

        #[test]
        fn rejects_garbage() {
            assert!(parse("{").is_err());
            assert!(parse("[1,]").is_err());
            assert!(parse("{} x").is_err());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "max_level": 8, "b0": 4096, "be": 4096, "bs": 256, "k": 32,
      "artifacts": {
        "level0": {"kind": "level0", "b": 4096, "file": "level0.hlo.txt", "sha256": "x"},
        "ci_e_l2": {"kind": "ci_e", "l": 2, "b": 4096, "file": "ci_e_l2.hlo.txt", "sha256": "y"}
      }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.max_level, 8);
        assert_eq!(m.be, 4096);
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts["ci_e_l2"];
        assert_eq!(a.l, 2);
        assert_eq!(a.kind, "ci_e");
        assert_eq!(a.file, "ci_e_l2.hlo.txt");
    }

    #[test]
    fn manifest_missing_fields_rejected() {
        assert!(Manifest::parse(r#"{"max_level": 8}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
