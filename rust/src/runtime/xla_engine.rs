//! The XLA-backed CI engine: executes the AOT Pallas/JAX kernels through
//! the PJRT CPU client. Batches of arbitrary size are chunked to the
//! artifact's static batch dimension and zero-padded (zero blocks are
//! numerically inert: ρ = 0, z = 0, and padded verdicts are discarded by
//! the packers' apply step anyway).

use super::artifacts::{shared_store, ArtifactStore};
use crate::skeleton::engine::CiEngine;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;

pub struct XlaEngine {
    /// shared, process-wide compiled-executable store (compilation is a
    /// one-time cost per process, not per run — PJRT compile latency
    /// must not pollute the level-loop measurements)
    store: Rc<RefCell<ArtifactStore>>,
    b0: usize,
    be: usize,
    bs: usize,
    k: usize,
    max_level: usize,
    /// number of PJRT execute() dispatches (perf accounting)
    pub dispatches: u64,
}

impl XlaEngine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let store = shared_store(artifacts_dir)?;
        let (b0, be, bs, k, max_level) = {
            let s = store.borrow();
            let m = &s.manifest;
            (m.b0, m.be, m.bs, m.k, m.max_level)
        };
        Ok(XlaEngine {
            b0,
            be,
            bs,
            k,
            max_level,
            store,
            dispatches: 0,
        })
    }

    /// Run one executable over f32 buffers with given logical shapes.
    fn exec(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
        out_len: usize,
    ) -> Result<Vec<f32>> {
        let mut store = self.store.borrow_mut();
        let exe = store.get(name)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?;
            lits.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        self.dispatches += 1;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = lit.to_tuple1().map_err(|e| anyhow!("tuple1 {name}: {e:?}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {name}: {e:?}"))?;
        debug_assert_eq!(v.len(), out_len);
        Ok(v)
    }

    fn check_level(&self, l: usize) -> Result<()> {
        if l == 0 || l > self.max_level {
            Err(anyhow!(
                "no artifact for level {l} (AOT range 1..={})",
                self.max_level
            ))
        } else {
            Ok(())
        }
    }
}

/// Pad `src` to `len` with zeros into a fresh buffer.
fn pad(src: &[f32], len: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(len);
    v.extend_from_slice(src);
    v.resize(len, 0.0);
    v
}

impl CiEngine for XlaEngine {
    fn level0(&mut self, c_ij: &[f32]) -> Result<Vec<f32>> {
        let b0 = self.b0;
        let mut out = Vec::with_capacity(c_ij.len());
        for chunk in c_ij.chunks(b0) {
            let buf = pad(chunk, b0);
            let z = self.exec("level0", &[(&buf, &[b0 as i64])], b0)?;
            out.extend_from_slice(&z[..chunk.len()]);
        }
        Ok(out)
    }

    fn ci_e(
        &mut self,
        l: usize,
        b: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
    ) -> Result<Vec<f32>> {
        self.check_level(l)?;
        debug_assert_eq!(c_ij.len(), b);
        let be = self.be;
        let name = format!("ci_e_l{l}");
        let mut out = Vec::with_capacity(b);
        let mut off = 0usize;
        while off < b {
            let nb = (b - off).min(be);
            let cb = pad(&c_ij[off..off + nb], be);
            let m1b = pad(&m1[off * 2 * l..(off + nb) * 2 * l], be * 2 * l);
            let m2b = pad(&m2[off * l * l..(off + nb) * l * l], be * l * l);
            let z = self.exec(
                &name,
                &[
                    (&cb, &[be as i64]),
                    (&m1b, &[be as i64, 2, l as i64]),
                    (&m2b, &[be as i64, l as i64, l as i64]),
                ],
                be,
            )?;
            out.extend_from_slice(&z[..nb]);
            off += nb;
        }
        Ok(out)
    }

    fn ci_s(
        &mut self,
        l: usize,
        rows: usize,
        k: usize,
        c_ij: &[f32],
        m1: &[f32],
        m2: &[f32],
        _valid: &[u32], // full-width kernel; padding discarded by apply
    ) -> Result<Vec<f32>> {
        self.check_level(l)?;
        assert_eq!(
            k, self.k,
            "ci_s packer K={k} must match the artifact K={}",
            self.k
        );
        let bs = self.bs;
        let name = format!("ci_s_l{l}");
        let mut out = Vec::with_capacity(rows * k);
        let mut row = 0usize;
        while row < rows {
            let nr = (rows - row).min(bs);
            let cb = pad(&c_ij[row * k..(row + nr) * k], bs * k);
            let m1b = pad(&m1[row * k * 2 * l..(row + nr) * k * 2 * l], bs * k * 2 * l);
            let m2b = pad(&m2[row * l * l..(row + nr) * l * l], bs * l * l);
            let z = self.exec(
                &name,
                &[
                    (&cb, &[bs as i64, k as i64]),
                    (&m1b, &[bs as i64, k as i64, 2, l as i64]),
                    (&m2b, &[bs as i64, l as i64, l as i64]),
                ],
                bs * k,
            )?;
            out.extend_from_slice(&z[..nr * k]);
            row += nr;
        }
        Ok(out)
    }

    fn max_level(&self) -> usize {
        self.max_level
    }

    fn batch_e(&self) -> usize {
        self.be
    }

    fn batch_s(&self) -> usize {
        self.bs
    }

    fn k(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
