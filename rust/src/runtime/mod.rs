//! XLA PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the coordinator's hot
//! loop. Python is never on this path — the artifacts are plain files
//! and the `xla` crate drives the PJRT CPU client directly.

pub mod artifacts;
pub mod xla_engine;

pub use artifacts::{ArtifactStore, Manifest};
pub use xla_engine::XlaEngine;

use crate::skeleton::engine::{CiEngine, NativeEngine, WithFallback};
use crate::skeleton::{Config, EngineKind};
use anyhow::Result;

/// Construct the engine selected by the config. The XLA engine is
/// composed with a native fallback for levels beyond the AOT range.
pub fn engine_from_config(cfg: &Config) -> Result<Box<dyn CiEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeEngine::new())),
        EngineKind::Xla => {
            let xla = XlaEngine::new(&cfg.artifacts_dir)?;
            // keep the native mirror on the same batch geometry
            let native = NativeEngine::with_batches(xla.batch_e(), xla.batch_s(), xla.k());
            Ok(Box::new(WithFallback {
                primary: xla,
                fallback: native,
            }))
        }
    }
}
