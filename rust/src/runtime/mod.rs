//! XLA PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the coordinator's hot
//! loop. Python is never on this path — the artifacts are plain files
//! and the `xla` crate drives the PJRT CPU client directly.
//!
//! The PJRT-dependent pieces ([`xla_engine`], the artifact store) are
//! behind the off-by-default `xla` cargo feature so a clean checkout
//! builds hermetically. The manifest parser stays unconditional — it has
//! no PJRT dependency and the experiment tooling reads manifests too.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use artifacts::Manifest;
#[cfg(feature = "xla")]
pub use artifacts::ArtifactStore;
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

use crate::skeleton::engine::{CiEngine, NativeEngine};
#[cfg(feature = "xla")]
use crate::skeleton::engine::WithFallback;
use crate::skeleton::{Config, EngineKind};
use anyhow::Result;

/// Construct the engine selected by the config. The XLA engine is
/// composed with a native fallback for levels beyond the AOT range.
///
/// Without the `xla` cargo feature, selecting [`EngineKind::Xla`] is a
/// descriptive runtime error (never a panic): the native engine is the
/// only compiled-in backend.
pub fn engine_from_config(cfg: &Config) -> Result<Box<dyn CiEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeEngine::with_kernel(cfg.kernel))),
        #[cfg(feature = "xla")]
        EngineKind::Xla => {
            let xla = XlaEngine::new(&cfg.artifacts_dir)?;
            // keep the native mirror on the same batch geometry (the
            // fallback runs the config-selected kernel)
            let native = NativeEngine::with_batches_kernel(
                xla.batch_e(),
                xla.batch_s(),
                xla.k(),
                cfg.kernel,
            );
            Ok(Box::new(WithFallback {
                primary: xla,
                fallback: native,
            }))
        }
        #[cfg(not(feature = "xla"))]
        EngineKind::Xla => Err(anyhow::anyhow!(
            "engine `xla` is not available: this build has the `xla` cargo feature disabled \
             (artifacts dir was {:?}); rebuild with `cargo build --features xla` and provide \
             the AOT artifacts, or select the always-available native engine",
            cfg.artifacts_dir
        )),
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;

    #[test]
    fn xla_engine_kind_is_a_descriptive_error_without_the_feature() {
        let cfg = Config {
            engine: EngineKind::Xla,
            ..Config::default()
        };
        let err = match engine_from_config(&cfg) {
            Ok(_) => panic!("EngineKind::Xla must not construct without the xla feature"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "unhelpful error: {msg}");
        assert!(msg.contains("native"), "should point at the fallback: {msg}");
    }
}
