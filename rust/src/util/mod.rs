//! Small self-contained utilities (no external crates are available
//! offline besides `xla`/`anyhow`, so RNG, CLI parsing and timing are
//! implemented here).

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;
