//! Deterministic PCG64-family RNG + Box-Muller normal sampling.
//!
//! Every stochastic piece of the repo (graph generation, SEM sampling,
//! test generators) draws from this so experiments are reproducible from
//! a single seed.

/// PCG-XSH-RR 64/32 with 64-bit output composed from two draws.
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation".
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
            spare: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (with caching of the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg::seeded(7);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(11);
        let n = 50000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_small_n() {
        let mut rng = Pcg::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
