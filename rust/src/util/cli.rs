//! Minimal argv parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, bare flags and positional args.
//! The typed getters return `Result` — a malformed `--threads x` is a
//! loud CLI error wherever the caller surfaces it, never a `panic!`
//! inside the parser (panics skip the binary's error rendering and, in
//! a daemon, read as crashes).

use anyhow::{Context, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand, positionals, and `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argv items (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} must be a non-negative integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} must be a non-negative integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} must be a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// `--foo-mb` → bytes with checked multiplication. The old
/// `get_usize(..) << 20` wrapped silently in release builds — a huge
/// `--cache-mb` produced a *tiny* (or zero) budget, quietly disabling
/// the cache — and panicked in debug. Overflow is now a loud CLI error
/// naming the flag.
pub fn mb_to_bytes_usize(mb: usize, flag: &str) -> Result<usize> {
    mb.checked_mul(1 << 20)
        .with_context(|| format!("--{flag} {mb} overflows the byte budget ({mb} MiB in bytes)"))
}

/// [`mb_to_bytes_usize`] for `u64`-denominated budgets (the disk tier).
pub fn mb_to_bytes_u64(mb: u64, flag: &str) -> Result<u64> {
    mb.checked_mul(1 << 20)
        .with_context(|| format!("--{flag} {mb} overflows the byte budget ({mb} MiB in bytes)"))
}

/// Process argv for `cargo bench` harness=false targets: skips the
/// binary name and strips the `--bench` flag cargo injects when
/// dispatching bench binaries. Without the strip, `--bench` followed by
/// a non-flag token (a positional, or the value of a later option in
/// some argv orders) is misparsed as `--bench <value>`, swallowing the
/// token. Shared by every bench target (benches/common/mod.rs).
pub fn bench_argv() -> Vec<String> {
    strip_bench_flag(std::env::args().skip(1))
}

/// The testable core of [`bench_argv`].
pub fn strip_bench_flag<I: IntoIterator<Item = String>>(argv: I) -> Vec<String> {
    argv.into_iter().filter(|a| a != "--bench").collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    fn strip(s: &str) -> Vec<String> {
        strip_bench_flag(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn strip_bench_flag_removes_every_occurrence() {
        assert_eq!(strip("--bench --graphs 8"), vec!["--graphs", "8"]);
        assert_eq!(strip("--graphs 8 --bench"), vec!["--graphs", "8"]);
        assert_eq!(strip("--bench"), Vec::<String>::new());
        // untouched when absent
        assert_eq!(strip("--scale small"), vec!["--scale", "small"]);
    }

    /// The regression this helper fixes: `--bench` directly before a
    /// non-flag token used to be parsed as an option eating that token.
    #[test]
    fn stripped_argv_keeps_positionals_after_bench_flag() {
        let broken = Args::parse(strip("--bench nci60-mini --graphs 8"));
        assert_eq!(broken.subcommand.as_deref(), Some("nci60-mini"));
        assert_eq!(broken.get_usize("graphs", 0).unwrap(), 8);
        assert!(broken.get("bench").is_none());
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("run --dataset nci60 --alpha 0.01 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("nci60"));
        assert_eq!(a.get_f64("alpha", 0.05).unwrap(), 0.01);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("experiment table2 --scale=small");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get("scale"), Some("small"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 100).unwrap(), 100);
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
        assert_eq!(a.get_f64("d", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("variant", "cups"), "cups");
    }

    /// Malformed typed options are `Err`s naming the flag — never a
    /// `panic!` (which would bypass the binary's error rendering and
    /// read as a crash in a long-lived daemon).
    #[test]
    fn malformed_typed_options_are_errors_not_panics() {
        let a = parse("run --threads x --seed 1.5 --alpha much");
        for (msg, needle) in [
            (format!("{:#}", a.get_usize("threads", 1).unwrap_err()), "--threads"),
            (format!("{:#}", a.get_u64("seed", 1).unwrap_err()), "--seed"),
            (format!("{:#}", a.get_f64("alpha", 0.01).unwrap_err()), "--alpha"),
        ] {
            assert!(msg.contains(needle), "{msg}");
        }
        // negatives are malformed for the unsigned getters too
        let a = parse("run --threads -4");
        assert!(a.get_usize("threads", 1).is_err());
    }

    /// The `--cache-mb << 20` regression: a huge MiB count used to wrap
    /// to a tiny/zero byte budget in release (silently disabling the
    /// cache) and panic in debug. Checked conversion errors loudly.
    #[test]
    fn mb_to_bytes_is_checked() {
        assert_eq!(mb_to_bytes_usize(256, "cache-mb").unwrap(), 256 << 20);
        assert_eq!(mb_to_bytes_u64(1024, "cache-disk-mb").unwrap(), 1 << 30);
        // the exact boundary: the largest representable MiB count works
        assert_eq!(
            mb_to_bytes_u64(u64::MAX >> 20, "cache-disk-mb").unwrap(),
            (u64::MAX >> 20) << 20
        );
        for msg in [
            format!("{:#}", mb_to_bytes_usize(usize::MAX, "cache-mb").unwrap_err()),
            format!("{:#}", mb_to_bytes_u64(u64::MAX, "cache-disk-mb").unwrap_err()),
            format!("{:#}", mb_to_bytes_u64((u64::MAX >> 20) + 1, "cache-disk-mb").unwrap_err()),
        ] {
            assert!(msg.contains("overflows the byte budget"), "{msg}");
        }
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }
}
