//! Minimal argv parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, bare flags and positional args.

use std::collections::HashMap;

/// Parsed command line: subcommand, positionals, and `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argv items (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Process argv for `cargo bench` harness=false targets: skips the
/// binary name and strips the `--bench` flag cargo injects when
/// dispatching bench binaries. Without the strip, `--bench` followed by
/// a non-flag token (a positional, or the value of a later option in
/// some argv orders) is misparsed as `--bench <value>`, swallowing the
/// token. Shared by every bench target (benches/common/mod.rs).
pub fn bench_argv() -> Vec<String> {
    strip_bench_flag(std::env::args().skip(1))
}

/// The testable core of [`bench_argv`].
pub fn strip_bench_flag<I: IntoIterator<Item = String>>(argv: I) -> Vec<String> {
    argv.into_iter().filter(|a| a != "--bench").collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    fn strip(s: &str) -> Vec<String> {
        strip_bench_flag(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn strip_bench_flag_removes_every_occurrence() {
        assert_eq!(strip("--bench --graphs 8"), vec!["--graphs", "8"]);
        assert_eq!(strip("--graphs 8 --bench"), vec!["--graphs", "8"]);
        assert_eq!(strip("--bench"), Vec::<String>::new());
        // untouched when absent
        assert_eq!(strip("--scale small"), vec!["--scale", "small"]);
    }

    /// The regression this helper fixes: `--bench` directly before a
    /// non-flag token used to be parsed as an option eating that token.
    #[test]
    fn stripped_argv_keeps_positionals_after_bench_flag() {
        let broken = Args::parse(strip("--bench nci60-mini --graphs 8"));
        assert_eq!(broken.subcommand.as_deref(), Some("nci60-mini"));
        assert_eq!(broken.get_usize("graphs", 0), 8);
        assert!(broken.get("bench").is_none());
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("run --dataset nci60 --alpha 0.01 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("nci60"));
        assert_eq!(a.get_f64("alpha", 0.05), 0.01);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("experiment table2 --scale=small");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get("scale"), Some("small"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 100), 100);
        assert_eq!(a.get_or("variant", "cups"), "cups");
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }
}
