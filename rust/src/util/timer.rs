//! Wall-clock timing helpers for the experiment harness.

use std::time::Instant;

/// A simple scope timer returning elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Run `f` `reps` times (after `warmup` discarded runs) and return the
/// median wall-clock seconds. The poor man's criterion (criterion is not
/// available offline).
pub fn median_time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t = Timer::start();
            f();
            t.elapsed_s()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn median_time_runs_all_reps() {
        let mut count = 0;
        let m = median_time(2, 3, || count += 1);
        assert_eq!(count, 5);
        assert!(m >= 0.0);
    }
}
