//! Minimal JSON parsing and string escaping (serde is not available
//! offline). Covers the full JSON grammar — objects, arrays, strings
//! with escapes (including `\uXXXX` surrogate pairs), numbers, booleans
//! and null — with byte-offset error messages. Used by the batch
//! service's manifest loader, by the `cupc serve` daemon on raw network
//! bytes, and by tests validating the JSON-lines reports; numbers are
//! held as `f64`, which is exact for every integer the manifest schema
//! uses.
//!
//! Because `serve` exposes this parser to untrusted input, it is
//! hardened against the two classic hand-rolled-parser holes: container
//! nesting is capped at [`MAX_DEPTH`] (a `[[[[…`-bomb would otherwise
//! overflow the recursive descent's stack and *abort* the daemon), and
//! numbers that overflow to ±infinity (`1e999`) are rejected (they
//! would otherwise round-trip as `inf` into rendered JSON, which has no
//! spelling for it). Both surface as ordinary byte-offset parse errors.

use anyhow::{bail, ensure, Context, Result};

/// Maximum container nesting depth ([`Json::parse`] errors beyond it).
///
/// Every `[` / `{` costs one recursive `value()` stack frame, so an
/// unbounded document — `[[[[…` a few thousand deep — overflows the
/// stack, which is an *abort*, not a catchable panic. A network daemon
/// parsing untrusted requests (`cupc serve`) cannot afford that, so the
/// parser refuses at a fixed depth with a byte-offset error instead.
/// 128 is far beyond any manifest or request shape this crate produces
/// (jobs nest four levels) and bounds worst-case recursion to ~100 KiB
/// of stack.
pub const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Object keys keep their document order (the
/// manifest loader does linear lookups; order never matters for
/// correctness but keeps error messages stable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(
            p.pos == p.b.len(),
            "trailing characters after JSON value at byte {}",
            p.pos
        );
        Ok(v)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer (rejects fractional and negative numbers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= usize::MAX as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Escape a string for embedding inside JSON double quotes (returns the
/// escaped content only — the caller supplies the surrounding quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    /// current container nesting, capped at [`MAX_DEPTH`]
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    /// Bump the nesting depth on container entry; refusing past
    /// [`MAX_DEPTH`] keeps the recursive descent's stack bounded (an
    /// overflow would abort the whole process — not a catchable panic).
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        ensure!(
            self.depth <= MAX_DEPTH,
            "nesting deeper than {MAX_DEPTH} levels at byte {}",
            self.pos
        );
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => {
                self.enter()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.enter()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("expected {lit:?} at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        // the scanned range is ASCII, so the slice is valid UTF-8
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        let v: f64 = s
            .parse()
            .with_context(|| format!("bad number {s:?} at byte {start}"))?;
        // `1e999` parses to infinity: accepting it would let a request
        // smuggle `inf` into Num and from there into rendered JSON
        // (which has no spelling for it — the output would be invalid)
        ensure!(
            v.is_finite(),
            "number {s:?} overflows a finite double at byte {start}"
        );
        Ok(Json::Num(v))
    }

    /// Four hex digits of a `\uXXXX` escape. Folds the digits directly —
    /// no intermediate `from_str_radix(..).unwrap()` — so every
    /// malformed shape (EOF inside the escape, a non-hex byte, a
    /// multi-byte UTF-8 char in the digit window) is a byte-offset
    /// parse error by construction, never a panic.
    fn hex4(&mut self) -> Result<u32> {
        ensure!(
            self.pos + 4 <= self.b.len(),
            "truncated \\u escape at byte {}",
            self.pos
        );
        let mut v = 0u32;
        for k in 0..4 {
            let d = match self.b[self.pos + k] {
                c @ b'0'..=b'9' => c - b'0',
                c @ b'a'..=b'f' => c - b'a' + 10,
                c @ b'A'..=b'F' => c - b'A' + 10,
                _ => bail!("bad \\u escape at byte {}", self.pos),
            };
            v = (v << 4) | d as u32;
        }
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            ensure!(self.pos < self.b.len(), "unterminated string");
            match self.b[self.pos] {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    ensure!(self.pos < self.b.len(), "unterminated escape");
                    let e = self.b[self.pos];
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let at = self.pos - 2; // the backslash
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                ensure!(
                                    self.b[self.pos..].starts_with(b"\\u"),
                                    "lone high surrogate at byte {at}"
                                );
                                self.pos += 2;
                                let lo = self.hex4()?;
                                ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "bad low surrogate at byte {}",
                                    self.pos - 4
                                );
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            // an unpaired low surrogate lands here: it is
                            // no char, so it reports rather than panics
                            out.push(char::from_u32(cp).with_context(|| {
                                format!("invalid unicode escape at byte {at}")
                            })?);
                        }
                        other => bail!("bad escape \\{} at byte {}", other as char, self.pos - 1),
                    }
                }
                _ => {
                    // copy a run of unescaped bytes; the delimiters are
                    // ASCII so the slice boundaries are char boundaries
                    let start = self.pos;
                    while self.pos < self.b.len()
                        && self.b[self.pos] != b'"'
                        && self.b[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .context("invalid UTF-8 in string")?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.pos += 1; // {
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            ensure!(
                self.peek() == Some(b'"'),
                "expected object key at byte {}",
                self.pos
            );
            let k = self.string()?;
            self.skip_ws();
            ensure!(self.peek() == Some(b':'), "expected ':' at byte {}", self.pos);
            self.pos += 1;
            self.skip_ws();
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.pos += 1; // [
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"jobs":[{"name":"a","alpha":0.01},{"n":2}],"ok":true}"#).unwrap();
        let jobs = v.get("jobs").unwrap().as_array().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(jobs[0].get("alpha").unwrap().as_f64(), Some(0.01));
        assert_eq!(jobs[1].get("n").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::parse(r#""a\"b\\c\nd\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd\tA\u{e9}"));
    }

    #[test]
    fn surrogate_pairs_decode() {
        // U+1F600 written as an escaped surrogate pair
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high surrogate");
    }

    /// Adversarial `\uXXXX` shapes in a manifest must surface as
    /// byte-offset parse errors — never a panic. (The escape decoder
    /// used to `from_str_radix(..).unwrap()` after a separate validity
    /// check; this pins the panic-free contract for every malformed
    /// shape, including the ones the old check never saw: unpaired low
    /// surrogates and EOF mid-escape.)
    #[test]
    fn malformed_unicode_escapes_error_with_byte_offsets() {
        for (doc, needle) in [
            // short escape: fewer than 4 digits left before EOF
            (r#""\u12""#, "truncated \\u escape"),
            // EOF mid-escape (document ends inside the digit window)
            (r#""\u12"#, "truncated \\u escape"),
            (r#""\u"#, "truncated \\u escape"),
            // non-hex digits, including a multi-byte UTF-8 char in the window
            (r#""\uGGGG""#, "bad \\u escape"),
            ("\"\\u12é9\"", "bad \\u escape"),
            // lone high surrogate: end of string / not followed by \u
            (r#""\uD83D""#, "lone high surrogate"),
            (r#""\uD83Dx""#, "lone high surrogate"),
            // high surrogate followed by an escape that is no surrogate
            (r#""\uD83D\u0041""#, "bad low surrogate"),
            // second half of the pair truncated
            (r#""\uD83D\u00"#, "truncated \\u escape"),
            // unpaired low surrogate is not a char
            (r#""\uDC00""#, "invalid unicode escape"),
        ] {
            let err = Json::parse(doc).expect_err(doc);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{doc}: {msg}");
            assert!(msg.contains("byte "), "{doc}: offset missing in {msg}");
        }
        // valid escapes at the boundaries still decode
        assert_eq!(Json::parse(r#""\u0041""#).unwrap().as_str(), Some("A"));
        assert_eq!(
            Json::parse(r#""\uFFFD""#).unwrap().as_str(),
            Some("\u{FFFD}")
        );
    }

    /// Nesting past [`MAX_DEPTH`] must be a byte-offset parse error —
    /// never a stack overflow (which aborts the process, uncatchable).
    /// `cupc serve` feeds this parser raw network bytes, so a
    /// `[[[[…`-bomb a few thousand deep used to be a remote kill switch
    /// for the whole daemon.
    #[test]
    fn nesting_depth_is_capped_not_stack_fatal() {
        let arrays = |d: usize| format!("{}0{}", "[".repeat(d), "]".repeat(d));
        // at the cap: parses, and round-trips the innermost value
        let mut v = Json::parse(&arrays(MAX_DEPTH)).unwrap();
        for _ in 0..MAX_DEPTH {
            v = match v {
                Json::Arr(mut items) => items.pop().unwrap(),
                other => other,
            };
        }
        assert_eq!(v, Json::Num(0.0));
        // one past the cap: byte-offset error naming the limit; the
        // offending bracket is the (MAX_DEPTH+1)-th, at offset MAX_DEPTH
        let err = Json::parse(&arrays(MAX_DEPTH + 1)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nesting deeper than 128"), "{msg}");
        assert!(msg.contains(&format!("byte {MAX_DEPTH}")), "{msg}");
        // one below the cap still parses
        assert!(Json::parse(&arrays(MAX_DEPTH - 1)).is_ok());
        // ~100k deep: must error promptly, not overflow the stack (this
        // is the adversarial shape — no closing brackets needed to kill
        // a recursive parser)
        assert!(Json::parse(&"[".repeat(100_000)).is_err());
        assert!(Json::parse(&"[[[".repeat(40_000)).is_err());
        // objects and mixed nesting count against the same cap
        let objs = format!("{}1{}", "{\"k\":[".repeat(70), "]}".repeat(70));
        let err = Json::parse(&objs).unwrap_err();
        assert!(format!("{err:#}").contains("nesting deeper"), "{err:#}");
        let ok = format!("{}1{}", "{\"k\":[".repeat(64), "]}".repeat(64));
        assert!(Json::parse(&ok).is_ok(), "depth 128 of mixed containers");
        // sibling containers do not accumulate depth
        let wide = format!("[{}0]", "[1],".repeat(10_000));
        assert!(Json::parse(&wide).is_ok(), "width is not depth");
    }

    /// `1e999` parses to infinity under `str::parse::<f64>`; accepting
    /// it would render `inf` into results.jsonl — invalid JSON for
    /// every downstream consumer. Non-finite parses must be byte-offset
    /// errors; the largest finite double must still round-trip exactly.
    #[test]
    fn non_finite_numbers_are_rejected_with_offsets() {
        for (doc, at) in [
            ("1e999", 0),
            ("-1e999", 0),
            ("1e309", 0),
            ("[1, 2e999]", 4),
            (r#"{"alpha": 1e999}"#, 10),
        ] {
            let err = Json::parse(doc).expect_err(doc);
            let msg = format!("{err:#}");
            assert!(msg.contains("overflows a finite double"), "{doc}: {msg}");
            assert!(msg.contains(&format!("byte {at}")), "{doc}: {msg}");
        }
        // the largest finite double (and its negation) parse exactly
        assert_eq!(
            Json::parse("1.7976931348623157e308").unwrap().as_f64(),
            Some(f64::MAX)
        );
        assert_eq!(
            Json::parse("-1.7976931348623157e308").unwrap().as_f64(),
            Some(f64::MIN)
        );
        // underflow-to-zero is fine (finite), matching common parsers
        assert_eq!(Json::parse("1e-999").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "1 2", "{'a':1}", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn errors_carry_byte_offsets() {
        let err = Json::parse("{\"a\": @}").unwrap_err();
        assert!(format!("{err:#}").contains("byte 6"), "{err:#}");
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn escape_then_parse_roundtrips() {
        let awkward = "quote\" slash\\ newline\n tab\t ctrl\u{1} unicode\u{e9}";
        let doc = format!("\"{}\"", escape(awkward));
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(awkward));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
