//! Minimal JSON parsing and string escaping (serde is not available
//! offline). Covers the full JSON grammar — objects, arrays, strings
//! with escapes (including `\uXXXX` surrogate pairs), numbers, booleans
//! and null — with byte-offset error messages. Used by the batch
//! service's manifest loader and by tests validating the JSON-lines
//! reports; numbers are held as `f64`, which is exact for every integer
//! the manifest schema uses.

use anyhow::{bail, ensure, Context, Result};

/// A parsed JSON value. Object keys keep their document order (the
/// manifest loader does linear lookups; order never matters for
/// correctness but keeps error messages stable).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(
            p.pos == p.b.len(),
            "trailing characters after JSON value at byte {}",
            p.pos
        );
        Ok(v)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer (rejects fractional and negative numbers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= usize::MAX as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Escape a string for embedding inside JSON double quotes (returns the
/// escaped content only — the caller supplies the surrounding quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("expected {lit:?} at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        // the scanned range is ASCII, so the slice is valid UTF-8
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .with_context(|| format!("bad number {s:?} at byte {start}"))
    }

    /// Four hex digits of a `\uXXXX` escape. Folds the digits directly —
    /// no intermediate `from_str_radix(..).unwrap()` — so every
    /// malformed shape (EOF inside the escape, a non-hex byte, a
    /// multi-byte UTF-8 char in the digit window) is a byte-offset
    /// parse error by construction, never a panic.
    fn hex4(&mut self) -> Result<u32> {
        ensure!(
            self.pos + 4 <= self.b.len(),
            "truncated \\u escape at byte {}",
            self.pos
        );
        let mut v = 0u32;
        for k in 0..4 {
            let d = match self.b[self.pos + k] {
                c @ b'0'..=b'9' => c - b'0',
                c @ b'a'..=b'f' => c - b'a' + 10,
                c @ b'A'..=b'F' => c - b'A' + 10,
                _ => bail!("bad \\u escape at byte {}", self.pos),
            };
            v = (v << 4) | d as u32;
        }
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            ensure!(self.pos < self.b.len(), "unterminated string");
            match self.b[self.pos] {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    ensure!(self.pos < self.b.len(), "unterminated escape");
                    let e = self.b[self.pos];
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let at = self.pos - 2; // the backslash
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                ensure!(
                                    self.b[self.pos..].starts_with(b"\\u"),
                                    "lone high surrogate at byte {at}"
                                );
                                self.pos += 2;
                                let lo = self.hex4()?;
                                ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "bad low surrogate at byte {}",
                                    self.pos - 4
                                );
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            // an unpaired low surrogate lands here: it is
                            // no char, so it reports rather than panics
                            out.push(char::from_u32(cp).with_context(|| {
                                format!("invalid unicode escape at byte {at}")
                            })?);
                        }
                        other => bail!("bad escape \\{} at byte {}", other as char, self.pos - 1),
                    }
                }
                _ => {
                    // copy a run of unescaped bytes; the delimiters are
                    // ASCII so the slice boundaries are char boundaries
                    let start = self.pos;
                    while self.pos < self.b.len()
                        && self.b[self.pos] != b'"'
                        && self.b[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .context("invalid UTF-8 in string")?,
                    );
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.pos += 1; // {
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            ensure!(
                self.peek() == Some(b'"'),
                "expected object key at byte {}",
                self.pos
            );
            let k = self.string()?;
            self.skip_ws();
            ensure!(self.peek() == Some(b':'), "expected ':' at byte {}", self.pos);
            self.pos += 1;
            self.skip_ws();
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.pos += 1; // [
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"jobs":[{"name":"a","alpha":0.01},{"n":2}],"ok":true}"#).unwrap();
        let jobs = v.get("jobs").unwrap().as_array().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(jobs[0].get("alpha").unwrap().as_f64(), Some(0.01));
        assert_eq!(jobs[1].get("n").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::parse(r#""a\"b\\c\nd\tAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd\tA\u{e9}"));
    }

    #[test]
    fn surrogate_pairs_decode() {
        // U+1F600 written as an escaped surrogate pair
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone high surrogate");
    }

    /// Adversarial `\uXXXX` shapes in a manifest must surface as
    /// byte-offset parse errors — never a panic. (The escape decoder
    /// used to `from_str_radix(..).unwrap()` after a separate validity
    /// check; this pins the panic-free contract for every malformed
    /// shape, including the ones the old check never saw: unpaired low
    /// surrogates and EOF mid-escape.)
    #[test]
    fn malformed_unicode_escapes_error_with_byte_offsets() {
        for (doc, needle) in [
            // short escape: fewer than 4 digits left before EOF
            (r#""\u12""#, "truncated \\u escape"),
            // EOF mid-escape (document ends inside the digit window)
            (r#""\u12"#, "truncated \\u escape"),
            (r#""\u"#, "truncated \\u escape"),
            // non-hex digits, including a multi-byte UTF-8 char in the window
            (r#""\uGGGG""#, "bad \\u escape"),
            ("\"\\u12é9\"", "bad \\u escape"),
            // lone high surrogate: end of string / not followed by \u
            (r#""\uD83D""#, "lone high surrogate"),
            (r#""\uD83Dx""#, "lone high surrogate"),
            // high surrogate followed by an escape that is no surrogate
            (r#""\uD83D\u0041""#, "bad low surrogate"),
            // second half of the pair truncated
            (r#""\uD83D\u00"#, "truncated \\u escape"),
            // unpaired low surrogate is not a char
            (r#""\uDC00""#, "invalid unicode escape"),
        ] {
            let err = Json::parse(doc).expect_err(doc);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{doc}: {msg}");
            assert!(msg.contains("byte "), "{doc}: offset missing in {msg}");
        }
        // valid escapes at the boundaries still decode
        assert_eq!(Json::parse(r#""\u0041""#).unwrap().as_str(), Some("A"));
        assert_eq!(
            Json::parse(r#""\uFFFD""#).unwrap().as_str(),
            Some("\u{FFFD}")
        );
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "1 2", "{'a':1}", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn errors_carry_byte_offsets() {
        let err = Json::parse("{\"a\": @}").unwrap_err();
        assert!(format!("{err:#}").contains("byte 6"), "{err:#}");
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn escape_then_parse_roundtrips() {
        let awkward = "quote\" slash\\ newline\n tab\t ctrl\u{1} unicode\u{e9}";
        let doc = format!("\"{}\"", escape(awkward));
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(awkward));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
