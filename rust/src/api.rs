//! Public API: the `pc_stable` entry points composing correlation →
//! skeleton → orientation, mirroring pcalg's `pc()` interface shape.

use crate::graph::cpdag::Cpdag;
use crate::orient;
use crate::skeleton::{self, Config, SkeletonResult};
use crate::stats::corr::{correlation_matrix, DataMatrix};
use anyhow::Result;

/// Full result of a PC-stable run.
pub struct PcResult {
    /// the CPDAG after v-structure + Meek orientation
    pub cpdag: Cpdag,
    /// skeleton phase output (graph, sepsets, per-level stats)
    pub skeleton: SkeletonResult,
    /// seconds spent in the correlation computation (0 when a
    /// correlation matrix was supplied directly)
    pub corr_seconds: f64,
    /// seconds spent in orientation
    pub orient_seconds: f64,
}

impl PcResult {
    /// End-to-end seconds (corr + skeleton + orientation).
    pub fn total_seconds(&self) -> f64 {
        self.corr_seconds + self.skeleton.total_seconds() + self.orient_seconds
    }

    /// Convenience access to the estimated graph.
    pub fn graph(&self) -> &Cpdag {
        &self.cpdag
    }
}

/// Run PC-stable from observational data (m samples × n variables).
pub fn pc_stable_data(data: &DataMatrix, cfg: &Config) -> Result<PcResult> {
    let t = crate::util::timer::Timer::start();
    let corr = correlation_matrix(data, cfg.threads);
    let corr_seconds = t.elapsed_s();
    let mut res = pc_stable_corr(&corr, data.n, data.m, cfg)?;
    res.corr_seconds = corr_seconds;
    Ok(res)
}

/// Run PC-stable from a precomputed correlation matrix (row-major n×n)
/// and the sample count `m` it was estimated from.
pub fn pc_stable_corr(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<PcResult> {
    let skel = skeleton::run(corr, n, m, cfg)?;
    let t = crate::util::timer::Timer::start();
    let cpdag = match cfg.orient {
        crate::skeleton::OrientRule::Standard => orient::orient(&skel.graph, &skel.sepsets),
        crate::skeleton::OrientRule::Majority => {
            let deepest = skel.levels.last().map(|l| l.level).unwrap_or(0);
            orient::orient_majority(&skel.graph, corr, m, cfg.alpha, deepest)
        }
    };
    Ok(PcResult {
        cpdag,
        skeleton: skel,
        corr_seconds: 0.0,
        orient_seconds: t.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{dag::WeightedDag, sem};
    use crate::util::rng::Pcg;

    /// The textbook collider: X0 → X2 ← X1 must orient both arrows.
    #[test]
    fn collider_is_recovered_end_to_end() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(0, 0.8), (1, 0.8)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(1));
        let cfg = Config::default();
        let res = pc_stable_data(&data, &cfg).unwrap();
        assert!(res.cpdag.is_directed(0, 2), "{:?}", res.cpdag);
        assert!(res.cpdag.is_directed(1, 2));
        assert!(!res.cpdag.adjacent(0, 1));
    }

    /// A chain is Markov-equivalent to its reversal: edges stay undirected.
    #[test]
    fn chain_stays_undirected() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![(0, 0.9)], vec![(1, 0.9)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(2));
        let res = pc_stable_data(&data, &Config::default()).unwrap();
        assert!(res.cpdag.is_undirected(0, 1));
        assert!(res.cpdag.is_undirected(1, 2));
        assert!(!res.cpdag.adjacent(0, 2));
    }

    /// All variants produce the same *skeleton* (PC-stable's
    /// order-independence guarantee). Sepsets — and hence individual
    /// orientations — may legitimately differ between schedules: each
    /// stores the *first* separating set it finds, and the search order
    /// is the schedule. (Colombo & Maathuis §4 discusses exactly this;
    /// the skeleton is the invariant.)
    #[test]
    fn all_variants_agree_on_skeleton() {
        use crate::skeleton::Variant;
        let dag = WeightedDag::random_er(30, 0.12, &mut Pcg::seeded(5));
        let data = sem::sample(&dag, 400, &mut Pcg::seeded(6));
        let base = Config::default();
        let mut results = Vec::new();
        for v in [
            Variant::Serial,
            Variant::ParallelCpu,
            Variant::CupcE,
            Variant::CupcS,
            Variant::Baseline1,
            Variant::Baseline2,
        ] {
            let cfg = Config {
                variant: v,
                ..base.clone()
            };
            results.push((v, pc_stable_data(&data, &cfg).unwrap()));
        }
        let (v0, first) = &results[0];
        for (v, r) in &results[1..] {
            assert_eq!(
                first.skeleton.graph.snapshot(),
                r.skeleton.graph.snapshot(),
                "{v:?} skeleton differs from {v0:?}"
            );
            // CPDAG skeletons (adjacency disregarding marks) also match
            assert_eq!(first.cpdag.skeleton(), r.cpdag.skeleton());
        }
    }

    /// Deterministic schedules are bit-reproducible run to run.
    #[test]
    fn deterministic_variants_reproduce_cpdag() {
        use crate::skeleton::Variant;
        let dag = WeightedDag::random_er(25, 0.15, &mut Pcg::seeded(15));
        let data = sem::sample(&dag, 300, &mut Pcg::seeded(16));
        for v in [Variant::Serial, Variant::CupcE, Variant::CupcS] {
            let cfg = Config {
                variant: v,
                ..Config::default()
            };
            let a = pc_stable_data(&data, &cfg).unwrap();
            let b = pc_stable_data(&data, &cfg).unwrap();
            assert!(a.cpdag.same_as(&b.cpdag), "{v:?} not deterministic");
        }
    }

    #[test]
    fn timings_populate() {
        let dag = WeightedDag::random_er(15, 0.2, &mut Pcg::seeded(8));
        let data = sem::sample(&dag, 200, &mut Pcg::seeded(9));
        let res = pc_stable_data(&data, &Config::default()).unwrap();
        assert!(res.total_seconds() > 0.0);
        assert!(res.corr_seconds > 0.0);
        assert!(!res.skeleton.levels.is_empty());
    }
}
