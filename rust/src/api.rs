//! Public API: the `pc_stable` entry points composing correlation →
//! skeleton → orientation, mirroring pcalg's `pc()` interface shape.

use crate::graph::cpdag::Cpdag;
use crate::orient::{self, OrientStats};
use crate::skeleton::pipeline::Executor;
use crate::skeleton::{self, Config, SkeletonResult};
use crate::stats::corr::{correlation_matrix, DataMatrix};
use anyhow::Result;

/// Full result of a PC-stable run.
pub struct PcResult {
    /// the CPDAG after v-structure + Meek orientation
    pub cpdag: Cpdag,
    /// skeleton phase output (graph, sepsets, per-level stats)
    pub skeleton: SkeletonResult,
    /// orientation phase bookkeeping (triples, census tests, sweeps) —
    /// deterministic for any thread count, unlike the timings
    pub orient: OrientStats,
    /// seconds spent in the correlation computation (0 when a
    /// correlation matrix was supplied directly)
    pub corr_seconds: f64,
    /// seconds spent in orientation
    pub orient_seconds: f64,
}

impl PcResult {
    /// End-to-end seconds (corr + skeleton + orientation).
    pub fn total_seconds(&self) -> f64 {
        self.corr_seconds + self.skeleton.total_seconds() + self.orient_seconds
    }

    /// Convenience access to the estimated graph.
    pub fn graph(&self) -> &Cpdag {
        &self.cpdag
    }
}

/// Full result of a causal-order engine run (the second engine kind —
/// see [`crate::family`]): a total order over the variables and a
/// pruned weighted DAG, rather than a CPDAG. Every field except the
/// timings is bit-identical for any thread count.
pub struct OrderResult {
    /// the estimated causal order, roots first
    pub order: Vec<usize>,
    /// the pruned DAG as `(parent, child, weight)` rows on standardized
    /// data, in canonical (child-position, parent-position) order
    pub edges: Vec<(usize, usize, f64)>,
    /// per-round stats of the root-finding loop, reusing the PC level
    /// row shape: `level` = round, `tests` = pairwise measures,
    /// `removed` = 1 (the elected root), `edges_after` = variables
    /// still active
    pub rounds: Vec<skeleton::LevelStats>,
    /// end-to-end wall-clock seconds (rounds + pruning)
    pub seconds: f64,
}

/// What any registered engine family returns: the PC kinds produce a
/// [`PcResult`], the causal-order kinds an [`OrderResult`]. `PcResult`
/// is boxed because the two payloads differ greatly in inline size.
pub enum EngineResult {
    Pc(Box<PcResult>),
    Order(OrderResult),
}

/// Run any registered engine family from observational data — the
/// single entry point the `cupc run` dispatch goes through. PC
/// families compose correlation → skeleton → orientation exactly like
/// [`pc_stable_data`]; causal-order families run their whole-run
/// function from the registry row.
pub fn run_family(
    id: crate::family::FamilyId,
    data: &DataMatrix,
    cfg: &Config,
) -> Result<EngineResult> {
    match crate::family::of(id).kind {
        crate::family::FamilyKind::Pc => {
            let variant = id.variant().expect("PC rows carry a variant");
            let cfg = Config {
                variant,
                ..cfg.clone()
            };
            Ok(EngineResult::Pc(Box::new(pc_stable_data(data, &cfg)?)))
        }
        crate::family::FamilyKind::Order(run) => Ok(EngineResult::Order(run(data, cfg)?)),
    }
}

/// Run PC-stable from observational data (m samples × n variables).
pub fn pc_stable_data(data: &DataMatrix, cfg: &Config) -> Result<PcResult> {
    let t = crate::util::timer::Timer::start();
    let corr = correlation_matrix(data, cfg.threads);
    let corr_seconds = t.elapsed_s();
    let mut res = pc_stable_corr(&corr, data.n, data.m, cfg)?;
    res.corr_seconds = corr_seconds;
    Ok(res)
}

/// Run PC-stable from a precomputed correlation matrix (row-major n×n)
/// and the sample count `m` it was estimated from.
///
/// Orientation runs through the same parallel pipeline executor as the
/// skeleton phase, at `cfg.threads` native workers — re-leased through
/// `cfg.width_hook` at the phase boundary, so a batch job's elastic
/// lease covers orientation too. The CPDAG, the orientation stats, and
/// every other deterministic field are bit-identical for any width.
pub fn pc_stable_corr(corr: &[f64], n: usize, m: usize, cfg: &Config) -> Result<PcResult> {
    let skel = skeleton::run(corr, n, m, cfg)?;
    finish_orientation(corr, m, cfg, skel)
}

/// Orient an already-computed skeleton into the full [`PcResult`] — the
/// tail of [`pc_stable_corr`], split out so callers that produce the
/// skeleton elsewhere (the `cupc shard` coordinator, whose skeleton
/// came through the cross-process driver) finish identically.
pub fn finish_orientation(
    corr: &[f64],
    m: usize,
    cfg: &Config,
    skel: SkeletonResult,
) -> Result<PcResult> {
    let t = crate::util::timer::Timer::start();
    // orientation evaluates on pooled native workers regardless of the
    // skeleton engine (the paper keeps orientation CPU-side; engines
    // share CI semantics, so this is placement, not numerics)
    let mut exec = Executor::pool_with(cfg.threads.max(1), cfg.kernel);
    if let Some(hook) = &cfg.width_hook {
        // the orientation phase is "the level after the last": absorb
        // idle workers / yield to waiters exactly like a level boundary
        exec.set_width(hook.0.width_for_level(skel.levels.len()));
    }
    let (cpdag, orient) = match cfg.orient {
        crate::skeleton::OrientRule::Standard => {
            orient::orient_with(&mut exec, &skel.graph, &skel.sepsets)?
        }
        crate::skeleton::OrientRule::Majority => {
            let deepest = skel.levels.last().map(|l| l.level).unwrap_or(0);
            orient::orient_majority_with(&mut exec, &skel.graph, corr, m, cfg.alpha, deepest)?
        }
    };
    Ok(PcResult {
        cpdag,
        skeleton: skel,
        orient,
        corr_seconds: 0.0,
        orient_seconds: t.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{dag::WeightedDag, sem};
    use crate::util::rng::Pcg;

    /// The textbook collider: X0 → X2 ← X1 must orient both arrows.
    #[test]
    fn collider_is_recovered_end_to_end() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(0, 0.8), (1, 0.8)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(1));
        let cfg = Config::default();
        let res = pc_stable_data(&data, &cfg).unwrap();
        assert!(res.cpdag.is_directed(0, 2), "{:?}", res.cpdag);
        assert!(res.cpdag.is_directed(1, 2));
        assert!(!res.cpdag.adjacent(0, 1));
    }

    /// A chain is Markov-equivalent to its reversal: edges stay undirected.
    #[test]
    fn chain_stays_undirected() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![(0, 0.9)], vec![(1, 0.9)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(2));
        let res = pc_stable_data(&data, &Config::default()).unwrap();
        assert!(res.cpdag.is_undirected(0, 1));
        assert!(res.cpdag.is_undirected(1, 2));
        assert!(!res.cpdag.adjacent(0, 2));
    }

    /// All variants produce the same *skeleton* (PC-stable's
    /// order-independence guarantee). Sepsets — and hence individual
    /// orientations — may legitimately differ between schedules: each
    /// stores the *first* separating set it finds, and the search order
    /// is the schedule. (Colombo & Maathuis §4 discusses exactly this;
    /// the skeleton is the invariant.)
    #[test]
    fn all_variants_agree_on_skeleton() {
        use crate::sim::scenarios::ALL_VARIANTS;
        let dag = WeightedDag::random_er(30, 0.12, &mut Pcg::seeded(5));
        let data = sem::sample(&dag, 400, &mut Pcg::seeded(6));
        let base = Config::default();
        let mut results = Vec::new();
        for v in ALL_VARIANTS {
            let cfg = Config {
                variant: v,
                ..base.clone()
            };
            results.push((v, pc_stable_data(&data, &cfg).unwrap()));
        }
        let (v0, first) = &results[0];
        for (v, r) in &results[1..] {
            assert_eq!(
                first.skeleton.graph.snapshot(),
                r.skeleton.graph.snapshot(),
                "{v:?} skeleton differs from {v0:?}"
            );
            // CPDAG skeletons (adjacency disregarding marks) also match
            assert_eq!(first.cpdag.skeleton(), r.cpdag.skeleton());
        }
    }

    /// Deterministic schedules are bit-reproducible run to run.
    #[test]
    fn deterministic_variants_reproduce_cpdag() {
        use crate::skeleton::Variant;
        let dag = WeightedDag::random_er(25, 0.15, &mut Pcg::seeded(15));
        let data = sem::sample(&dag, 300, &mut Pcg::seeded(16));
        for v in [
            Variant::Serial,
            Variant::CupcE,
            Variant::CupcS,
            Variant::Reversed,
        ] {
            let cfg = Config {
                variant: v,
                ..Config::default()
            };
            let a = pc_stable_data(&data, &cfg).unwrap();
            let b = pc_stable_data(&data, &cfg).unwrap();
            assert!(a.cpdag.same_as(&b.cpdag), "{v:?} not deterministic");
        }
    }

    /// Orientation stats are populated, deterministic, and census tests
    /// only appear under the majority rule.
    #[test]
    fn orient_stats_populate_and_are_thread_invariant() {
        use crate::skeleton::OrientRule;
        let dag = WeightedDag::random_er(20, 0.2, &mut Pcg::seeded(21));
        let data = sem::sample(&dag, 300, &mut Pcg::seeded(22));
        let run = |orient: OrientRule, threads: usize| {
            let cfg = Config {
                orient,
                threads,
                ..Config::default()
            };
            pc_stable_data(&data, &cfg).unwrap()
        };
        let std1 = run(OrientRule::Standard, 1);
        assert!(std1.orient.triples > 0);
        assert_eq!(std1.orient.census_tests, 0, "no census under first-sepset");
        let maj1 = run(OrientRule::Majority, 1);
        assert!(maj1.orient.census_tests > 0, "the census must be counted");
        assert_eq!(maj1.orient.triples, std1.orient.triples);
        for threads in [2usize, 4] {
            let stdn = run(OrientRule::Standard, threads);
            assert!(stdn.cpdag.same_as(&std1.cpdag), "threads={threads}");
            assert_eq!(stdn.orient, std1.orient, "threads={threads}");
            let majn = run(OrientRule::Majority, threads);
            assert!(majn.cpdag.same_as(&maj1.cpdag), "threads={threads}");
            assert_eq!(majn.orient, maj1.orient, "threads={threads}");
        }
    }

    #[test]
    fn timings_populate() {
        let dag = WeightedDag::random_er(15, 0.2, &mut Pcg::seeded(8));
        let data = sem::sample(&dag, 200, &mut Pcg::seeded(9));
        let res = pc_stable_data(&data, &Config::default()).unwrap();
        assert!(res.total_seconds() > 0.0);
        assert!(res.corr_seconds > 0.0);
        assert!(!res.skeleton.levels.is_empty());
    }
}
