//! Fig. 9: histogram of conditional-set sharing at level 2 of
//! DREAM5-Insilico — the evidence for cuPC-S's local-only sharing
//! (§5.5): ~95% of redundant sets S appear in at most 40 rows of A'_G.

use super::ExpOpts;
use crate::graph::compact::CompactAdj;
use crate::sim::datasets;
use crate::skeleton::census;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Out {
    pub dataset: String,
    /// (bin lower bound, % of distinct sets)
    pub histogram: Vec<(u32, f64)>,
    pub share_at_most_40: f64,
    pub distinct_sets: usize,
}

pub fn run(opts: &ExpOpts) -> Result<Out> {
    let name = match opts.scale {
        super::Scale::Small => "dream5-insilico-mini",
        super::Scale::Paper => "dream5-insilico",
    };
    let ds = datasets::generate(datasets::spec(name).unwrap());
    let corr = correlation_matrix(&ds.data, opts.base_config().threads);
    // run levels 0..1; the remaining graph is G' at the start of level 2
    let cfg = Config {
        variant: Variant::CupcS,
        max_level: Some(1),
        ..opts.base_config()
    };
    let res = run_skeleton(&corr, ds.data.n, ds.data.m, &cfg)?;
    let comp = CompactAdj::from_snapshot(&res.graph.snapshot(), ds.data.n);
    let counts = census::set_row_counts(&comp, 2);
    // paper bins: width 40 over [1, ...]
    let histogram = census::histogram(&counts, 40, 10);
    Ok(Out {
        dataset: name.to_string(),
        share_at_most_40: census::share_at_most(&counts, 40),
        distinct_sets: counts.len(),
        histogram,
    })
}

pub fn print(out: &Out) {
    println!("== Fig. 9 analog: sharing of conditional sets S, level 2, {} ==", out.dataset);
    println!("distinct sets: {}", out.distinct_sets);
    for (lo, share) in &out.histogram {
        let hi = lo + 39;
        println!("rows [{lo:>3}, {hi:>3}] : {share:>6.2}%");
    }
    println!(
        "share of sets in ≤40 rows: {:.1}%  (paper: ~95% — local sharing suffices)",
        out.share_at_most_40
    );
}
