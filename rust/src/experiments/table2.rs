//! Table 2: serial vs multicore-CPU vs cuPC-E vs cuPC-S runtimes and
//! speedup ratios on the six benchmark datasets.
//!
//! Mapping to the paper's rows (T1..T5):
//!   T1 "Stable (R)"        — not reproducible (no R runtime offline);
//!                            reported as n/a. T1/T2 is instead shown as
//!                            serial/parallel-CPU, the paper's multicore
//!                            speedup notion on this host.
//!   T2 "Parallel-PC"       — our threaded CPU engine.
//!   T3 "Stable.fast (C)"   — our serial native engine.
//!   T4 cuPC-E, T5 cuPC-S   — the batched schedules.

use super::{median, ExpOpts};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    pub t2_parallel: f64,
    pub t3_serial: f64,
    pub t4_cupc_e: f64,
    pub t5_cupc_s: f64,
    pub edges: usize,
    pub levels: usize,
}

pub fn run(opts: &ExpOpts) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in opts.dataset_names() {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, opts.base_config().threads);
        let (n, m) = (ds.data.n, ds.data.m);
        let time_variant = |v: Variant| -> Result<(f64, usize, usize)> {
            let cfg = Config {
                variant: v,
                ..opts.base_config()
            };
            let mut times = Vec::new();
            let mut edges = 0;
            let mut levels = 0;
            for _ in 0..opts.reps.max(1) {
                let res = run_skeleton(&corr, n, m, &cfg)?;
                times.push(res.total_seconds());
                edges = res.graph.n_edges();
                levels = res.levels.len();
            }
            Ok((median(&times), edges, levels))
        };
        let (t3, edges, levels) = time_variant(Variant::Serial)?;
        let (t2, e2, _) = time_variant(Variant::ParallelCpu)?;
        let (t4, e4, _) = time_variant(Variant::CupcE)?;
        let (t5, e5, _) = time_variant(Variant::CupcS)?;
        assert_eq!(edges, e2, "{name}: parallel CPU skeleton differs");
        assert_eq!(edges, e4, "{name}: cuPC-E skeleton differs");
        assert_eq!(edges, e5, "{name}: cuPC-S skeleton differs");
        rows.push(Row {
            dataset: name,
            t2_parallel: t2,
            t3_serial: t3,
            t4_cupc_e: t4,
            t5_cupc_s: t5,
            edges,
            levels,
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    println!("== Table 2 analog: runtimes (seconds) and speedups ==");
    println!(
        "{:<22} {:>12} {:>12} {:>10} {:>10} {:>8} {:>9} {:>9}",
        "dataset", "parCPU(T2)", "serial(T3)", "cuPC-E", "cuPC-S", "edges", "T3/T4", "T3/T5"
    );
    let mut geo_e = 0.0f64;
    let mut geo_s = 0.0f64;
    for r in rows {
        let se = r.t3_serial / r.t4_cupc_e;
        let ss = r.t3_serial / r.t5_cupc_s;
        geo_e += se.max(1e-12).ln();
        geo_s += ss.max(1e-12).ln();
        println!(
            "{:<22} {:>12.3} {:>12.3} {:>10.3} {:>10.3} {:>8} {:>8.1}x {:>8.1}x",
            r.dataset, r.t2_parallel, r.t3_serial, r.t4_cupc_e, r.t5_cupc_s, r.edges, se, ss
        );
    }
    let nn = rows.len().max(1) as f64;
    println!(
        "geometric-mean speedup: cuPC-E {:.1}x, cuPC-S {:.1}x  (paper: 525x / 1296x on GTX-1080 vs 1-core Xeon)",
        (geo_e / nn).exp(),
        (geo_s / nn).exp()
    );
}
