//! Fig. 6: distribution of runtime (%) across levels for cuPC-E and
//! cuPC-S (per-level timing includes compaction, as in the paper).

use super::ExpOpts;
use crate::metrics::level_time_shares;
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    pub variant: &'static str,
    /// (level, percent-of-total)
    pub shares: Vec<(usize, f64)>,
}

pub fn run(opts: &ExpOpts) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in opts.dataset_names() {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, opts.base_config().threads);
        let (n, m) = (ds.data.n, ds.data.m);
        for (variant, label) in [(Variant::CupcE, "cuPC-E"), (Variant::CupcS, "cuPC-S")] {
            let cfg = Config {
                variant,
                ..opts.base_config()
            };
            let res = run_skeleton(&corr, n, m, &cfg)?;
            rows.push(Row {
                dataset: name.clone(),
                variant: label,
                shares: level_time_shares(&res.levels),
            });
        }
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    println!("== Fig. 6 analog: % of runtime per level ==");
    let max_level = rows
        .iter()
        .flat_map(|r| r.shares.iter().map(|&(l, _)| l))
        .max()
        .unwrap_or(0);
    print!("{:<22} {:<8}", "dataset", "variant");
    for l in 0..=max_level {
        print!(" {:>7}", format!("L{l}"));
    }
    println!();
    for r in rows {
        print!("{:<22} {:<8}", r.dataset, r.variant);
        for l in 0..=max_level {
            let share = r
                .shares
                .iter()
                .find(|&&(lv, _)| lv == l)
                .map(|&(_, s)| s)
                .unwrap_or(0.0);
            print!(" {:>6.1}%", share);
        }
        println!();
    }
    println!("(paper: level 1 takes 49–83% in the first five datasets; DREAM5 spends 70–90% in levels 2–5)");
}
