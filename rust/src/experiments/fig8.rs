//! Fig. 8: cuPC-S configuration heat maps — runtime of (θ, δ) configs
//! relative to the paper-selected cuPC-S-64-2, θ ∈ {32,64,128,256},
//! δ ∈ {1,2,4,8}.

use super::{median, ExpOpts};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Cell {
    pub theta: usize,
    pub delta: usize,
    pub speed_ratio: f64,
}

#[derive(Debug, Clone)]
pub struct Map {
    pub dataset: String,
    pub cells: Vec<Cell>,
}

pub const THETAS: [usize; 4] = [32, 64, 128, 256];
pub const DELTAS: [usize; 4] = [1, 2, 4, 8];

pub fn run(opts: &ExpOpts, datasets_filter: Option<&[&str]>) -> Result<Vec<Map>> {
    let names = opts.dataset_names();
    let selected: Vec<String> = match datasets_filter {
        Some(f) => names
            .into_iter()
            .filter(|n| f.iter().any(|x| n.starts_with(x)))
            .collect(),
        None => names,
    };
    let mut maps = Vec::new();
    for name in selected {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, opts.base_config().threads);
        let (n, m) = (ds.data.n, ds.data.m);
        let time_of = |theta: usize, delta: usize| -> Result<f64> {
            let cfg = Config {
                variant: Variant::CupcS,
                theta,
                delta,
                ..opts.base_config()
            };
            let times: Result<Vec<f64>> = (0..opts.reps.max(1))
                .map(|_| Ok(run_skeleton(&corr, n, m, &cfg)?.total_seconds()))
                .collect();
            Ok(median(&times?))
        };
        let t_sel = time_of(64, 2)?;
        let mut cells = Vec::new();
        for &theta in &THETAS {
            for &delta in &DELTAS {
                let t = time_of(theta, delta)?;
                cells.push(Cell {
                    theta,
                    delta,
                    speed_ratio: t_sel / t,
                });
            }
        }
        maps.push(Map {
            dataset: name,
            cells,
        });
    }
    Ok(maps)
}

pub fn print(maps: &[Map]) {
    println!("== Fig. 8 analog: cuPC-S (θ,δ) speed vs selected cuPC-S-64-2 ==");
    for map in maps {
        println!("--- {} (ratio >1 ⇒ faster than 64-2) ---", map.dataset);
        print!("{:>6}", "θ\\δ");
        for &d in &DELTAS {
            print!(" {:>6}", d);
        }
        println!();
        for &t in &THETAS {
            print!("{:>6}", t);
            for &d in &DELTAS {
                match map.cells.iter().find(|c| c.theta == t && c.delta == d) {
                    Some(c) => print!(" {:>6.2}", c.speed_ratio),
                    None => print!(" {:>6}", "-"),
                }
            }
            println!();
        }
    }
    println!("(paper: variation 0.7x–1.2x — less sensitive than cuPC-E because blocks stay loaded)");
}
