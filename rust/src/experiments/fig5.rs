//! Fig. 5: cuPC-E and cuPC-S vs the two baseline GPU schedules.
//! Bars are runtime ratios baseline/cuPC (higher = cuPC faster).

use super::{median, ExpOpts};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    pub b1_over_e: f64,
    pub b2_over_e: f64,
    pub b1_over_s: f64,
    pub b2_over_s: f64,
}

pub fn run(opts: &ExpOpts) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in opts.dataset_names() {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, opts.base_config().threads);
        let (n, m) = (ds.data.n, ds.data.m);
        let time_of = |v: Variant| -> Result<f64> {
            let cfg = Config {
                variant: v,
                ..opts.base_config()
            };
            let times: Result<Vec<f64>> = (0..opts.reps.max(1))
                .map(|_| Ok(run_skeleton(&corr, n, m, &cfg)?.total_seconds()))
                .collect();
            Ok(median(&times?))
        };
        let te = time_of(Variant::CupcE)?;
        let ts = time_of(Variant::CupcS)?;
        let tb1 = time_of(Variant::Baseline1)?;
        let tb2 = time_of(Variant::Baseline2)?;
        rows.push(Row {
            dataset: name,
            b1_over_e: tb1 / te,
            b2_over_e: tb2 / te,
            b1_over_s: tb1 / ts,
            b2_over_s: tb2 / ts,
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    println!("== Fig. 5 analog: speedup of cuPC over baseline GPU schedules ==");
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10}",
        "dataset", "B1/cuPC-E", "B2/cuPC-E", "B1/cuPC-S", "B2/cuPC-S"
    );
    for r in rows {
        println!(
            "{:<22} {:>9.2}x {:>9.2}x {:>9.2}x {:>9.2}x",
            r.dataset, r.b1_over_e, r.b2_over_e, r.b1_over_s, r.b2_over_s
        );
    }
    println!("(paper: cuPC-E 1.3–3.9x over B1, 1.8–3.2x over B2; cuPC-S up to 45.8x/20.6x on DREAM5)");
}
