//! Fig. 7: cuPC-E configuration heat maps — runtime of (β, γ) configs
//! relative to the paper-selected cuPC-E-2-32, over β,γ ∈ {1,2,…,256}
//! with 32 ≤ β·γ ≤ 256.

use super::{median, ExpOpts};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Cell {
    pub beta: usize,
    pub gamma: usize,
    /// runtime(selected) / runtime(this): >1 = faster than selected
    pub speed_ratio: f64,
}

#[derive(Debug, Clone)]
pub struct Map {
    pub dataset: String,
    pub cells: Vec<Cell>,
}

pub const POWERS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

pub fn configs() -> Vec<(usize, usize)> {
    let mut v = Vec::new();
    for &beta in &POWERS {
        for &gamma in &POWERS {
            let prod = beta * gamma;
            if (32..=256).contains(&prod) {
                v.push((beta, gamma));
            }
        }
    }
    v
}

pub fn run(opts: &ExpOpts, datasets_filter: Option<&[&str]>) -> Result<Vec<Map>> {
    let names = opts.dataset_names();
    let selected: Vec<String> = match datasets_filter {
        Some(f) => names
            .into_iter()
            .filter(|n| f.iter().any(|x| n.starts_with(x)))
            .collect(),
        None => names,
    };
    let mut maps = Vec::new();
    for name in selected {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, opts.base_config().threads);
        let (n, m) = (ds.data.n, ds.data.m);
        let time_of = |beta: usize, gamma: usize| -> Result<f64> {
            let cfg = Config {
                variant: Variant::CupcE,
                beta,
                gamma,
                ..opts.base_config()
            };
            let times: Result<Vec<f64>> = (0..opts.reps.max(1))
                .map(|_| Ok(run_skeleton(&corr, n, m, &cfg)?.total_seconds()))
                .collect();
            Ok(median(&times?))
        };
        let t_sel = time_of(2, 32)?;
        let mut cells = Vec::new();
        for (beta, gamma) in configs() {
            let t = time_of(beta, gamma)?;
            cells.push(Cell {
                beta,
                gamma,
                speed_ratio: t_sel / t,
            });
        }
        maps.push(Map {
            dataset: name,
            cells,
        });
    }
    Ok(maps)
}

pub fn print(maps: &[Map]) {
    println!("== Fig. 7 analog: cuPC-E (β,γ) speed vs selected cuPC-E-2-32 ==");
    for map in maps {
        println!("--- {} (ratio >1 ⇒ faster than 2-32) ---", map.dataset);
        let betas: Vec<usize> = {
            let mut b: Vec<usize> = map.cells.iter().map(|c| c.beta).collect();
            b.sort_unstable();
            b.dedup();
            b
        };
        let gammas: Vec<usize> = {
            let mut g: Vec<usize> = map.cells.iter().map(|c| c.gamma).collect();
            g.sort_unstable();
            g.dedup();
            g
        };
        print!("{:>6}", "β\\γ");
        for &g in &gammas {
            print!(" {:>6}", g);
        }
        println!();
        for &b in &betas {
            print!("{:>6}", b);
            for &g in &gammas {
                match map.cells.iter().find(|c| c.beta == b && c.gamma == g) {
                    Some(c) => print!(" {:>6.2}", c.speed_ratio),
                    None => print!(" {:>6}", "-"),
                }
            }
            println!();
        }
    }
    println!("(paper: variation 0.3x–1.3x; dense graphs favour larger γ, sparse favour smaller)");
}
