//! Experiment harness: one module per paper table/figure (see DESIGN.md
//! §5 for the index). Each regenerates the corresponding artifact's rows
//! on this testbed — shapes (who wins, by what factor, where crossovers
//! fall) are the reproduction target; absolute numbers re-baseline to
//! this substrate (XLA-CPU PJRT, 1-core host; see EXPERIMENTS.md).
//!
//! Used by both `cupc experiment <id>` and the `cargo bench` targets.

pub mod ablation;
pub mod fig10;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table2;

use crate::skeleton::EngineKind;
use std::path::PathBuf;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scale {
    /// mini datasets (n scaled ~8× down) — CI-image friendly
    Small,
    /// the paper's exact (n, m) — hours of runtime
    Paper,
}

#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub scale: Scale,
    pub engine: EngineKind,
    pub reps: usize,
    pub artifacts: PathBuf,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            scale: Scale::Small,
            engine: EngineKind::Native,
            reps: 1,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

impl ExpOpts {
    pub fn dataset_names(&self) -> Vec<String> {
        crate::sim::datasets::TABLE2_ORDER
            .iter()
            .map(|b| match self.scale {
                Scale::Small => format!("{b}-mini"),
                Scale::Paper => b.to_string(),
            })
            .collect()
    }

    pub fn base_config(&self) -> crate::skeleton::Config {
        crate::skeleton::Config {
            engine: self.engine,
            artifacts_dir: self.artifacts.clone(),
            ..crate::skeleton::Config::default()
        }
    }
}

/// Median of a sample (sorted copy).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.is_empty() {
        return f64::NAN;
    }
    v[v.len() / 2]
}

/// Quartiles (q1, median, q3) for box plots (Fig. 10).
pub fn quartiles(xs: &[f64]) -> (f64, f64, f64) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        if v.is_empty() {
            return f64::NAN;
        }
        let idx = p * (v.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        let frac = idx - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    };
    (q(0.25), q(0.5), q(0.75))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_quartiles() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        let (q1, q2, q3) = quartiles(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(q2, 3.0);
        assert_eq!(q1, 2.0);
        assert_eq!(q3, 4.0);
    }

    #[test]
    fn dataset_names_respect_scale() {
        let small = ExpOpts::default();
        assert!(small.dataset_names()[0].ends_with("-mini"));
        let paper = ExpOpts {
            scale: Scale::Paper,
            ..ExpOpts::default()
        };
        assert_eq!(paper.dataset_names()[0], "nci60");
    }
}
