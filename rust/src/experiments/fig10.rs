//! Fig. 10: scalability of cuPC-E / cuPC-S over (a) the number of
//! variables n, (b) the sample size m, (c) the graph density d —
//! 10 random ER graphs per point (paper §5.6), box-plot quartiles.

use super::{quartiles, ExpOpts, Scale};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Point {
    pub x: f64,
    pub variant: &'static str,
    pub q1: f64,
    pub med: f64,
    pub q3: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sweep {
    N,
    M,
    D,
}

impl Sweep {
    pub fn parse(s: &str) -> Option<Sweep> {
        Some(match s {
            "n" => Sweep::N,
            "m" => Sweep::M,
            "d" => Sweep::D,
            _ => return None,
        })
    }
}

/// Sweep parameters: paper values, or ~10x smaller in Small scale.
pub fn sweep_points(sweep: Sweep, scale: Scale) -> Vec<(usize, usize, f64)> {
    // returns (n, m, d) per point
    match (sweep, scale) {
        (Sweep::N, Scale::Paper) => [1000usize, 2000, 3000, 4000]
            .iter()
            .map(|&n| (n, 10000, 0.1))
            .collect(),
        (Sweep::N, Scale::Small) => [100usize, 200, 300, 400]
            .iter()
            .map(|&n| (n, 1000, 0.1))
            .collect(),
        (Sweep::M, Scale::Paper) => [2000usize, 4000, 6000, 8000, 10000]
            .iter()
            .map(|&m| (1000, m, 0.1))
            .collect(),
        (Sweep::M, Scale::Small) => [200usize, 400, 600, 800, 1000]
            .iter()
            .map(|&m| (100, m, 0.1))
            .collect(),
        (Sweep::D, Scale::Paper) => [0.1, 0.2, 0.3, 0.4, 0.5]
            .iter()
            .map(|&d| (1000, 10000, d))
            .collect(),
        (Sweep::D, Scale::Small) => [0.1, 0.2, 0.3, 0.4, 0.5]
            .iter()
            .map(|&d| (100, 1000, d))
            .collect(),
    }
}

pub fn run(opts: &ExpOpts, sweep: Sweep, graphs_per_point: usize) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for (n, m, d) in sweep_points(sweep, opts.scale) {
        let x = match sweep {
            Sweep::N => n as f64,
            Sweep::M => m as f64,
            Sweep::D => d,
        };
        for (variant, label) in [(Variant::CupcE, "cuPC-E"), (Variant::CupcS, "cuPC-S")] {
            let mut times = Vec::new();
            for g in 0..graphs_per_point.max(1) {
                let ds = datasets::generate_er(n, m, d, 1000 + g as u64);
                let corr = correlation_matrix(&ds.data, opts.base_config().threads);
                let cfg = Config {
                    variant,
                    ..opts.base_config()
                };
                let res = run_skeleton(&corr, n, m, &cfg)?;
                times.push(res.total_seconds());
            }
            let (q1, med, q3) = quartiles(&times);
            out.push(Point {
                x,
                variant: label,
                q1,
                med,
                q3,
            });
        }
    }
    Ok(out)
}

pub fn print(points: &[Point], sweep: Sweep) {
    let axis = match sweep {
        Sweep::N => "n (variables)",
        Sweep::M => "m (samples)",
        Sweep::D => "d (density)",
    };
    println!("== Fig. 10 analog: runtime vs {axis} (box quartiles, seconds) ==");
    println!(
        "{:>12} {:<8} {:>10} {:>10} {:>10}",
        axis, "variant", "q1", "median", "q3"
    );
    for p in points {
        println!(
            "{:>12} {:<8} {:>10.3} {:>10.3} {:>10.3}",
            p.x, p.variant, p.q1, p.med, p.q3
        );
    }
    println!("(paper: runtime grows with n and d, ~linear in m; cuPC-S dominates cuPC-E throughout)");
}
