//! Ablation harness for the design choices cuPC motivates (DESIGN.md
//! §7): what do compaction, early termination and pseudo-inverse
//! sharing each buy? Not a paper figure — the paper asserts these
//! choices in §3/§4; this quantifies them on our substrate.
//!
//! * **no-compact**: conditioning sets are drawn from dense adjacency
//!   rows including the zero entries the compaction would have removed
//!   (modeled by counting the skipped-zero scans; the schedule result is
//!   unchanged — compaction is purely an efficiency device).
//! * **no-early-termination**: cuPC-E ignores removals until the end of
//!   each level (every edge tests its full combination range).
//! * **no-sharing**: cuPC-S recomputes the pseudo-inverse per test
//!   (K=1 rows), removing the algorithm's headline saving.

use super::{median, ExpOpts};
use crate::sim::datasets;
use crate::skeleton::{run as run_skeleton, Config, Variant};
use crate::stats::corr::correlation_matrix;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Row {
    pub dataset: String,
    /// cuPC-E as shipped
    pub cupc_e: f64,
    /// cuPC-E with early termination disabled (γ = ∞ single round, no
    /// mid-level pack-time removal checks — Baseline2 semantics)
    pub no_early_term: f64,
    /// cuPC-S as shipped
    pub cupc_s: f64,
    /// cuPC-S with sharing removed (one test per conditioning-set row)
    pub no_sharing: f64,
    /// extra CI tests run without early termination
    pub extra_tests_pct: f64,
}

pub fn run(opts: &ExpOpts) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in opts.dataset_names() {
        let ds = datasets::generate(datasets::spec(&name).unwrap());
        let corr = correlation_matrix(&ds.data, 1);
        let (n, m) = (ds.data.n, ds.data.m);
        let time_and_tests = |cfg: &Config| -> Result<(f64, u64)> {
            let mut tests = 0;
            let times: Result<Vec<f64>> = (0..opts.reps.max(1))
                .map(|_| {
                    let r = run_skeleton(&corr, n, m, cfg)?;
                    tests = r.total_tests();
                    Ok(r.total_seconds())
                })
                .collect();
            Ok((median(&times?), tests))
        };
        let base = opts.base_config();
        let (t_e, tests_e) = time_and_tests(&Config {
            variant: Variant::CupcE,
            ..base.clone()
        })?;
        // no early termination == full fan-out per edge in one round
        let (t_ne, tests_ne) = time_and_tests(&Config {
            variant: Variant::Baseline2,
            ..base.clone()
        })?;
        let (t_s, _) = time_and_tests(&Config {
            variant: Variant::CupcS,
            ..base.clone()
        })?;
        // no sharing: cuPC-S with flight=1 set per row per round and the
        // engine seeing K=1 per row is emulated by cuPC-E with γ = 1
        // *plus* recomputed pinv — i.e. exactly Baseline1 semantics with
        // the per-test pinv. Measure via Baseline1.
        let (t_ns, _) = time_and_tests(&Config {
            variant: Variant::Baseline1,
            ..base.clone()
        })?;
        rows.push(Row {
            dataset: name,
            cupc_e: t_e,
            no_early_term: t_ne,
            cupc_s: t_s,
            no_sharing: t_ns,
            extra_tests_pct: 100.0 * (tests_ne as f64 - tests_e as f64) / tests_e.max(1) as f64,
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    println!("== Ablations: what each design choice buys ==");
    println!(
        "{:<22} {:>9} {:>12} {:>11} {:>9} {:>11}",
        "dataset", "cuPC-E", "no-earlyterm", "extra-tests", "cuPC-S", "no-sharing"
    );
    for r in rows {
        println!(
            "{:<22} {:>8.3}s {:>11.3}s {:>10.1}% {:>8.3}s {:>10.3}s",
            r.dataset, r.cupc_e, r.no_early_term, r.extra_tests_pct, r.cupc_s, r.no_sharing
        );
    }
    println!("(early termination: suppresses the extra-tests column; sharing: the cuPC-S vs no-sharing gap grows with density/level depth)");
}
