//! Partial correlation ρ(Vi, Vj | S) from a correlation matrix
//! (paper eq. 3-5) — the *native* (pure Rust) CI-test path, used by the
//! serial/threaded CPU engines and as the cross-check oracle for the XLA
//! engine.
//!
//! The batched mirrors of this math (one pseudoinverse per slot / per
//! shared row) live in [`crate::stats::kernels`]; the operation-order
//! rules that keep them bitwise equal are in `docs/NUMERICS.md`.

use super::chol::{pinv_fast, PinvScratch};
use super::fisher::fisher_z;

/// Reusable workspace for CI tests up to conditioning-set size `max_l`.
pub struct CiWorkspace {
    max_l: usize,
    m1: Vec<f64>,    // 2×l   rows (C[i,S]; C[j,S])
    m2: Vec<f64>,    // l×l   C[S,S]
    m2inv: Vec<f64>, // l×l
    w: Vec<f64>,     // 2×l   M1 × M2⁻¹
    sc: PinvScratch,
}

impl CiWorkspace {
    pub fn new(max_l: usize) -> Self {
        let l = max_l.max(1);
        CiWorkspace {
            max_l: l,
            m1: vec![0.0; 2 * l],
            m2: vec![0.0; l * l],
            m2inv: vec![0.0; l * l],
            w: vec![0.0; 2 * l],
            sc: PinvScratch::new(l),
        }
    }
}

/// Correlation matrix view: row-major `n×n` f64 with unit diagonal.
pub struct Corr<'a> {
    pub c: &'a [f64],
    pub n: usize,
}

impl<'a> Corr<'a> {
    pub fn new(c: &'a [f64], n: usize) -> Self {
        debug_assert_eq!(c.len(), n * n);
        Corr { c, n }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.c[i * self.n + j]
    }
}

/// ρ(Vi, Vj | S). `s` holds variable indices, `|s| = l`. With `l == 0`
/// this is just C[i,j].
pub fn partial_corr(corr: &Corr, i: usize, j: usize, s: &[usize], ws: &mut CiWorkspace) -> f64 {
    let l = s.len();
    if l == 0 {
        return corr.at(i, j);
    }
    assert!(l <= ws.max_l, "conditioning set {l} exceeds workspace {}", ws.max_l);
    // gather M1 = (C[i,S]; C[j,S]) and M2 = C[S,S]
    for (a, &sa) in s.iter().enumerate() {
        ws.m1[a] = corr.at(i, sa);
        ws.m1[l + a] = corr.at(j, sa);
        for (b, &sb) in s.iter().enumerate() {
            ws.m2[a * l + b] = corr.at(sa, sb);
        }
    }
    pinv_fast(&ws.m2[..l * l], l, &mut ws.sc, &mut ws.m2inv[..l * l]);
    // w = M1 × M2⁻¹  (2×l)
    for r in 0..2 {
        for col in 0..l {
            let mut acc = 0.0;
            for k in 0..l {
                acc += ws.m1[r * l + k] * ws.m2inv[k * l + col];
            }
            ws.w[r * l + col] = acc;
        }
    }
    // H = M0 − w × M1ᵀ, M0 = [[1, c_ij],[c_ij, 1]]
    let mut h00 = 0.0;
    let mut h01 = 0.0;
    let mut h11 = 0.0;
    for k in 0..l {
        h00 += ws.w[k] * ws.m1[k];
        h01 += ws.w[k] * ws.m1[l + k];
        h11 += ws.w[l + k] * ws.m1[l + k];
    }
    let c_ij = corr.at(i, j);
    let h00 = 1.0 - h00;
    let h11 = 1.0 - h11;
    let h01 = c_ij - h01;
    h01 / (h00 * h11).max(1e-12).sqrt()
}

/// |Fisher z| of the partial correlation — the statistic compared to τ.
pub fn ci_statistic(corr: &Corr, i: usize, j: usize, s: &[usize], ws: &mut CiWorkspace) -> f64 {
    fisher_z(partial_corr(corr, i, j, s, ws))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Correlation of the chain X0 -> X1 -> X2 with unit coefficients
    /// r01, r12; r02 = r01*r12 (Markov). Conditioning on X1 must zero it.
    fn chain_corr() -> Vec<f64> {
        let r01 = 0.8;
        let r12 = 0.7;
        let r02 = r01 * r12;
        vec![1.0, r01, r02, r01, 1.0, r12, r02, r12, 1.0]
    }

    #[test]
    fn level0_is_raw_correlation() {
        let c = chain_corr();
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        assert!((partial_corr(&corr, 0, 2, &[], &mut ws) - 0.56).abs() < 1e-12);
    }

    #[test]
    fn conditioning_on_mediator_zeroes_rho() {
        let c = chain_corr();
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        let rho = partial_corr(&corr, 0, 2, &[1], &mut ws);
        assert!(rho.abs() < 1e-6, "rho={rho}");
    }

    #[test]
    fn conditioning_on_irrelevant_keeps_rho() {
        // 4 vars: 0-1 correlated, 2,3 independent of them
        let n = 4;
        let mut c = vec![0.0; n * n];
        for i in 0..n {
            c[i * n + i] = 1.0;
        }
        c[1] = 0.6;
        c[n] = 0.6; // C[0,1]
        let corr = Corr::new(&c, n);
        let mut ws = CiWorkspace::new(4);
        let rho = partial_corr(&corr, 0, 1, &[2, 3], &mut ws);
        assert!((rho - 0.6).abs() < 1e-6, "rho={rho}");
    }

    #[test]
    fn symmetric_in_i_j() {
        let c = chain_corr();
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        let a = partial_corr(&corr, 0, 2, &[1], &mut ws);
        let b = partial_corr(&corr, 2, 0, &[1], &mut ws);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn collider_conditioning_creates_dependence() {
        // X0 -> X2 <- X1 with X0 ⟂ X1: conditioning on the collider X2
        // induces |rho(0,1|2)| > 0.
        let a = 0.7;
        let b = 0.7;
        // model: x2 = a x0 + b x1 + e; var(x2) = a²+b²+σ²=1 with σ² chosen
        let s2 = 1.0 - a * a - b * b;
        assert!(s2 > 0.0);
        let c = vec![1.0, 0.0, a, 0.0, 1.0, b, a, b, 1.0];
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        let rho0 = partial_corr(&corr, 0, 1, &[], &mut ws);
        let rho1 = partial_corr(&corr, 0, 1, &[2], &mut ws);
        assert!(rho0.abs() < 1e-12);
        assert!(rho1.abs() > 0.3, "rho1={rho1}");
    }

    #[test]
    fn statistic_is_abs_fisher_z() {
        let c = chain_corr();
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        let z = ci_statistic(&corr, 0, 1, &[], &mut ws);
        assert!((z - (0.8f64).atanh()).abs() < 1e-9);
    }

    #[test]
    fn duplicated_variable_in_s_is_finite() {
        // S = {1, 1} makes M2 singular; pinv must keep things finite.
        let c = chain_corr();
        let corr = Corr::new(&c, 3);
        let mut ws = CiWorkspace::new(4);
        let rho = partial_corr(&corr, 0, 2, &[1, 1], &mut ws);
        assert!(rho.is_finite());
        // and the answer should still be ~0 (conditioning on X1 twice)
        assert!(rho.abs() < 1e-3, "rho={rho}");
    }
}
