//! Sample correlation matrix from a data matrix (m samples × n variables).
//!
//! Standardize each column, then compute the gram matrix with a
//! cache-blocked kernel, optionally sharded across threads (the image may
//! have 1 core, but the code path is exercised and tested regardless).

/// Which correlation estimator feeds the CI tests. Pearson is the
/// paper's default; Spearman is the "Rank PC" variant (Harris & Drton
/// 2013, §2.3) for non-Gaussian monotone data — both produce an n×n
/// matrix consumed by the exact same skeleton machinery.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CorrKind {
    Pearson,
    Spearman,
}

impl CorrKind {
    pub fn parse(s: &str) -> Option<CorrKind> {
        match s.to_ascii_lowercase().as_str() {
            "pearson" => Some(CorrKind::Pearson),
            "spearman" | "rank" => Some(CorrKind::Spearman),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CorrKind::Pearson => "pearson",
            CorrKind::Spearman => "spearman",
        }
    }

    /// Stable tag for content hashing (cache keys depend on it — never
    /// renumber).
    pub fn tag(self) -> u8 {
        match self {
            CorrKind::Pearson => 0,
            CorrKind::Spearman => 1,
        }
    }

    /// Compute this kind's correlation matrix. Bit-identical for any
    /// `threads` value (the gram is blocked; blocks are computed
    /// identically regardless of which worker owns them).
    pub fn matrix(self, data: &DataMatrix, threads: usize) -> Vec<f64> {
        match self {
            CorrKind::Pearson => correlation_matrix(data, threads),
            CorrKind::Spearman => spearman_correlation_matrix(data, threads),
        }
    }
}

/// Column-major-free: data is row-major `m×n` (sample-major), the natural
/// CSV layout.
pub struct DataMatrix {
    pub x: Vec<f64>,
    pub m: usize,
    pub n: usize,
}

impl DataMatrix {
    pub fn new(x: Vec<f64>, m: usize, n: usize) -> Self {
        assert_eq!(x.len(), m * n, "data length {} != m*n = {}", x.len(), m * n);
        DataMatrix { x, m, n }
    }

    #[inline]
    pub fn at(&self, sample: usize, var: usize) -> f64 {
        self.x[sample * self.n + var]
    }
}

/// Standardize columns to zero mean / unit variance. Returns the
/// variable-major (n×m) standardized matrix for cache-friendly grams.
/// Constant columns standardize to all-zeros (correlation 0 with all).
pub fn standardize_var_major(data: &DataMatrix) -> Vec<f64> {
    let (m, n) = (data.m, data.n);
    let mut out = vec![0.0; n * m];
    for v in 0..n {
        let mut mean = 0.0;
        for s in 0..m {
            mean += data.at(s, v);
        }
        mean /= m as f64;
        let mut var = 0.0;
        for s in 0..m {
            let d = data.at(s, v) - mean;
            var += d * d;
        }
        let sd = (var / m as f64).sqrt();
        let inv = if sd > 1e-12 { 1.0 / (sd * (m as f64).sqrt()) } else { 0.0 };
        for s in 0..m {
            // scaling by 1/sqrt(m) here makes the gram directly the correlation
            out[v * m + s] = (data.at(s, v) - mean) * inv;
        }
    }
    out
}

/// Correlation matrix (n×n, row-major) from data, blocked gram over the
/// standardized variable-major matrix, optionally multi-threaded.
pub fn correlation_matrix(data: &DataMatrix, threads: usize) -> Vec<f64> {
    let (m, n) = (data.m, data.n);
    let xs = standardize_var_major(data);
    let mut c = vec![0.0; n * n];
    let nthreads = threads.max(1);

    // Parallelize over row-blocks of the upper triangle.
    let block = 32usize;
    let row_blocks: Vec<usize> = (0..n).step_by(block).collect();
    if nthreads == 1 {
        for &i0 in &row_blocks {
            gram_block(&xs, m, n, i0, block, &mut c);
        }
    } else {
        let next = std::sync::atomic::AtomicUsize::new(0);
        let c_ptr = SendPtr(c.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..nthreads {
                scope.spawn(|| {
                    let c_ptr = &c_ptr;
                    loop {
                        let k = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if k >= row_blocks.len() {
                            break;
                        }
                        let i0 = row_blocks[k];
                        // SAFETY: each row-block [i0, i0+block) writes a
                        // disjoint set of rows of c (and their mirrored
                        // columns are written by the owner of the row only
                        // via the symmetric fill below, also disjoint).
                        let c_slice = unsafe {
                            std::slice::from_raw_parts_mut(c_ptr.0, n * n)
                        };
                        gram_block(&xs, m, n, i0, block, c_slice);
                    }
                });
            }
        });
    }
    // mirror the upper triangle and set the diagonal exactly
    for i in 0..n {
        c[i * n + i] = 1.0;
        for j in (i + 1)..n {
            c[j * n + i] = c[i * n + j];
        }
    }
    c
}

struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Fill rows [i0, i0+block) of the upper triangle of c with xs·xsᵀ.
fn gram_block(xs: &[f64], m: usize, n: usize, i0: usize, block: usize, c: &mut [f64]) {
    let i1 = (i0 + block).min(n);
    for i in i0..i1 {
        let xi = &xs[i * m..(i + 1) * m];
        for j in i..n {
            let xj = &xs[j * m..(j + 1) * m];
            let mut acc = 0.0;
            for k in 0..m {
                acc += xi[k] * xj[k];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Spearman rank correlation matrix — the "Rank PC" variant (Harris &
/// Drton 2013, cited in the paper §2.3) for non-Gaussian monotone data:
/// replace each column by its ranks, then Pearson-correlate the ranks.
/// The result feeds the exact same CI-test machinery.
pub fn spearman_correlation_matrix(data: &DataMatrix, threads: usize) -> Vec<f64> {
    let (m, n) = (data.m, data.n);
    let mut ranked = vec![0.0f64; m * n];
    let mut idx: Vec<usize> = (0..m).collect();
    for v in 0..n {
        idx.sort_by(|&a, &b| {
            data.at(a, v)
                .partial_cmp(&data.at(b, v))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        // average ranks for ties
        let mut s = 0usize;
        while s < m {
            let mut e = s;
            while e + 1 < m && data.at(idx[e + 1], v) == data.at(idx[s], v) {
                e += 1;
            }
            let avg = (s + e) as f64 / 2.0 + 1.0;
            for &sample in &idx[s..=e] {
                ranked[sample * n + v] = avg;
            }
            s = e + 1;
        }
        idx.sort_unstable(); // restore for the next column's stable reuse
    }
    let rd = DataMatrix::new(ranked, m, n);
    correlation_matrix(&rd, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn toy_data() -> DataMatrix {
        let mut rng = Pcg::seeded(10);
        let m = 500;
        let n = 5;
        let mut x = vec![0.0; m * n];
        for s in 0..m {
            let a = rng.normal();
            let b = rng.normal();
            x[s * n] = a;
            x[s * n + 1] = 0.9 * a + 0.4359 * rng.normal(); // corr ~0.9
            x[s * n + 2] = b;
            x[s * n + 3] = -b; // corr -1
            x[s * n + 4] = 3.14; // constant
        }
        DataMatrix::new(x, m, n)
    }

    #[test]
    fn correlation_diagonal_is_one() {
        let d = toy_data();
        let c = correlation_matrix(&d, 1);
        for i in 0..d.n {
            assert_eq!(c[i * d.n + i], 1.0);
        }
    }

    #[test]
    fn correlation_symmetric() {
        let d = toy_data();
        let c = correlation_matrix(&d, 1);
        for i in 0..d.n {
            for j in 0..d.n {
                assert_eq!(c[i * d.n + j], c[j * d.n + i]);
            }
        }
    }

    #[test]
    fn correlated_pair_detected() {
        let d = toy_data();
        let c = correlation_matrix(&d, 1);
        assert!(c[1] > 0.85, "c01={}", c[1]);
        assert!((c[2 * d.n + 3] + 1.0).abs() < 1e-9, "c23={}", c[2 * d.n + 3]);
    }

    #[test]
    fn constant_column_is_zero_correlated() {
        let d = toy_data();
        let c = correlation_matrix(&d, 1);
        for i in 0..4 {
            assert_eq!(c[i * d.n + 4], 0.0);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let mut rng = Pcg::seeded(77);
        let m = 100;
        let n = 67; // awkward non-multiple of block size
        let x: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let d = DataMatrix::new(x, m, n);
        let c1 = correlation_matrix(&d, 1);
        let c4 = correlation_matrix(&d, 4);
        let md = c1
            .iter()
            .zip(&c4)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(md < 1e-12, "max diff {md}");
    }

    #[test]
    fn bounds() {
        let d = toy_data();
        let c = correlation_matrix(&d, 1);
        for v in &c {
            assert!(*v >= -1.0 - 1e-9 && *v <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn spearman_detects_monotone_nonlinear() {
        // y = exp(x): Pearson < 1 but Spearman == 1 exactly
        let mut rng = Pcg::seeded(21);
        let m = 300;
        let mut x = vec![0.0; m * 2];
        for s in 0..m {
            let v = rng.normal();
            x[s * 2] = v;
            x[s * 2 + 1] = (3.0 * v).exp();
        }
        let d = DataMatrix::new(x, m, 2);
        let pearson = correlation_matrix(&d, 1)[1];
        let spearman = spearman_correlation_matrix(&d, 1)[1];
        assert!(spearman > 0.999, "spearman={spearman}");
        assert!(pearson < 0.9, "pearson={pearson}");
    }

    #[test]
    fn corr_kind_parses_and_dispatches() {
        assert_eq!(CorrKind::parse("pearson"), Some(CorrKind::Pearson));
        assert_eq!(CorrKind::parse("Spearman"), Some(CorrKind::Spearman));
        assert_eq!(CorrKind::parse("rank"), Some(CorrKind::Spearman));
        assert_eq!(CorrKind::parse("kendall"), None);
        assert_ne!(CorrKind::Pearson.tag(), CorrKind::Spearman.tag());
        let d = toy_data();
        assert_eq!(
            CorrKind::Pearson.matrix(&d, 1),
            correlation_matrix(&d, 1),
            "Pearson dispatch"
        );
        assert_eq!(
            CorrKind::Spearman.matrix(&d, 1),
            spearman_correlation_matrix(&d, 1),
            "Spearman dispatch"
        );
    }

    #[test]
    fn threaded_matches_serial_bitwise() {
        // the batch service caches correlation matrices across jobs that
        // may run at different leased widths: the blocked gram must be
        // bit-identical, not merely close, for any thread count
        let mut rng = Pcg::seeded(78);
        let m = 80;
        let n = 67;
        let x: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        let d = DataMatrix::new(x, m, n);
        assert_eq!(correlation_matrix(&d, 1), correlation_matrix(&d, 4));
        assert_eq!(
            spearman_correlation_matrix(&d, 1),
            spearman_correlation_matrix(&d, 3)
        );
    }

    #[test]
    fn spearman_handles_ties() {
        let x = vec![1.0, 5.0, 1.0, 7.0, 2.0, 9.0, 2.0, 11.0];
        let d = DataMatrix::new(x, 4, 2);
        let s = spearman_correlation_matrix(&d, 1);
        assert!(s[1].is_finite());
        assert!(s[1] > 0.8, "tied ranks should still correlate: {}", s[1]);
    }

    #[test]
    fn spearman_equals_pearson_on_ranks_of_gaussian() {
        let mut rng = Pcg::seeded(22);
        let m = 500;
        let mut x = vec![0.0; m * 2];
        for s in 0..m {
            let a = rng.normal();
            x[s * 2] = a;
            x[s * 2 + 1] = 0.8 * a + 0.6 * rng.normal();
        }
        let d = DataMatrix::new(x, m, 2);
        let p = correlation_matrix(&d, 1)[1];
        let sp = spearman_correlation_matrix(&d, 1)[1];
        // for bivariate normal, spearman ~ (6/pi) asin(rho/2) ≈ rho
        assert!((p - sp).abs() < 0.05, "pearson={p} spearman={sp}");
    }
}
