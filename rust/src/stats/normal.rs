//! Standard normal CDF and quantile function.
//!
//! `phi_inv` (Φ⁻¹) is Acklam's rational approximation refined with one
//! Halley step against `phi`; overall |error| < ~2e-7 (bounded by the
//! erfc Chebyshev fit), four orders below what the τ threshold
//! (paper eq. 7) needs.

/// Standard normal CDF Φ(x) via the complementary error function.
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Complementary error function (Numerical Recipes' Chebyshev fit,
/// |err| < 1.2e-7 before refinement; adequate and monotone).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Inverse standard normal CDF Φ⁻¹(p), p in (0, 1). Acklam's algorithm
/// plus one Halley refinement step using `phi`.
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: p in (0,1), got {p}");

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley step: e = Phi(x) - p; u = e * sqrt(2*pi) * exp(x^2/2)
    let e = phi(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi_known_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-7);
        assert!((phi(1.959963985) - 0.975).abs() < 1e-6);
        assert!((phi(-1.959963985) - 0.025).abs() < 1e-6);
        assert!((phi(2.575829304) - 0.995).abs() < 1e-6);
    }

    #[test]
    fn phi_inv_known_values() {
        assert!((phi_inv(0.5)).abs() < 1e-6);
        assert!((phi_inv(0.975) - 1.959963985).abs() < 2e-6);
        assert!((phi_inv(0.995) - 2.575829304).abs() < 2e-6);
        assert!((phi_inv(0.025) + 1.959963985).abs() < 2e-6);
    }

    #[test]
    fn phi_inv_roundtrip() {
        for i in 1..200 {
            let p = i as f64 / 200.0;
            let x = phi_inv(p);
            assert!((phi(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
    }

    #[test]
    fn phi_inv_tails() {
        let x = phi_inv(1e-10);
        assert!(x < -6.0 && x > -7.0, "x={x}");
        let y = phi_inv(1.0 - 1e-10);
        assert!((x + y).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn phi_inv_rejects_zero() {
        phi_inv(0.0);
    }

    #[test]
    fn phi_monotone() {
        let mut last = 0.0;
        for i in -400..400 {
            let v = phi(i as f64 / 100.0);
            assert!(v >= last);
            last = v;
        }
    }
}
