//! Runtime-selectable CI-test kernels: the hot EBatch/SBatch paths.
//!
//! The packed batches built by `skeleton/batch.rs` are evaluated here.
//! Two kernels share one contract (see `docs/NUMERICS.md`):
//!
//! * [`scalar`] — the reference path: one slot at a time, row-major,
//!   exactly the loop nest the engine has always run. Every other
//!   kernel is diffed against it.
//! * [`blocked`] — the vectorized path: processes [`LANES`] batch slots
//!   per inner iteration over *lane-major* (column-major across the
//!   block) f64 panels, so the per-`(r, c, k)` updates become
//!   contiguous 8-wide strips the autovectorizer turns into SIMD. The
//!   per-lane f64 operation *order* is identical to the scalar kernel
//!   (same `r`/`c`/`k` nesting, same pseudo-inverse per slot, remainder
//!   slots run the scalar routine), so its output is **bitwise
//!   identical** by construction — the conformance grid stays the
//!   bitwise gate. A future kernel that reassociates (block-summed
//!   grams, FMA) instead gates on the margin bound from
//!   `tools/margin_oracle.py --kernel-delta`.
//!
//! Selection: `CUPC_KERNEL=scalar|blocked` (read once, see
//! [`KernelKind::from_env`]) or explicitly via `Config.kernel` /
//! `NativeEngine::with_kernel`. The choice never enters cache keys —
//! like thread count, it cannot change a single output bit.
//!
//! ```
//! use cupc::stats::kernels::KernelKind;
//! assert_eq!(KernelKind::parse("scalar"), Some(KernelKind::Scalar));
//! assert_eq!(KernelKind::parse("BLOCKED"), Some(KernelKind::Blocked));
//! assert_eq!(KernelKind::parse("simd"), None);
//! assert_eq!(KernelKind::default().name(), "blocked");
//! ```

use crate::stats::chol::PinvScratch;
use std::sync::OnceLock;

pub mod blocked;
pub mod scalar;

/// Batch slots evaluated per inner iteration by the blocked kernel —
/// the CPU analogue of a (narrow) CUDA warp. 8 f64 lanes = one AVX-512
/// register or two AVX2 registers; the panels stay L1-resident at
/// every supported level.
pub const LANES: usize = 8;

/// Which CI-test kernel evaluates packed batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Reference path: per-slot row-major loops (the bitwise oracle).
    Scalar,
    /// Lane-major blocked path (bitwise-identical, autovectorizable).
    #[default]
    Blocked,
}

impl KernelKind {
    /// Parse a kernel name (case-insensitive). `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "blocked" => Some(KernelKind::Blocked),
            _ => None,
        }
    }

    /// Stable lowercase name (round-trips through [`KernelKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
        }
    }

    /// Kernel selected by `CUPC_KERNEL`, defaulting to [`Blocked`]
    /// (unset or unrecognized values fall back to the default). Read
    /// once per process — tests that need both kernels in one process
    /// construct engines explicitly instead of mutating the
    /// environment.
    ///
    /// [`Blocked`]: KernelKind::Blocked
    pub fn from_env() -> Self {
        static CACHED: OnceLock<KernelKind> = OnceLock::new();
        *CACHED.get_or_init(|| {
            std::env::var("CUPC_KERNEL")
                .ok()
                .and_then(|s| KernelKind::parse(&s))
                .unwrap_or_default()
        })
    }
}

/// Reusable per-engine workspace shared by both kernels: the
/// pseudo-inverse scratch plus the lane-major panels the blocked
/// kernel gathers into. Sized once for the largest supported level
/// (~72 KiB at `max_l = 32`) so the hot loops never allocate.
pub struct Scratch {
    pinv: PinvScratch,
    /// M2 widened to f64 (`l·l`), input to the pseudo-inverse.
    m2f: Vec<f64>,
    /// M2⁻¹ for the slot/row most recently inverted (`l·l`).
    m2inv: Vec<f64>,
    /// Lane-major M1 panel: `m1p[c·LANES + lane]` (`2·l·LANES`).
    m1p: Vec<f64>,
    /// Lane-major M2⁻¹ panel: `m2invp[e·LANES + lane]` (`l·l·LANES`).
    m2invp: Vec<f64>,
}

impl Scratch {
    pub fn new(max_l: usize) -> Self {
        Scratch {
            pinv: PinvScratch::new(max_l),
            m2f: vec![0.0; max_l * max_l],
            m2inv: vec![0.0; max_l * max_l],
            m1p: vec![0.0; 2 * max_l * LANES],
            m2invp: vec![0.0; max_l * max_l * LANES],
        }
    }
}

/// Level-0 sweep: elementwise `|fisher_z|` of raw correlations. Both
/// kernels share the scalar routine — there is no accumulation to
/// block, and libm's `ln` dominates.
pub fn level0(_kind: KernelKind, c_ij: &[f32]) -> Vec<f32> {
    scalar::level0(c_ij)
}

/// cuPC-E batch: one `(i, j, S)` test per slot, `b` slots.
pub fn ci_e(
    kind: KernelKind,
    l: usize,
    b: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    sc: &mut Scratch,
) -> Vec<f32> {
    match kind {
        KernelKind::Scalar => scalar::ci_e(l, b, c_ij, m1, m2, sc),
        KernelKind::Blocked => blocked::ci_e(l, b, c_ij, m1, m2, sc),
    }
}

/// cuPC-S batch: `rows` conditioning sets × `k` tests each, one
/// pseudo-inverse per row.
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI
pub fn ci_s(
    kind: KernelKind,
    l: usize,
    rows: usize,
    k: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    valid: &[u32],
    sc: &mut Scratch,
) -> Vec<f32> {
    match kind {
        KernelKind::Scalar => scalar::ci_s(l, rows, k, c_ij, m1, m2, valid, sc),
        KernelKind::Blocked => blocked::ci_s(l, rows, k, c_ij, m1, m2, valid, sc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_and_rejects_unknown() {
        for kind in [KernelKind::Scalar, KernelKind::Blocked] {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse(" Scalar "), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse(""), None);
        assert_eq!(KernelKind::parse("avx"), None);
    }

    #[test]
    fn default_is_blocked() {
        assert_eq!(KernelKind::default(), KernelKind::Blocked);
    }
}
