//! Blocked, lane-major CI-test kernel: [`LANES`] slots per iteration.
//!
//! Layout (the CPU translation of cuPC's coalesced accesses): for each
//! block of `LANES` batch slots the per-slot M1 rows and M2⁻¹ entries
//! are gathered into *lane-major* f64 panels —
//! `panel[coeff_index · LANES + lane]` — so that one coefficient's
//! values for all eight slots sit in one contiguous, aligned strip.
//! The `r → c → k` loop nest of the scalar kernel then runs once per
//! block with every scalar op widened to an 8-lane strip op the
//! autovectorizer lowers to SIMD; no lane ever reads another lane.
//!
//! Numerics (see `docs/NUMERICS.md`): for each lane the sequence of
//! f64 operations — widening loads, multiply, the `k`-ascending
//! accumulation into `acc`, the `c`-ascending accumulation into
//! `h00/h01/h11`, and the per-slot `pinv_fast` — is *exactly* the
//! scalar kernel's sequence, so the output is bitwise identical by
//! construction, and the conformance grid diffs the two kernels with
//! `assert_eq!`. The remainder (`b mod LANES` slots, and partially
//! valid cuPC-S rows) runs the scalar per-slot routine directly.

use super::{scalar, Scratch, LANES};
use crate::stats::fisher::fisher_z;

/// cuPC-E batch: full blocks of `LANES` slots, scalar remainder.
pub fn ci_e(
    l: usize,
    b: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    sc: &mut Scratch,
) -> Vec<f32> {
    let mut z = vec![0.0f32; b];
    let full = b / LANES * LANES;
    let mut s0 = 0;
    while s0 < full {
        // Gather: one pseudo-inverse per lane (identical to scalar),
        // scattered into the lane-major panels.
        for lane in 0..LANES {
            let s = s0 + lane;
            scalar::pinv_f32(&m2[s * l * l..(s + 1) * l * l], l, sc);
            for (e, &v) in sc.m2inv[..l * l].iter().enumerate() {
                sc.m2invp[e * LANES + lane] = v;
            }
            for (c, &v) in m1[s * 2 * l..(s + 1) * 2 * l].iter().enumerate() {
                sc.m1p[c * LANES + lane] = v as f64;
            }
        }
        block_z(&c_ij[s0..s0 + LANES], sc, l, &mut z[s0..s0 + LANES]);
        s0 += LANES;
    }
    for s in full..b {
        scalar::pinv_f32(&m2[s * l * l..(s + 1) * l * l], l, sc);
        z[s] = scalar::z_from_packed(
            c_ij[s],
            &m1[s * 2 * l..(s + 1) * 2 * l],
            &sc.m2inv[..l * l],
            l,
        );
    }
    z
}

/// cuPC-S batch: ONE pseudo-inverse per row, broadcast across the
/// lane block (every lane in a row shares M2⁻¹ — the cuPC-S saving
/// becomes a scalar-broadcast multiplier). Full blocks inside
/// `valid[r]`; the partial tail runs per-slot scalar; padding keeps
/// z = 0.0.
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI
pub fn ci_s(
    l: usize,
    rows: usize,
    k: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    valid: &[u32],
    sc: &mut Scratch,
) -> Vec<f32> {
    let mut z = vec![0.0f32; rows * k];
    for r in 0..rows {
        scalar::pinv_f32(&m2[r * l * l..(r + 1) * l * l], l, sc);
        let nt = (valid[r] as usize).min(k);
        let full = nt / LANES * LANES;
        let mut t0 = 0;
        while t0 < full {
            let s0 = r * k + t0;
            for lane in 0..LANES {
                let s = s0 + lane;
                for (c, &v) in m1[s * 2 * l..(s + 1) * 2 * l].iter().enumerate() {
                    sc.m1p[c * LANES + lane] = v as f64;
                }
            }
            block_z_shared(&c_ij[s0..s0 + LANES], sc, l, &mut z[s0..s0 + LANES]);
            t0 += LANES;
        }
        for t in full..nt {
            let s = r * k + t;
            z[s] = scalar::z_from_packed(
                c_ij[s],
                &m1[s * 2 * l..(s + 1) * 2 * l],
                &sc.m2inv[..l * l],
                l,
            );
        }
    }
    z
}

/// One block of z statistics from the lane-major panels (per-slot
/// M2⁻¹, i.e. the ci_e shape). Per lane this replays the scalar
/// `z_from_packed` op-for-op.
fn block_z(c_ij: &[f32], sc: &Scratch, l: usize, out: &mut [f32]) {
    let m1p = &sc.m1p[..2 * l * LANES];
    let m2invp = &sc.m2invp[..l * l * LANES];
    let mut h00 = [0.0f64; LANES];
    let mut h01 = [0.0f64; LANES];
    let mut h11 = [0.0f64; LANES];
    for r in 0..2 {
        for c in 0..l {
            let mut acc = [0.0f64; LANES];
            for k in 0..l {
                let a = &m1p[(r * l + k) * LANES..][..LANES];
                let m = &m2invp[(k * l + c) * LANES..][..LANES];
                for ((acc, &a), &m) in acc.iter_mut().zip(a).zip(m) {
                    *acc += a * m;
                }
            }
            accumulate_h(r, c, l, m1p, &acc, &mut h00, &mut h01, &mut h11);
        }
    }
    finish_block(c_ij, &h00, &h01, &h11, out);
}

/// Same as [`block_z`] but with one shared M2⁻¹ for the whole block
/// (the ci_s shape): the inverse enters as a scalar broadcast.
fn block_z_shared(c_ij: &[f32], sc: &Scratch, l: usize, out: &mut [f32]) {
    let m1p = &sc.m1p[..2 * l * LANES];
    let m2inv = &sc.m2inv[..l * l];
    let mut h00 = [0.0f64; LANES];
    let mut h01 = [0.0f64; LANES];
    let mut h11 = [0.0f64; LANES];
    for r in 0..2 {
        for c in 0..l {
            let mut acc = [0.0f64; LANES];
            for k in 0..l {
                let a = &m1p[(r * l + k) * LANES..][..LANES];
                let m = m2inv[k * l + c];
                for (acc, &a) in acc.iter_mut().zip(a) {
                    *acc += a * m;
                }
            }
            accumulate_h(r, c, l, m1p, &acc, &mut h00, &mut h01, &mut h11);
        }
    }
    finish_block(c_ij, &h00, &h01, &h11, out);
}

/// Fold one `acc` strip into the H accumulators — the lane-wide
/// version of the scalar kernel's `match r` arm (h00 before h01 for
/// r = 0, matching the scalar statement order per lane).
#[allow(clippy::too_many_arguments)] // hot-loop helper, mirrors the scalar arm
#[inline]
fn accumulate_h(
    r: usize,
    c: usize,
    l: usize,
    m1p: &[f64],
    acc: &[f64; LANES],
    h00: &mut [f64; LANES],
    h01: &mut [f64; LANES],
    h11: &mut [f64; LANES],
) {
    if r == 0 {
        let mi = &m1p[c * LANES..][..LANES];
        let mj = &m1p[(l + c) * LANES..][..LANES];
        for ((h, &acc), &m) in h00.iter_mut().zip(acc).zip(mi) {
            *h += acc * m;
        }
        for ((h, &acc), &m) in h01.iter_mut().zip(acc).zip(mj) {
            *h += acc * m;
        }
    } else {
        let mj = &m1p[(l + c) * LANES..][..LANES];
        for ((h, &acc), &m) in h11.iter_mut().zip(acc).zip(mj) {
            *h += acc * m;
        }
    }
}

/// ρ and Fisher-z epilogue for one block, per-lane identical to the
/// scalar tail.
#[inline]
fn finish_block(
    c_ij: &[f32],
    h00: &[f64; LANES],
    h01: &[f64; LANES],
    h11: &[f64; LANES],
    out: &mut [f32],
) {
    for (lane, z) in out.iter_mut().enumerate() {
        let h00 = 1.0 - h00[lane];
        let h11 = 1.0 - h11[lane];
        let h01 = c_ij[lane] as f64 - h01[lane];
        let rho = h01 / (h00 * h11).max(1e-12).sqrt();
        *z = fisher_z(rho) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ci_e, ci_s, KernelKind, Scratch};
    use crate::sim::batches::{random_batch, random_s_batch};
    use crate::util::rng::Pcg;

    const MAX_L: usize = 32;

    /// Bitwise agreement on single-slot batches: with one slot there is
    /// no blocking at all (the remainder path runs), so any divergence
    /// here would mean the seam itself leaks.
    #[test]
    fn single_slot_batches_agree_bitwise() {
        let mut rng = Pcg::seeded(0x51);
        let mut sc_s = Scratch::new(MAX_L);
        let mut sc_b = Scratch::new(MAX_L);
        for l in 1..=8 {
            let (c_ij, m1, m2) = random_batch(&mut rng, 1, l);
            let zs = ci_e(KernelKind::Scalar, l, 1, &c_ij, &m1, &m2, &mut sc_s);
            let zb = ci_e(KernelKind::Blocked, l, 1, &c_ij, &m1, &m2, &mut sc_b);
            assert_eq!(zs[0].to_bits(), zb[0].to_bits(), "l={l}");
        }
    }

    /// Bitwise agreement across the full random generator, including
    /// odd batch sizes that exercise every remainder length 0..LANES.
    #[test]
    fn ci_e_agrees_bitwise_across_batch_sizes() {
        let mut rng = Pcg::seeded(0xE0);
        let mut sc_s = Scratch::new(MAX_L);
        let mut sc_b = Scratch::new(MAX_L);
        for l in 1..=8 {
            for b in [1usize, 7, 8, 9, 15, 16, 33, 100] {
                let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
                let zs = ci_e(KernelKind::Scalar, l, b, &c_ij, &m1, &m2, &mut sc_s);
                let zb = ci_e(KernelKind::Blocked, l, b, &c_ij, &m1, &m2, &mut sc_b);
                for (s, (a, x)) in zs.iter().zip(&zb).enumerate() {
                    assert_eq!(a.to_bits(), x.to_bits(), "l={l} b={b} slot={s}");
                }
            }
        }
    }

    /// ci_s bitwise agreement, sweeping partial `valid` widths so both
    /// the full-block and per-slot tails run, and padding stays 0.
    #[test]
    fn ci_s_agrees_bitwise_including_partial_rows() {
        let mut rng = Pcg::seeded(0x50);
        let mut sc_s = Scratch::new(MAX_L);
        let mut sc_b = Scratch::new(MAX_L);
        for l in 1..=8 {
            for (rows, k) in [(1usize, 4usize), (3, 8), (5, 17), (4, 32)] {
                let (c_ij, m1, m2) = random_s_batch(&mut rng, rows, k, l);
                // a mix of full, partial, and empty rows
                let valid: Vec<u32> = (0..rows as u32)
                    .map(|r| match r % 4 {
                        0 => k as u32,
                        1 => (k as u32) / 2,
                        2 => 1,
                        _ => 0,
                    })
                    .collect();
                let zs = ci_s(KernelKind::Scalar, l, rows, k, &c_ij, &m1, &m2, &valid, &mut sc_s);
                let zb = ci_s(KernelKind::Blocked, l, rows, k, &c_ij, &m1, &m2, &valid, &mut sc_b);
                for (s, (a, x)) in zs.iter().zip(&zb).enumerate() {
                    assert_eq!(a.to_bits(), x.to_bits(), "l={l} rows={rows} k={k} slot={s}");
                }
                for r in 0..rows {
                    for t in (valid[r] as usize).min(k)..k {
                        assert_eq!(zb[r * k + t], 0.0, "padding must stay zero");
                    }
                }
            }
        }
    }
}
