//! Reference CI-test kernel: one slot at a time, row-major loops.
//!
//! This is the loop nest the native engine has always run, moved
//! verbatim behind the kernel seam. It defines the bitwise contract
//! every other kernel is held to (`docs/NUMERICS.md`): f32 inputs
//! widened to f64, the `r → c → k` accumulation order below, one
//! `pinv_fast` pseudo-inverse per slot (ci_e) or per row (ci_s).

use super::Scratch;
use crate::stats::chol::pinv_fast;
use crate::stats::fisher::fisher_z;

/// |z| of raw correlations (level 0) — shared by both kernels.
pub fn level0(c_ij: &[f32]) -> Vec<f32> {
    c_ij.iter().map(|&c| fisher_z(c as f64) as f32).collect()
}

/// z for one packed test given a precomputed M2⁻¹.
#[inline]
pub(super) fn z_from_packed(c_ij: f32, m1: &[f32], m2inv: &[f64], l: usize) -> f32 {
    // w = M1 M2⁻¹ (2×l), H = M0 − w M1ᵀ
    let (mut h00, mut h01, mut h11) = (0.0f64, 0.0f64, 0.0f64);
    for r in 0..2 {
        for c in 0..l {
            let mut acc = 0.0f64;
            for k in 0..l {
                acc += m1[r * l + k] as f64 * m2inv[k * l + c];
            }
            // accumulate H terms on the fly
            match r {
                0 => {
                    h00 += acc * m1[c] as f64;
                    h01 += acc * m1[l + c] as f64;
                }
                _ => {
                    h11 += acc * m1[l + c] as f64;
                }
            }
        }
    }
    let h00 = 1.0 - h00;
    let h11 = 1.0 - h11;
    let h01 = c_ij as f64 - h01;
    let rho = h01 / (h00 * h11).max(1e-12).sqrt();
    fisher_z(rho) as f32
}

/// Widen a packed f32 M2 to f64 and pseudo-invert it into `sc.m2inv`.
pub(super) fn pinv_f32(m2: &[f32], l: usize, sc: &mut Scratch) {
    let Scratch { pinv, m2f, m2inv, .. } = sc;
    for (dst, src) in m2f[..l * l].iter_mut().zip(m2) {
        *dst = *src as f64;
    }
    pinv_fast(&m2f[..l * l], l, pinv, &mut m2inv[..l * l]);
}

/// cuPC-E batch: one pseudo-inverse + one z per slot.
pub fn ci_e(
    l: usize,
    b: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    sc: &mut Scratch,
) -> Vec<f32> {
    let mut z = Vec::with_capacity(b);
    for s in 0..b {
        pinv_f32(&m2[s * l * l..(s + 1) * l * l], l, sc);
        z.push(z_from_packed(
            c_ij[s],
            &m1[s * 2 * l..(s + 1) * 2 * l],
            &sc.m2inv[..l * l],
            l,
        ));
    }
    z
}

/// cuPC-S batch: ONE pseudo-inverse per row (the cuPC-S saving),
/// padded tail skipped — padding slots keep z = 0.0.
#[allow(clippy::too_many_arguments)] // mirrors the kernel ABI
pub fn ci_s(
    l: usize,
    rows: usize,
    k: usize,
    c_ij: &[f32],
    m1: &[f32],
    m2: &[f32],
    valid: &[u32],
    sc: &mut Scratch,
) -> Vec<f32> {
    let mut z = vec![0.0f32; rows * k];
    for r in 0..rows {
        pinv_f32(&m2[r * l * l..(r + 1) * l * l], l, sc);
        // skip the padded tail (CUDA's inactive lanes, for free here)
        for t in 0..(valid[r] as usize).min(k) {
            let s = r * k + t;
            z[s] = z_from_packed(c_ij[s], &m1[s * 2 * l..(s + 1) * 2 * l], &sc.m2inv[..l * l], l);
        }
    }
    z
}
