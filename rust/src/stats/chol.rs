//! Small dense linear algebra for conditioning sets (ℓ ≤ ~16).
//!
//! [`pinv_fast`] is shared by both CI-test kernel paths in
//! [`crate::stats::kernels`] — sharing it (rather than re-deriving a
//! blocked factorization) is one of the three properties that make the
//! blocked kernel bitwise-identical to scalar (`docs/NUMERICS.md`).
//!
//! Mirrors `python/compile/kernels/linalg.py` operation-for-operation:
//! Cholesky-Banachiewicz factorization (optionally rank-revealing, zeroing
//! deficient columns — Courrieu's "full-rank Cholesky" with static shape),
//! forward-substitution triangular inverse, SPD inverse, and the paper's
//! Algorithm 7 Moore-Penrose pseudo-inverse. Row-major `&[f64]` matrices,
//! caller-provided scratch to keep the hot loop allocation-free.

/// Jitter matching `linalg.CHOL_EPS` (f32 kernels use 1e-8; we keep the
/// same constant so Native and XLA engines agree numerically).
pub const CHOL_EPS: f64 = 1e-8;

/// In-place lower Cholesky of the row-major `l×l` matrix `a` into `out`.
/// If `rank_tol > 0`, pivots with squared norm below it zero their column
/// (rank-revealing); otherwise pivots are clamped to CHOL_EPS.
pub fn cholesky(a: &[f64], l: usize, rank_tol: f64, out: &mut [f64]) {
    debug_assert_eq!(a.len(), l * l);
    debug_assert_eq!(out.len(), l * l);
    out.fill(0.0);
    for k in 0..l {
        let mut s = a[k * l + k];
        for m in 0..k {
            s -= out[k * l + m] * out[k * l + m];
        }
        let (dkk, inv_dkk) = if rank_tol > 0.0 {
            if s > rank_tol {
                let d = s.max(CHOL_EPS).sqrt();
                (d, 1.0 / d)
            } else {
                (0.0, 0.0)
            }
        } else {
            let d = s.max(CHOL_EPS).sqrt();
            (d, 1.0 / d)
        };
        out[k * l + k] = dkk;
        for i in (k + 1)..l {
            let mut s = a[i * l + k];
            for m in 0..k {
                s -= out[i * l + m] * out[k * l + m];
            }
            out[i * l + k] = s * inv_dkk;
        }
    }
}

/// Inverse of a lower-triangular matrix by forward substitution.
/// Zero pivots (from rank-revealing Cholesky) produce zero columns.
pub fn tril_inverse(lmat: &[f64], l: usize, out: &mut [f64]) {
    debug_assert_eq!(lmat.len(), l * l);
    out.fill(0.0);
    for j in 0..l {
        for i in j..l {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in j..i {
                s -= lmat[i * l + k] * out[k * l + j];
            }
            let d = lmat[i * l + i];
            out[i * l + j] = if d != 0.0 { s / d } else { 0.0 };
        }
    }
}

/// out = a × b for row-major `l×l` matrices.
pub fn matmul(a: &[f64], b: &[f64], l: usize, out: &mut [f64]) {
    out.fill(0.0);
    for i in 0..l {
        for k in 0..l {
            let aik = a[i * l + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..l {
                out[i * l + j] += aik * b[k * l + j];
            }
        }
    }
}

/// out = aᵀ × a.
pub fn gram(a: &[f64], l: usize, out: &mut [f64]) {
    out.fill(0.0);
    for k in 0..l {
        for i in 0..l {
            let aki = a[k * l + i];
            if aki == 0.0 {
                continue;
            }
            for j in 0..l {
                out[i * l + j] += aki * a[k * l + j];
            }
        }
    }
}

/// SPD inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹. `scratch` needs 2·l² slots.
pub fn spd_inverse(a: &[f64], l: usize, scratch: &mut [f64], out: &mut [f64]) {
    let (lmat, linv) = scratch.split_at_mut(l * l);
    cholesky(a, l, 0.0, lmat);
    tril_inverse(lmat, l, linv);
    // out = linvᵀ × linv
    out.fill(0.0);
    for k in 0..l {
        for i in 0..l {
            let lki = linv[k * l + i];
            if lki == 0.0 {
                continue;
            }
            for j in 0..l {
                out[i * l + j] += lki * linv[k * l + j];
            }
        }
    }
}

/// Scratch buffer for [`pinv`]; reuse across calls to avoid allocation.
pub struct PinvScratch {
    mtm: Vec<f64>,
    lmat: Vec<f64>,
    ltl: Vec<f64>,
    r: Vec<f64>,
    t1: Vec<f64>,
    t2: Vec<f64>,
    spd: Vec<f64>,
}

impl PinvScratch {
    pub fn new(max_l: usize) -> Self {
        let s = max_l * max_l;
        PinvScratch {
            mtm: vec![0.0; s],
            lmat: vec![0.0; s],
            ltl: vec![0.0; s],
            r: vec![0.0; s],
            t1: vec![0.0; s],
            t2: vec![0.0; s],
            spd: vec![0.0; 2 * s],
        }
    }
}

/// Moore-Penrose pseudo-inverse, paper Algorithm 7 (Courrieu):
/// L = full-rank-chol(M2ᵀM2); R = (LᵀL + εI)⁻¹; M2⁺ = L·R·R·Lᵀ·M2ᵀ.
/// Mirrors `linalg.batched_pinv` including the 1×1 fast path and the
/// relative rank tolerance.
pub fn pinv(m2: &[f64], l: usize, sc: &mut PinvScratch, out: &mut [f64]) {
    debug_assert_eq!(m2.len(), l * l);
    if l == 1 {
        let x = m2[0];
        out[0] = x / (x * x + CHOL_EPS);
        return;
    }
    let n2 = l * l;
    gram(m2, l, &mut sc.mtm[..n2]);
    // rank tolerance relative to the largest diagonal entry
    let mut maxd: f64 = 0.0;
    for d in 0..l {
        maxd = maxd.max(sc.mtm[d * l + d]);
    }
    let rank_tol = maxd * 1e-6 + CHOL_EPS;
    cholesky(&sc.mtm[..n2], l, rank_tol, &mut sc.lmat[..n2]);
    // LᵀL + eps I
    gram(&sc.lmat[..n2], l, &mut sc.ltl[..n2]);
    for d in 0..l {
        sc.ltl[d * l + d] += CHOL_EPS;
    }
    spd_inverse(&sc.ltl[..n2], l, &mut sc.spd[..2 * n2], &mut sc.r[..n2]);
    // t1 = L R ; t2 = t1 R ; t1 = t2 Lᵀ ; out = t1 M2ᵀ
    matmul(&sc.lmat[..n2], &sc.r[..n2], l, &mut sc.t1[..n2]);
    matmul(&sc.t1[..n2], &sc.r[..n2], l, &mut sc.t2[..n2]);
    // t1 = t2 × Lᵀ
    sc.t1[..n2].fill(0.0);
    for i in 0..l {
        for k in 0..l {
            let v = sc.t2[i * l + k];
            if v == 0.0 {
                continue;
            }
            for j in 0..l {
                sc.t1[i * l + j] += v * sc.lmat[j * l + k];
            }
        }
    }
    // out = t1 × M2ᵀ
    out.fill(0.0);
    for i in 0..l {
        for k in 0..l {
            let v = sc.t1[i * l + k];
            if v == 0.0 {
                continue;
            }
            for j in 0..l {
                out[i * l + j] += v * m2[j * l + k];
            }
        }
    }
}

/// Fast-path pseudo-inverse: identical result to [`pinv`] on
/// well-conditioned correlation submatrices (the overwhelmingly common
/// case in a PC run) at a fraction of the cost, falling back to the full
/// Algorithm 7 when conditioning is poor.
///
/// * l = 1: closed form.
/// * l = 2, 3: direct adjugate inverse guarded by a determinant check.
/// * l ≥ 4: plain Cholesky inverse (A⁻¹ = L⁻ᵀL⁻¹) guarded by the pivot
///   magnitudes; Algorithm 7 when any pivot degenerates.
///
/// The XLA kernels keep the full Algorithm 7 — batched einsums amortize
/// it; this path only serves the sequential native mirror (§Perf L3).
pub fn pinv_fast(m2: &[f64], l: usize, sc: &mut PinvScratch, out: &mut [f64]) {
    const DET_TOL: f64 = 1e-6;
    match l {
        1 => {
            let x = m2[0];
            out[0] = x / (x * x + CHOL_EPS);
        }
        2 => {
            let (a, b, c, d) = (m2[0], m2[1], m2[2], m2[3]);
            let det = a * d - b * c;
            let scale = a.abs().max(b.abs()).max(c.abs()).max(d.abs());
            if det.abs() > DET_TOL * scale * scale {
                let inv = 1.0 / det;
                out[0] = d * inv;
                out[1] = -b * inv;
                out[2] = -c * inv;
                out[3] = a * inv;
            } else {
                pinv(m2, l, sc, out);
            }
        }
        3 => {
            let m = m2;
            let c00 = m[4] * m[8] - m[5] * m[7];
            let c01 = m[5] * m[6] - m[3] * m[8];
            let c02 = m[3] * m[7] - m[4] * m[6];
            let det = m[0] * c00 + m[1] * c01 + m[2] * c02;
            let scale = m.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
            if det.abs() > DET_TOL * scale * scale * scale {
                let inv = 1.0 / det;
                out[0] = c00 * inv;
                out[1] = (m[2] * m[7] - m[1] * m[8]) * inv;
                out[2] = (m[1] * m[5] - m[2] * m[4]) * inv;
                out[3] = c01 * inv;
                out[4] = (m[0] * m[8] - m[2] * m[6]) * inv;
                out[5] = (m[2] * m[3] - m[0] * m[5]) * inv;
                out[6] = c02 * inv;
                out[7] = (m[1] * m[6] - m[0] * m[7]) * inv;
                out[8] = (m[0] * m[4] - m[1] * m[3]) * inv;
            } else {
                pinv(m2, l, sc, out);
            }
        }
        _ => {
            // Cholesky with rank detection reusing the scratch buffers
            let n2 = l * l;
            let maxd = (0..l).fold(0.0f64, |a, d| a.max(m2[d * l + d]));
            let rank_tol = maxd * 1e-6 + CHOL_EPS;
            cholesky(m2, l, rank_tol, &mut sc.lmat[..n2]);
            let full_rank = (0..l).all(|d| sc.lmat[d * l + d] > 0.0);
            if full_rank {
                tril_inverse(&sc.lmat[..n2], l, &mut sc.t1[..n2]);
                // out = t1ᵀ t1
                out.fill(0.0);
                for k in 0..l {
                    for i in 0..l {
                        let v = sc.t1[k * l + i];
                        if v == 0.0 {
                            continue;
                        }
                        for j in 0..=i {
                            out[i * l + j] += v * sc.t1[k * l + j];
                        }
                    }
                }
                for i in 0..l {
                    for j in (i + 1)..l {
                        out[i * l + j] = out[j * l + i];
                    }
                }
            } else {
                pinv(m2, l, sc, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_spd(rng: &mut Pcg, l: usize) -> Vec<f64> {
        // A = B Bᵀ + 0.1 I
        let b: Vec<f64> = (0..l * l).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; l * l];
        for i in 0..l {
            for j in 0..l {
                let mut s = if i == j { 0.1 } else { 0.0 };
                for k in 0..l {
                    s += b[i * l + k] * b[j * l + k];
                }
                a[i * l + j] = s;
            }
        }
        a
    }

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg::seeded(1);
        for l in [1, 2, 3, 5, 8] {
            let a = random_spd(&mut rng, l);
            let mut lo = vec![0.0; l * l];
            cholesky(&a, l, 0.0, &mut lo);
            // rec = lo loᵀ
            let mut rec = vec![0.0; l * l];
            for i in 0..l {
                for j in 0..l {
                    for k in 0..l {
                        rec[i * l + j] += lo[i * l + k] * lo[j * l + k];
                    }
                }
            }
            assert!(max_abs_diff(&rec, &a) < 1e-9, "l={l}");
        }
    }

    #[test]
    fn tril_inverse_identity() {
        let mut rng = Pcg::seeded(2);
        for l in [2, 4, 7] {
            let a = random_spd(&mut rng, l);
            let mut lo = vec![0.0; l * l];
            cholesky(&a, l, 0.0, &mut lo);
            let mut li = vec![0.0; l * l];
            tril_inverse(&lo, l, &mut li);
            let mut eye = vec![0.0; l * l];
            matmul(&lo, &li, l, &mut eye);
            for i in 0..l {
                for j in 0..l {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((eye[i * l + j] - want).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn spd_inverse_identity() {
        let mut rng = Pcg::seeded(3);
        for l in [2, 3, 6] {
            let a = random_spd(&mut rng, l);
            let mut scratch = vec![0.0; 2 * l * l];
            let mut inv = vec![0.0; l * l];
            spd_inverse(&a, l, &mut scratch, &mut inv);
            let mut eye = vec![0.0; l * l];
            matmul(&a, &inv, l, &mut eye);
            for i in 0..l {
                for j in 0..l {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (eye[i * l + j] - want).abs() < 1e-6,
                        "l={l} i={i} j={j} got={}",
                        eye[i * l + j]
                    );
                }
            }
        }
    }

    #[test]
    fn pinv_matches_inverse_when_nonsingular() {
        let mut rng = Pcg::seeded(4);
        for l in [1, 2, 3, 5, 8] {
            let a = random_spd(&mut rng, l);
            let mut sc = PinvScratch::new(l);
            let mut p = vec![0.0; l * l];
            pinv(&a, l, &mut sc, &mut p);
            let mut scratch = vec![0.0; 2 * l * l];
            let mut inv = vec![0.0; l * l];
            spd_inverse(&a, l, &mut scratch, &mut inv);
            assert!(max_abs_diff(&p, &inv) < 1e-3, "l={l}");
        }
    }

    #[test]
    fn pinv_rank_deficient_penrose() {
        // all-ones correlation (duplicated variables): pinv = J / l².
        for l in [2, 3, 4] {
            let a = vec![1.0; l * l];
            let mut sc = PinvScratch::new(l);
            let mut p = vec![0.0; l * l];
            pinv(&a, l, &mut sc, &mut p);
            let want = 1.0 / (l * l) as f64;
            for v in &p {
                assert!((v - want).abs() < 1e-3, "l={l} got={v} want={want}");
            }
        }
    }

    #[test]
    fn pinv_fast_matches_pinv_well_conditioned() {
        let mut rng = Pcg::seeded(6);
        for l in [1usize, 2, 3, 4, 6, 8] {
            for _ in 0..20 {
                let a = random_spd(&mut rng, l);
                let mut sc1 = PinvScratch::new(l);
                let mut sc2 = PinvScratch::new(l);
                let mut slow = vec![0.0; l * l];
                let mut fast = vec![0.0; l * l];
                pinv(&a, l, &mut sc1, &mut slow);
                pinv_fast(&a, l, &mut sc2, &mut fast);
                let scale = slow.iter().fold(1.0f64, |m, &x| m.max(x.abs()));
                assert!(
                    max_abs_diff(&slow, &fast) < 1e-3 * scale,
                    "l={l} diff={}",
                    max_abs_diff(&slow, &fast)
                );
            }
        }
    }

    #[test]
    fn pinv_fast_rank_deficient_falls_back() {
        for l in [2usize, 3, 4] {
            let a = vec![1.0; l * l]; // all-ones: rank 1
            let mut sc = PinvScratch::new(l);
            let mut fast = vec![0.0; l * l];
            pinv_fast(&a, l, &mut sc, &mut fast);
            let want = 1.0 / (l * l) as f64;
            for v in &fast {
                assert!((v - want).abs() < 1e-3, "l={l} got={v}");
            }
        }
    }

    #[test]
    fn pinv_1x1_fast_path() {
        let mut sc = PinvScratch::new(1);
        let mut p = vec![0.0];
        pinv(&[2.0], 1, &mut sc, &mut p);
        assert!((p[0] - 0.5).abs() < 1e-6);
        pinv(&[0.0], 1, &mut sc, &mut p);
        assert_eq!(p[0], 0.0);
    }

    /// Dense Gauss-Jordan inverse with partial pivoting — an independent
    /// reference implementation (no Cholesky machinery shared with the
    /// code under test). Returns None when a pivot degenerates.
    fn gauss_jordan_inverse(a: &[f64], l: usize) -> Option<Vec<f64>> {
        let mut aug = vec![0.0f64; l * 2 * l];
        for i in 0..l {
            for j in 0..l {
                aug[i * 2 * l + j] = a[i * l + j];
            }
            aug[i * 2 * l + l + i] = 1.0;
        }
        for col in 0..l {
            // partial pivot
            let mut piv = col;
            for r in (col + 1)..l {
                if aug[r * 2 * l + col].abs() > aug[piv * 2 * l + col].abs() {
                    piv = r;
                }
            }
            if aug[piv * 2 * l + col].abs() < 1e-12 {
                return None;
            }
            if piv != col {
                for c in 0..2 * l {
                    aug.swap(col * 2 * l + c, piv * 2 * l + c);
                }
            }
            let inv_p = 1.0 / aug[col * 2 * l + col];
            for c in 0..2 * l {
                aug[col * 2 * l + c] *= inv_p;
            }
            for r in 0..l {
                if r == col {
                    continue;
                }
                let f = aug[r * 2 * l + col];
                if f == 0.0 {
                    continue;
                }
                for c in 0..2 * l {
                    aug[r * 2 * l + c] -= f * aug[col * 2 * l + c];
                }
            }
        }
        let mut out = vec![0.0f64; l * l];
        for i in 0..l {
            for j in 0..l {
                out[i * l + j] = aug[i * 2 * l + l + j];
            }
        }
        Some(out)
    }

    /// max |(A·X − I)_{ij}|
    fn identity_residual(a: &[f64], x: &[f64], l: usize) -> f64 {
        let mut worst = 0.0f64;
        for i in 0..l {
            for j in 0..l {
                let mut acc = 0.0;
                for k in 0..l {
                    acc += a[i * l + k] * x[k * l + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((acc - want).abs());
            }
        }
        worst
    }

    /// Property sweep for `pinv_fast` across l = 1..=12 on random seeded
    /// SPD matrices: A·A⁻¹ ≈ I and agreement with an independent
    /// Gauss-Jordan reference inverse.
    #[test]
    fn pinv_fast_property_sweep_l1_to_12() {
        let mut rng = Pcg::seeded(31);
        for l in 1..=12usize {
            let mut sc = PinvScratch::new(l);
            for rep in 0..10 {
                let a = random_spd(&mut rng, l);
                let mut fast = vec![0.0; l * l];
                pinv_fast(&a, l, &mut sc, &mut fast);

                // tolerance: the 1×1 closed form carries the CHOL_EPS
                // jitter (error ≈ 1e-8/x²), so 1e-4 relative bounds every
                // path with margin
                let resid = identity_residual(&a, &fast, l);
                assert!(resid < 1e-4, "l={l} rep={rep}: |A·A⁻¹ − I| = {resid}");

                let gj = gauss_jordan_inverse(&a, l)
                    .unwrap_or_else(|| panic!("l={l} rep={rep}: SPD matrix must invert"));
                let scale = gj.iter().fold(1.0f64, |m, &x| m.max(x.abs()));
                let diff = max_abs_diff(&fast, &gj);
                assert!(
                    diff < 1e-4 * scale,
                    "l={l} rep={rep}: pinv_fast vs Gauss-Jordan diff = {diff} (scale {scale})"
                );
            }
        }
    }

    /// Near-singular case: A = B·Bᵀ with rank l−1 plus a whisper of
    /// jitter. The fast path must detect the degenerate pivot, fall back
    /// to Algorithm 7, stay finite, and satisfy the Penrose condition
    /// A·A⁺·A ≈ A.
    #[test]
    fn pinv_fast_near_singular_falls_back_finite_and_penrose() {
        let mut rng = Pcg::seeded(32);
        for l in 2..=8usize {
            // rank-deficient gram: B is l×(l−1)
            let r = l - 1;
            let b: Vec<f64> = (0..l * r).map(|_| rng.normal()).collect();
            let mut a = vec![0.0f64; l * l];
            for i in 0..l {
                for j in 0..l {
                    let mut s = if i == j { 1e-10 } else { 0.0 };
                    for k in 0..r {
                        s += b[i * r + k] * b[j * r + k];
                    }
                    a[i * l + j] = s;
                }
            }
            let mut sc = PinvScratch::new(l);
            let mut p = vec![0.0; l * l];
            pinv_fast(&a, l, &mut sc, &mut p);
            assert!(p.iter().all(|v| v.is_finite()), "l={l}: non-finite entries");

            // Penrose 1: A·A⁺·A ≈ A (relative to A's scale)
            let mut ap = vec![0.0f64; l * l];
            matmul(&a, &p, l, &mut ap);
            let mut apa = vec![0.0f64; l * l];
            matmul(&ap, &a, l, &mut apa);
            let scale = a.iter().fold(1e-12f64, |m, &x| m.max(x.abs()));
            let diff = max_abs_diff(&apa, &a);
            assert!(
                diff < 1e-3 * scale,
                "l={l}: |A·A⁺·A − A| = {diff} (scale {scale})"
            );
        }
    }
}
