//! Statistical substrate: normal quantiles, Fisher-z CI testing, small
//! dense linear algebra (the paper's Algorithm 7) and correlation
//! matrices — everything the PC engines need, implemented from scratch.

pub mod chol;
pub mod corr;
pub mod fisher;
pub mod normal;
pub mod pcorr;
