//! Statistical substrate: normal quantiles, Fisher-z CI testing, small
//! dense linear algebra (the paper's Algorithm 7), correlation
//! matrices, and the runtime-selectable CI-test kernels (`kernels/`)
//! — everything the PC engines need, implemented from scratch. The
//! precision contract (f32 vs f64, bitwise guarantees) lives in
//! `docs/NUMERICS.md`.

pub mod chol;
pub mod corr;
pub mod fisher;
pub mod kernels;
pub mod normal;
pub mod pcorr;
