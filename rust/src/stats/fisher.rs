//! Fisher's z-transform and the CI-test threshold τ (paper eq. 6-7).

use super::normal::phi_inv;

/// |½ ln((1+ρ)/(1−ρ))| with ρ clamped away from ±1, matching
/// `python/compile/kernels/linalg.py::fisher_z` exactly.
#[inline]
pub fn fisher_z(rho: f64) -> f64 {
    let r = rho.clamp(-0.999_999_9, 0.999_999_9);
    (0.5 * ((1.0 + r) / (1.0 - r)).ln()).abs()
}

/// τ = Φ⁻¹(1 − α/2) / sqrt(m − |S| − 3)   (paper eq. 7).
///
/// `m` = sample count, `l` = conditioning-set size, `alpha` = significance.
/// Returns +∞ when m − l − 3 ≤ 0: with too few samples the test cannot
/// reject the independence null at any z, matching pcalg's convention
/// (p-value 1 ⇒ independent ⇒ edge removed).
pub fn tau(m: usize, l: usize, alpha: f64) -> f64 {
    let dof = m as f64 - l as f64 - 3.0;
    if dof <= 0.0 {
        return f64::INFINITY;
    }
    phi_inv(1.0 - alpha / 2.0) / dof.sqrt()
}

/// The CI decision: independent ⟺ z ≤ τ.
#[inline]
pub fn independent(z: f64, tau: f64) -> bool {
    z <= tau
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fisher_z_zero_at_zero() {
        assert_eq!(fisher_z(0.0), 0.0);
    }

    #[test]
    fn fisher_z_symmetric_abs() {
        for r in [0.1, 0.5, 0.9, 0.99] {
            assert!((fisher_z(r) - fisher_z(-r)).abs() < 1e-12);
        }
    }

    #[test]
    fn fisher_z_is_atanh() {
        for r in [-0.9, -0.3, 0.0, 0.2, 0.7] {
            assert!((fisher_z(r) - (r as f64).atanh().abs()).abs() < 1e-9);
        }
    }

    #[test]
    fn fisher_z_finite_at_one() {
        assert!(fisher_z(1.0).is_finite());
        assert!(fisher_z(-1.0).is_finite());
    }

    #[test]
    fn tau_alpha001_m100() {
        // phi_inv(0.995) = 2.5758...; sqrt(100-0-3) = 9.849
        let t = tau(100, 0, 0.01);
        assert!((t - 2.575829304 / (97.0f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn tau_decreases_with_m() {
        assert!(tau(1000, 2, 0.01) < tau(100, 2, 0.01));
    }

    #[test]
    fn tau_increases_with_l() {
        assert!(tau(50, 10, 0.01) > tau(50, 1, 0.01));
    }

    #[test]
    fn tau_infinite_when_underpowered() {
        let t = tau(4, 1, 0.01);
        assert!(t.is_infinite());
        // underpowered test never removes an edge... except z==inf is
        // impossible since fisher_z is clamped finite.
        assert!(independent(fisher_z(1.0), t));
    }
}
