//! The `cupc shard` plan protocol: one skeleton job split across
//! worker processes.
//!
//! The coordinator computes the correlation matrix once, stores it in
//! the shared [`DiskStore`] directory under its content key, encodes a
//! [`ShardPlan`] (every parameter that can influence a bit of the
//! result), stores that under the plan's content key, and hands workers
//! nothing but `--store DIR --plan HEX --rank i`. Each worker — and the
//! coordinator itself, as rank 0 — rebuilds the identical [`Config`]
//! from the plan and drives
//! [`run_rounds_sharded`](crate::skeleton::schedule::run_rounds_sharded)
//! with a [`DiskExchange`] over the same directory. Because every rank
//! applies the identical merged removal stream in canonical order,
//! every rank finishes holding the bit-identical skeleton; the
//! coordinator then orients exactly like a single-process run.
//!
//! The plan payload is schema-versioned independently of the store's
//! header version: a worker from a different build refuses a plan it
//! cannot parse instead of silently diverging.

use crate::service::cache::{ContentHasher, Key};
use crate::service::store::DiskStore;
use crate::skeleton::family;
use crate::skeleton::schedule::run_rounds_sharded;
use crate::skeleton::{AdjMode, Config, OocConfig, OrientRule, SkeletonResult, Variant};
use anyhow::{bail, ensure, Context, Result};
use std::time::Duration;

use super::exchange::DiskExchange;

/// Plan payload schema — bump on any layout change.
pub const PLAN_VERSION: u8 = 1;

/// Everything a worker needs to reproduce the job bit-for-bit: problem
/// shape, the correlation matrix's content key, the full parameter set
/// of the skeleton phase, and the sharding topology.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardPlan {
    pub n: usize,
    pub m: usize,
    pub corr_key: Key,
    pub alpha: f64,
    pub max_level: Option<usize>,
    pub variant: Variant,
    pub orient: OrientRule,
    /// number of ranks (coordinator = rank 0)
    pub world: usize,
    /// native worker threads per rank
    pub threads: usize,
    pub beta: usize,
    pub gamma: usize,
    pub theta: usize,
    pub delta: usize,
    pub adjacency: AdjMode,
    pub window_runs: usize,
    pub window_slots: u64,
}

impl ShardPlan {
    /// Plan for `spec`-shaped parameters with the crate-default schedule
    /// knobs and out-of-core budgets.
    pub fn new(
        n: usize,
        m: usize,
        corr_key: Key,
        cfg: &Config,
        world: usize,
    ) -> ShardPlan {
        ShardPlan {
            n,
            m,
            corr_key,
            alpha: cfg.alpha,
            max_level: cfg.max_level,
            variant: cfg.variant,
            orient: cfg.orient,
            world,
            threads: cfg.threads,
            beta: cfg.beta,
            gamma: cfg.gamma,
            theta: cfg.theta,
            delta: cfg.delta,
            adjacency: cfg.ooc.adjacency,
            window_runs: cfg.ooc.window_runs,
            window_slots: cfg.ooc.window_slots,
        }
    }

    /// The worker-side [`Config`] — identical on every rank by
    /// construction.
    pub fn config(&self) -> Config {
        Config {
            alpha: self.alpha,
            max_level: self.max_level,
            variant: self.variant,
            orient: self.orient,
            beta: self.beta,
            gamma: self.gamma,
            theta: self.theta,
            delta: self.delta,
            ooc: OocConfig {
                adjacency: self.adjacency,
                window_runs: self.window_runs,
                window_slots: self.window_slots,
            },
            ..Config::default()
        }
        .with_threads(self.threads)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut b = vec![PLAN_VERSION];
        for v in [
            self.n as u64,
            self.m as u64,
            self.corr_key.0,
            self.corr_key.1,
            self.alpha.to_bits(),
            self.max_level.map(|l| l as u64 + 1).unwrap_or(0),
        ] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.push(crate::service::job::variant_tag(self.variant));
        b.push(crate::service::job::orient_tag(self.orient));
        b.extend_from_slice(&(self.world as u32).to_le_bytes());
        b.extend_from_slice(&(self.threads as u32).to_le_bytes());
        for v in [
            self.beta as u64,
            self.gamma as u64,
            self.theta as u64,
            self.delta as u64,
        ] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.push(match self.adjacency {
            AdjMode::Auto => 0,
            AdjMode::Dense => 1,
            AdjMode::Sparse => 2,
        });
        b.extend_from_slice(&(self.window_runs as u64).to_le_bytes());
        b.extend_from_slice(&self.window_slots.to_le_bytes());
        b
    }

    pub fn decode(b: &[u8]) -> Result<ShardPlan> {
        // 1 version + 6×8 + 2 tags + 2×4 + 4×8 + 1 mode + 2×8
        const WANT: usize = 1 + 48 + 2 + 8 + 32 + 1 + 16;
        ensure!(!b.is_empty(), "empty plan payload");
        ensure!(
            b[0] == PLAN_VERSION,
            "plan schema v{} but this build speaks v{PLAN_VERSION}",
            b[0]
        );
        ensure!(b.len() == WANT, "plan payload is {} bytes, want {WANT}", b.len());
        let u64_at = |at: usize| u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
        let u32_at = |at: usize| u32::from_le_bytes(b[at..at + 4].try_into().unwrap());
        let variant = crate::family::by_tag(b[49])
            .and_then(|f| f.id.variant())
            .with_context(|| format!("tag {} is not a shardable PC variant", b[49]))?;
        let orient = match b[50] {
            0 => OrientRule::Standard,
            1 => OrientRule::Majority,
            t => bail!("unknown orient tag {t}"),
        };
        let adjacency = match b[91] {
            0 => AdjMode::Auto,
            1 => AdjMode::Dense,
            2 => AdjMode::Sparse,
            t => bail!("unknown adjacency mode tag {t}"),
        };
        let max_level = match u64_at(41) {
            0 => None,
            l => Some((l - 1) as usize),
        };
        let plan = ShardPlan {
            n: u64_at(1) as usize,
            m: u64_at(9) as usize,
            corr_key: (u64_at(17), u64_at(25)),
            alpha: f64::from_bits(u64_at(33)),
            max_level,
            variant,
            orient,
            world: u32_at(51) as usize,
            threads: u32_at(55) as usize,
            beta: u64_at(59) as usize,
            gamma: u64_at(67) as usize,
            theta: u64_at(75) as usize,
            delta: u64_at(83) as usize,
            adjacency,
            window_runs: u64_at(92) as usize,
            window_slots: u64_at(100),
        };
        ensure!(plan.world >= 1, "plan world must be >= 1");
        Ok(plan)
    }

    /// Content key of this plan — also the job identity the exchange
    /// namespaces its blobs under.
    pub fn key(&self) -> Key {
        let mut h = ContentHasher::new();
        h.write(b"cupc-shard-plan/v1");
        h.write(&self.encode());
        h.finish()
    }
}

/// The 32-hex-digit CLI spelling of a plan key.
pub fn format_plan_key(key: Key) -> String {
    format!("{:016x}{:016x}", key.0, key.1)
}

pub fn parse_plan_key(s: &str) -> Result<Key> {
    ensure!(
        s.len() == 32 && s.bytes().all(|b| b.is_ascii_hexdigit()),
        "plan key must be 32 hex digits, got {s:?}"
    );
    Ok((
        u64::from_str_radix(&s[..16], 16).unwrap(),
        u64::from_str_radix(&s[16..], 16).unwrap(),
    ))
}

/// Coordinator side: persist the plan and verify it reads back (puts
/// are best-effort by store contract, but an unpublished plan would
/// strand every worker, so fail loudly here). Returns the plan key.
pub fn publish_plan(store: &DiskStore, plan: &ShardPlan) -> Result<Key> {
    let key = plan.key();
    store.put_plan(key, &plan.encode());
    ensure!(
        store.get_plan(key).is_some(),
        "could not persist shard plan in the store directory"
    );
    Ok(key)
}

/// Worker side (and the coordinator's own rank 0): load the plan and
/// corr matrix from `store`, run the sharded skeleton as `rank`, and
/// return it with the decoded plan. `timing` overrides the exchange's
/// (poll, timeout) — tests use tight values.
pub fn run_skeleton_sharded(
    store: DiskStore,
    plan_key: Key,
    rank: usize,
    timing: Option<(Duration, Duration)>,
) -> Result<(ShardPlan, SkeletonResult)> {
    let raw = store
        .get_plan(plan_key)
        .with_context(|| format!("plan {} not in store", format_plan_key(plan_key)))?;
    let plan = ShardPlan::decode(&raw)?;
    ensure!(
        rank < plan.world,
        "rank {rank} out of range for world {}",
        plan.world
    );
    let corr = store
        .get_corr(plan.corr_key, plan.n * plan.n)
        .context("correlation matrix not in store (did the coordinator publish it?)")?;
    let cfg = plan.config();
    let fam = family::of(cfg.variant);
    let make = fam
        .schedule
        .with_context(|| format!("variant {} is not shardable (no batched schedule)", fam.name))?;
    let mut sched = make(&cfg);
    let mut exch = DiskExchange::new(store, plan_key, rank, plan.world);
    if let Some((poll, timeout)) = timing {
        exch = exch.with_timing(poll, timeout);
    }
    let skel = run_rounds_sharded(&corr, plan.n, plan.m, &cfg, sched.as_mut(), &mut exch)?;
    Ok((plan, skel))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn toy_plan() -> ShardPlan {
        ShardPlan {
            n: 100,
            m: 400,
            corr_key: (0xdead, 0xbeef),
            alpha: 0.013,
            max_level: Some(3),
            variant: Variant::CupcS,
            orient: OrientRule::Majority,
            world: 2,
            threads: 4,
            beta: 2,
            gamma: 32,
            theta: 64,
            delta: 2,
            adjacency: AdjMode::Sparse,
            window_runs: 1 << 10,
            window_slots: 1 << 14,
        }
    }

    #[test]
    fn plan_codec_roundtrips_every_field() {
        let mut p = toy_plan();
        assert_eq!(ShardPlan::decode(&p.encode()).unwrap(), p);
        p.max_level = None;
        p.adjacency = AdjMode::Auto;
        p.variant = Variant::Baseline2;
        p.orient = OrientRule::Standard;
        let q = ShardPlan::decode(&p.encode()).unwrap();
        assert_eq!(q, p);
        assert_eq!(q.max_level, None);
        // max_level 0 and None must not collide
        p.max_level = Some(0);
        assert_eq!(ShardPlan::decode(&p.encode()).unwrap().max_level, Some(0));
    }

    #[test]
    fn plan_codec_rejects_alien_payloads() {
        let b = toy_plan().encode();
        assert!(ShardPlan::decode(&[]).is_err());
        assert!(ShardPlan::decode(&b[..b.len() - 1]).is_err(), "truncated");
        let mut wrong_ver = b.clone();
        wrong_ver[0] = PLAN_VERSION + 1;
        let err = ShardPlan::decode(&wrong_ver).unwrap_err();
        assert!(format!("{err:#}").contains("schema"), "{err:#}");
        let mut bad_variant = b.clone();
        bad_variant[49] = 200;
        assert!(ShardPlan::decode(&bad_variant).is_err());
        let mut bad_mode = b;
        bad_mode[91] = 9;
        assert!(ShardPlan::decode(&bad_mode).is_err());
    }

    #[test]
    fn plan_key_hex_roundtrips() {
        let key = toy_plan().key();
        let hex = format_plan_key(key);
        assert_eq!(hex.len(), 32);
        assert_eq!(parse_plan_key(&hex).unwrap(), key);
        assert!(parse_plan_key("xyz").is_err());
        assert!(parse_plan_key(&hex[..31]).is_err());
        // key covers the payload: any field change re-keys
        let mut other = toy_plan();
        other.alpha = 0.05;
        assert_ne!(other.key(), key);
    }

    #[test]
    fn config_rebuild_matches_the_source_config() {
        let cfg = Config {
            alpha: 0.02,
            max_level: Some(2),
            variant: Variant::CupcE,
            orient: OrientRule::Majority,
            ..Config::default()
        }
        .with_threads(3);
        let plan = ShardPlan::new(50, 200, (1, 2), &cfg, 4);
        let got = plan.config();
        assert_eq!(got.alpha, cfg.alpha);
        assert_eq!(got.max_level, cfg.max_level);
        assert_eq!(got.variant, cfg.variant);
        assert_eq!(got.orient, cfg.orient);
        assert_eq!(got.threads, cfg.threads);
        assert_eq!(got.gamma, cfg.gamma);
        assert_eq!(got.ooc, cfg.ooc);
    }

    /// End-to-end over one store directory: two in-process ranks run the
    /// plan and both reproduce the single-process skeleton bit-for-bit.
    /// (The full grid × window-budget sweep lives in
    /// `tests/oocore_conformance.rs`; this is the module smoke.)
    #[test]
    fn two_ranks_reproduce_the_single_process_skeleton() {
        use crate::sim::{dag::WeightedDag, sem};
        use crate::stats::corr::correlation_matrix;
        use crate::util::rng::Pcg;

        let dag = WeightedDag::random_er(18, 0.2, &mut Pcg::seeded(41));
        let data = sem::sample(&dag, 250, &mut Pcg::seeded(42));
        let corr = correlation_matrix(&data, 1);
        let cfg = Config {
            variant: Variant::CupcS,
            ooc: OocConfig {
                adjacency: AdjMode::Auto,
                window_runs: 4, // tiny budgets force real multi-chunk rounds
                window_slots: 64,
                ..Default::default()
            },
            ..Config::default()
        };
        let single = crate::skeleton::run(&corr, data.n, data.m, &cfg).unwrap();

        let dir: PathBuf = std::env::temp_dir().join(format!(
            "cupc_shard_{}_smoke",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let corr_key: Key = (7, 9);
        let plan = ShardPlan::new(data.n, data.m, corr_key, &cfg, 2);
        {
            let store = DiskStore::open(&dir, u64::MAX).unwrap();
            store.put_corr(corr_key, &corr);
            publish_plan(&store, &plan).unwrap();
        }
        let timing = Some((Duration::from_millis(1), Duration::from_secs(30)));
        let results = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let dir = &dir;
                    let key = plan.key();
                    scope.spawn(move || {
                        let store = DiskStore::open(dir, u64::MAX).unwrap();
                        run_skeleton_sharded(store, key, rank, timing).unwrap().1
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        for (rank, skel) in results.iter().enumerate() {
            assert_eq!(
                skel.graph.snapshot(),
                single.graph.snapshot(),
                "rank {rank} skeleton"
            );
            assert_eq!(
                skel.sepsets.sorted_entries(),
                single.sepsets.sorted_entries(),
                "rank {rank} sepsets"
            );
            let stats = |r: &SkeletonResult| -> Vec<(usize, u64, usize, usize)> {
                r.levels
                    .iter()
                    .map(|s| (s.level, s.tests, s.removed, s.edges_after))
                    .collect()
            };
            assert_eq!(stats(skel), stats(&single), "rank {rank} per-level stats");
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
