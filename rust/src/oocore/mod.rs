//! Out-of-core skeleton subsystem: everything that lets one skeleton
//! job scale past RAM (ROADMAP item 3, the gene-network regime the
//! multi-core fast-PC and ParallelPC lines target).
//!
//! Three coordinated axes, all behind the existing
//! [`RoundSchedule`](crate::skeleton::schedule::RoundSchedule) driver so
//! every schedule family runs unchanged:
//!
//! * [`sparse`] — `SparseAdj`, a CSR adjacency with atomic tombstones
//!   selected automatically past a density/size threshold: memory
//!   O(edges) instead of O(n²), with bit-identical observable behavior
//!   to the dense matrix (gated by property tests and
//!   `tests/oocore_conformance.rs`).
//! * [`stream`] — `WindowPump`, the bounded-memory round streamer: a
//!   round's combination windows are fed to the pipeline executor
//!   chunk-by-chunk in canonical order, so the run buffer is O(live
//!   chunk) instead of O(level). Chunk boundaries never change results
//!   (evaluation is pure; candidates apply at round end in chunk order).
//! * [`exchange`] / [`shard`] — cross-process sharding: `cupc shard`
//!   splits one job's chunk stream round-robin across worker processes
//!   that exchange per-round removal sets through rename-atomic
//!   [`DiskStore`](crate::service::store::DiskStore) entries, and the
//!   canonical-order merge reproduces the single-process skeleton
//!   bit-for-bit.

pub mod exchange;
pub mod shard;
pub mod sparse;
pub mod stream;
