//! Sparse adjacency for the out-of-core skeleton path.
//!
//! [`SparseAdj`] stores per-row **sorted neighbor lists** frozen at
//! construction (CSR layout) plus a parallel array of atomic
//! alive-flags, so edge removal is the same lock-free monotone 1 → 0
//! transition the dense [`AdjMatrix`] provides — but memory is
//! O(edges), not O(n²), and per-level compaction
//! ([`SparseAdj::compact`]) filters the live entries directly into a
//! [`CompactAdj`] without ever materializing the O(n²) snapshot the
//! dense route copies each level.
//!
//! The skeleton never *adds* edges after level 0, so freezing the
//! neighbor universe at construction (from the level-0 survivor list)
//! loses nothing: every representable graph state is a subset of the
//! construction edges, exactly like the dense matrix starting complete.
//!
//! [`Adj`] is the dispatch seam the level-loop driver holds: every PC
//! schedule family reads adjacency through it (`has_edge` is the only
//! read on the hot path), so they run on either representation
//! unchanged. Parity with [`AdjMatrix`] — identical neighbor iteration
//! order, degrees, snapshot contents, and `should_continue` decisions
//! under arbitrary removal sequences — is gated by the property tests
//! below.

use crate::graph::adj::{AdjMatrix, EdgeRemove};
use crate::graph::compact::CompactAdj;
use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};

/// Smallest n where [`AdjMode::Auto`](crate::skeleton::AdjMode) will
/// consider the sparse representation: below this the dense matrix is a
/// few hundred KB and always wins. Past it, the driver goes sparse when
/// the level-0 survivors are ≤ 25% of all pairs (the CSR slot + flag
/// overhead is ~4× a dense bit, so 25% density is the break-even).
pub const SPARSE_MIN_N: usize = 1024;

/// CSR adjacency with atomic tombstones.
pub struct SparseAdj {
    n: usize,
    /// concatenated sorted neighbor lists (frozen)
    items: Vec<u32>,
    /// row offsets into `items`, len n+1 (frozen)
    offsets: Vec<u32>,
    /// liveness flag per `items` slot (1 = edge present)
    alive: Vec<AtomicU8>,
    /// live degree per row
    degs: Vec<AtomicU32>,
    /// live undirected edge count
    edges: AtomicUsize,
}

impl SparseAdj {
    /// Build from an edge list of (i, j) pairs with i < j, sorted
    /// row-major ascending (the canonical level-0 survivor order).
    /// Every row comes out sorted: for row r the pairs (k, r) with
    /// k < r precede the pairs (r, j) with j > r in the input, and each
    /// group is itself ascending.
    pub fn from_edges(n: usize, pairs: &[(u32, u32)]) -> Self {
        debug_assert!(pairs.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        let mut counts = vec![0u32; n];
        for &(i, j) in pairs {
            debug_assert!((i as usize) < n && i < j && (j as usize) < n);
            counts[i as usize] += 1;
            counts[j as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &c in &counts {
            acc += c;
            offsets.push(acc);
        }
        let mut items = vec![0u32; acc as usize];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for &(i, j) in pairs {
            items[cursor[i as usize] as usize] = j;
            cursor[i as usize] += 1;
            items[cursor[j as usize] as usize] = i;
            cursor[j as usize] += 1;
        }
        let alive = (0..items.len()).map(|_| AtomicU8::new(1)).collect();
        let degs = counts.into_iter().map(AtomicU32::new).collect();
        SparseAdj {
            n,
            items,
            offsets,
            alive,
            degs,
            edges: AtomicUsize::new(pairs.len()),
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Slot of j in row i's frozen list, if present there at all.
    #[inline]
    fn slot(&self, i: usize, j: usize) -> Option<usize> {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        self.items[lo..hi]
            .binary_search(&(j as u32))
            .ok()
            .map(|p| lo + p)
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        match self.slot(i, j) {
            Some(s) => self.alive[s].load(Ordering::Relaxed) != 0,
            None => false,
        }
    }

    /// Remove (i,j) symmetrically. The slot in the lower-index row is
    /// authoritative, so concurrent removers of one edge see exactly one
    /// winner (mirroring the dense matrix's swap).
    pub fn remove_edge(&self, i: usize, j: usize) -> bool {
        if i == j {
            return false;
        }
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        let Some(sa) = self.slot(a, b) else {
            return false;
        };
        let won = self.alive[sa].swap(0, Ordering::Relaxed) != 0;
        if let Some(sb) = self.slot(b, a) {
            self.alive[sb].store(0, Ordering::Relaxed);
        }
        if won {
            self.degs[a].fetch_sub(1, Ordering::Relaxed);
            self.degs[b].fetch_sub(1, Ordering::Relaxed);
            self.edges.fetch_sub(1, Ordering::Relaxed);
        }
        won
    }

    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.degs[i].load(Ordering::Relaxed) as usize
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    pub fn n_edges(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }

    /// Live neighbors of i, ascending (parity with
    /// [`AdjMatrix::neighbors`]).
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        (lo..hi)
            .filter(|&s| self.alive[s].load(Ordering::Relaxed) != 0)
            .map(|s| self.items[s] as usize)
            .collect()
    }

    /// Compact the live entries straight into CSR form — the per-level
    /// `G → G'` freeze without the dense O(n²) snapshot.
    pub fn compact(&self) -> CompactAdj {
        let mut items = Vec::with_capacity(2 * self.n_edges());
        let mut offsets = Vec::with_capacity(self.n + 1);
        offsets.push(0u32);
        for i in 0..self.n {
            let lo = self.offsets[i] as usize;
            let hi = self.offsets[i + 1] as usize;
            for s in lo..hi {
                if self.alive[s].load(Ordering::Relaxed) != 0 {
                    items.push(self.items[s]);
                }
            }
            offsets.push(items.len() as u32);
        }
        CompactAdj::from_parts(self.n, items, offsets)
    }

    /// Dense O(n²) snapshot, bit-compatible with
    /// [`AdjMatrix::snapshot`] (tests / small-n interop only).
    pub fn snapshot(&self) -> Vec<u8> {
        let mut snap = vec![0u8; self.n * self.n];
        for i in 0..self.n {
            for j in self.neighbors(i) {
                snap[i * self.n + j] = 1;
            }
        }
        snap
    }

    /// Materialize into a dense [`AdjMatrix`] (the orientation phase is
    /// dense; at sparse-path scale the result graph is small).
    pub fn to_dense(&self) -> AdjMatrix {
        AdjMatrix::from_dense(&self.snapshot(), self.n)
    }
}

impl EdgeRemove for SparseAdj {
    fn remove_edge(&self, i: usize, j: usize) -> bool {
        SparseAdj::remove_edge(self, i, j)
    }
}

impl std::fmt::Debug for SparseAdj {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SparseAdj(n={}, edges={})", self.n, self.n_edges())
    }
}

/// The adjacency representation seam behind the level loop: dense for
/// small/dense problems (today's exact path), sparse past the
/// out-of-core threshold. Schedules only ever call [`Adj::has_edge`];
/// the driver uses the rest.
pub enum Adj {
    Dense(AdjMatrix),
    Sparse(SparseAdj),
}

impl Adj {
    #[inline]
    pub fn n(&self) -> usize {
        match self {
            Adj::Dense(g) => g.n(),
            Adj::Sparse(g) => g.n(),
        }
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        match self {
            Adj::Dense(g) => g.has_edge(i, j),
            Adj::Sparse(g) => g.has_edge(i, j),
        }
    }

    pub fn remove_edge(&self, i: usize, j: usize) -> bool {
        match self {
            Adj::Dense(g) => g.remove_edge(i, j),
            Adj::Sparse(g) => g.remove_edge(i, j),
        }
    }

    pub fn max_degree(&self) -> usize {
        match self {
            Adj::Dense(g) => g.max_degree(),
            Adj::Sparse(g) => g.max_degree(),
        }
    }

    pub fn n_edges(&self) -> usize {
        match self {
            Adj::Dense(g) => g.n_edges(),
            Adj::Sparse(g) => g.n_edges(),
        }
    }

    /// The per-level `G → G'` freeze.
    pub fn compact(&self) -> CompactAdj {
        match self {
            Adj::Dense(g) => CompactAdj::from_snapshot(&g.snapshot(), g.n()),
            Adj::Sparse(g) => g.compact(),
        }
    }

    /// Stable spelling for the stats sidecar (CI greps these).
    pub fn label(&self) -> &'static str {
        match self {
            Adj::Dense(_) => "dense",
            Adj::Sparse(_) => "sparse",
        }
    }

    /// Finish the run: orientation (and the public `SkeletonResult`)
    /// stay dense.
    pub fn into_dense(self) -> AdjMatrix {
        match self {
            Adj::Dense(g) => g,
            Adj::Sparse(g) => g.to_dense(),
        }
    }
}

impl EdgeRemove for Adj {
    fn remove_edge(&self, i: usize, j: usize) -> bool {
        Adj::remove_edge(self, i, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random i<j pairs over n nodes, sorted row-major (the canonical
    /// survivor order the driver feeds [`SparseAdj::from_edges`]).
    fn random_pairs(n: usize, p: f64, rng: &mut Pcg) -> Vec<(u32, u32)> {
        let mut pairs = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if rng.bernoulli(p) {
                    pairs.push((i, j));
                }
            }
        }
        pairs
    }

    fn dense_from_pairs(n: usize, pairs: &[(u32, u32)]) -> AdjMatrix {
        let g = AdjMatrix::empty(n);
        for &(i, j) in pairs {
            g.add_edge(i as usize, j as usize);
        }
        g
    }

    fn assert_parity(d: &AdjMatrix, s: &SparseAdj, ctx: &str) {
        assert_eq!(d.n_edges(), s.n_edges(), "{ctx}: n_edges");
        assert_eq!(d.max_degree(), s.max_degree(), "{ctx}: max_degree");
        assert_eq!(d.snapshot(), s.snapshot(), "{ctx}: snapshot");
        let dc = CompactAdj::from_snapshot(&d.snapshot(), d.n());
        let sc = s.compact();
        for i in 0..d.n() {
            assert_eq!(d.degree(i), s.degree(i), "{ctx}: degree({i})");
            assert_eq!(d.neighbors(i), s.neighbors(i), "{ctx}: neighbors({i})");
            assert_eq!(dc.row(i), sc.row(i), "{ctx}: compact row {i}");
        }
        for j in 0..d.n() {
            for i in 0..d.n() {
                assert_eq!(d.has_edge(i, j), s.has_edge(i, j), "{ctx}: has({i},{j})");
            }
        }
    }

    /// Satellite: randomized removal sequences must keep the two
    /// representations indistinguishable — neighbor iteration order,
    /// degrees, snapshot contents, and the level loop's
    /// `should_continue` decision at every step.
    #[test]
    fn random_removal_sequences_preserve_parity() {
        use crate::skeleton::{should_continue_any, Config};
        let cfg = Config::default();
        for seed in 0..6u64 {
            let mut rng = Pcg::seeded(4000 + seed);
            let n = 12 + (seed as usize % 3) * 7;
            let pairs = random_pairs(n, 0.35, &mut rng);
            let dense = dense_from_pairs(n, &pairs);
            let sparse = SparseAdj::from_edges(n, &pairs);
            assert_parity(&dense, &sparse, "initial");
            // remove a random half, in random order, including repeats
            // and never-present edges
            for step in 0..pairs.len() {
                let (i, j) = if rng.bernoulli(0.8) && !pairs.is_empty() {
                    let p = pairs[rng.below(pairs.len() as u64) as usize];
                    (p.0 as usize, p.1 as usize)
                } else {
                    (
                        rng.below(n as u64) as usize,
                        rng.below(n as u64) as usize,
                    )
                };
                if i == j {
                    continue;
                }
                let dw = dense.remove_edge(i, j);
                let sw = sparse.remove_edge(i, j);
                assert_eq!(dw, sw, "winner flag at step {step} ({i},{j})");
                for l in 0..4usize {
                    assert_eq!(
                        should_continue_any(dense.max_degree(), l, &cfg),
                        should_continue_any(sparse.max_degree(), l, &cfg),
                        "should_continue at step {step} level {l}"
                    );
                }
            }
            assert_parity(&dense, &sparse, "final");
        }
    }

    #[test]
    fn concurrent_removal_exactly_one_winner() {
        let pairs = vec![(0u32, 1u32), (0, 2), (1, 2), (2, 3)];
        let g = std::sync::Arc::new(SparseAdj::from_edges(4, &pairs));
        let wins = std::sync::Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = g.clone();
                let wins = wins.clone();
                s.spawn(move || {
                    if g.remove_edge(2, 1) {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(wins.load(Ordering::Relaxed), 1);
        assert_eq!(g.n_edges(), 3);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.degree(2), 2);
    }

    #[test]
    fn removing_absent_or_self_edges_is_inert() {
        let g = SparseAdj::from_edges(4, &[(0, 1)]);
        assert!(!g.remove_edge(2, 3), "never-present edge");
        assert!(!g.remove_edge(1, 1), "self loop");
        assert!(g.remove_edge(1, 0));
        assert!(!g.remove_edge(0, 1), "second removal loses");
        assert_eq!(g.n_edges(), 0);
    }

    #[test]
    fn compact_is_a_frozen_copy() {
        let g = SparseAdj::from_edges(4, &[(0, 1), (0, 2), (1, 3)]);
        let c = g.compact();
        g.remove_edge(0, 2);
        assert_eq!(c.row(0), &[1, 2], "compaction must not see later removals");
        assert_eq!(g.compact().row(0), &[1]);
    }

    #[test]
    fn adj_enum_dispatches_and_labels() {
        let pairs = vec![(0u32, 1u32), (1, 2)];
        let d = Adj::Dense(dense_from_pairs(3, &pairs));
        let s = Adj::Sparse(SparseAdj::from_edges(3, &pairs));
        assert_eq!(d.label(), "dense");
        assert_eq!(s.label(), "sparse");
        for g in [&d, &s] {
            assert_eq!(g.n(), 3);
            assert_eq!(g.n_edges(), 2);
            assert_eq!(g.max_degree(), 2);
            assert!(g.has_edge(1, 0) && !g.has_edge(0, 2));
            assert_eq!(g.compact().row(1), &[0, 2]);
        }
        assert!(s.remove_edge(0, 1));
        assert_eq!(s.into_dense().snapshot(), {
            let only = dense_from_pairs(3, &[(1, 2)]);
            only.snapshot()
        });
    }
}
