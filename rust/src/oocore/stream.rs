//! Bounded-memory round streaming: the [`WindowPump`] buffers a round's
//! combination windows ([`Run`]s) up to a budget and hands them to a
//! sink chunk-by-chunk, in canonical order.
//!
//! The pre-out-of-core driver materialized every live window of a round
//! into one `Vec<Run>` before sharding it — O(level) memory on wide
//! levels. The pump caps that buffer at `window_runs` entries /
//! `window_slots` CI-test slots: the schedule's emit order is chopped
//! into consecutive chunks, each evaluated (and, under `cupc shard`,
//! distributed) independently. Because CI evaluation is pure per slot
//! and candidates are applied at round end in chunk order, the chunk
//! boundaries never change results — only memory (gated by
//! `tests/oocore_conformance.rs::window_budgets_are_pure_memory_knobs`).
//!
//! A single run wider than `window_slots` is split mid-range (same
//! arithmetic as [`split_runs`](crate::skeleton::pipeline::split_runs)),
//! so no chunk ever exceeds the slot budget.

use crate::skeleton::pipeline::Run;
use anyhow::Result;

/// Canonical-order chunker for one round's run stream. Chunks are
/// numbered from 0 in emission order — the sequence number is the
/// ownership key for cross-process distribution (`seq % world == rank`).
pub struct WindowPump {
    max_runs: usize,
    max_slots: u64,
    buf: Vec<Run>,
    slots: u64,
    emitted: u32,
    peak_bytes: u64,
}

impl WindowPump {
    pub fn new(window_runs: usize, window_slots: u64) -> Self {
        WindowPump {
            max_runs: window_runs.max(1),
            max_slots: window_slots.max(1),
            buf: Vec::new(),
            slots: 0,
            emitted: 0,
            peak_bytes: 0,
        }
    }

    /// Feed one window; completed chunks flow to `sink(seq, runs)` in
    /// order. Splits `run` mid-range if it exceeds the slot budget.
    pub fn offer(
        &mut self,
        run: Run,
        mut sink: impl FnMut(u32, Vec<Run>) -> Result<()>,
    ) -> Result<()> {
        let mut rest = run;
        while rest.count > 0 {
            let take = rest.count.min(self.max_slots);
            let piece = Run { task: rest.task, t0: rest.t0, count: take };
            rest.t0 += take;
            rest.count -= take;
            if !self.buf.is_empty()
                && (self.buf.len() >= self.max_runs || self.slots + take > self.max_slots)
            {
                self.flush(&mut sink)?;
            }
            self.buf.push(piece);
            self.slots += take;
            let bytes = (self.buf.len() * std::mem::size_of::<Run>()) as u64;
            self.peak_bytes = self.peak_bytes.max(bytes);
        }
        Ok(())
    }

    /// Flush the final partial chunk of the round (if any).
    pub fn finish(&mut self, mut sink: impl FnMut(u32, Vec<Run>) -> Result<()>) -> Result<()> {
        if !self.buf.is_empty() {
            self.flush(&mut sink)?;
        }
        Ok(())
    }

    fn flush(&mut self, sink: &mut impl FnMut(u32, Vec<Run>) -> Result<()>) -> Result<()> {
        let chunk = std::mem::take(&mut self.buf);
        self.slots = 0;
        let seq = self.emitted;
        self.emitted += 1;
        sink(seq, chunk)
    }

    /// Chunks handed to the sink so far (== the round's chunk count
    /// after [`WindowPump::finish`]). Identical on every rank, because
    /// the emit order and the budgets are.
    pub fn chunks_emitted(&self) -> u32 {
        self.emitted
    }

    /// Peak bytes the run buffer held — the job-level
    /// `peak_window_bytes` stat aggregates the max over all rounds.
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(pump: &mut WindowPump, runs: &[Run]) -> Vec<(u32, Vec<Run>)> {
        let mut chunks = Vec::new();
        for &r in runs {
            pump.offer(r, |seq, c| {
                chunks.push((seq, c));
                Ok(())
            })
            .unwrap();
        }
        pump.finish(|seq, c| {
            chunks.push((seq, c));
            Ok(())
        })
        .unwrap();
        chunks
    }

    fn slot_list(chunks: &[(u32, Vec<Run>)]) -> Vec<(usize, u64)> {
        let mut v = Vec::new();
        for (_, chunk) in chunks {
            for r in chunk {
                for t in r.t0..r.t0 + r.count {
                    v.push((r.task, t));
                }
            }
        }
        v
    }

    #[test]
    fn chunks_partition_the_stream_in_order() {
        let runs = vec![
            Run { task: 0, t0: 0, count: 10 },
            Run { task: 1, t0: 5, count: 3 },
            Run { task: 2, t0: 0, count: 9 },
        ];
        let want: Vec<(usize, u64)> = slot_list(&[(0, runs.clone())]);
        for (max_runs, max_slots) in [(1usize, 4u64), (2, 7), (100, 1), (100, 1000)] {
            let mut pump = WindowPump::new(max_runs, max_slots);
            let chunks = collect(&mut pump, &runs);
            assert_eq!(slot_list(&chunks), want, "runs={max_runs} slots={max_slots}");
            let seqs: Vec<u32> = chunks.iter().map(|(s, _)| *s).collect();
            let expect: Vec<u32> = (0..chunks.len() as u32).collect();
            assert_eq!(seqs, expect, "chunk seqs are dense and ordered");
            assert_eq!(pump.chunks_emitted() as usize, chunks.len());
            for (_, c) in &chunks {
                assert!(c.len() <= max_runs);
                assert!(c.iter().map(|r| r.count).sum::<u64>() <= max_slots);
            }
        }
    }

    #[test]
    fn oversized_runs_split_mid_range() {
        let mut pump = WindowPump::new(8, 10);
        let chunks = collect(&mut pump, &[Run { task: 3, t0: 2, count: 35 }]);
        assert_eq!(chunks.len(), 4);
        let counts: Vec<u64> = chunks
            .iter()
            .map(|(_, c)| c.iter().map(|r| r.count).sum())
            .collect();
        assert_eq!(counts, vec![10, 10, 10, 5]);
        assert_eq!(chunks[1].1[0].t0, 12, "pieces continue the range");
    }

    #[test]
    fn peak_bytes_tracks_the_largest_buffer() {
        let mut pump = WindowPump::new(3, 1000);
        let runs: Vec<Run> = (0..7).map(|i| Run { task: i, t0: 0, count: 1 }).collect();
        let chunks = collect(&mut pump, &runs);
        assert_eq!(chunks.len(), 3);
        assert_eq!(
            pump.peak_bytes(),
            (3 * std::mem::size_of::<Run>()) as u64,
            "peak is the fullest buffer, not the total stream"
        );
    }

    #[test]
    fn empty_stream_emits_nothing() {
        let mut pump = WindowPump::new(4, 4);
        let chunks = collect(&mut pump, &[]);
        assert!(chunks.is_empty());
        assert_eq!(pump.chunks_emitted(), 0);
        assert_eq!(pump.peak_bytes(), 0);
        // zero-count runs are dropped, not emitted as empty chunks
        let chunks = collect(&mut pump, &[Run { task: 0, t0: 0, count: 0 }]);
        assert!(chunks.is_empty());
    }

    #[test]
    fn sink_errors_propagate() {
        let mut pump = WindowPump::new(1, 1);
        let r = pump.offer(Run { task: 0, t0: 0, count: 5 }, |_, _| {
            anyhow::bail!("sink failed")
        });
        assert!(r.is_err());
    }
}
