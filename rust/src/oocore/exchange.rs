//! Cross-process round merging for `cupc shard`: ranks trade per-chunk
//! results through rename-atomic [`DiskStore`] entries.
//!
//! The driver owns chunks round-robin (`seq % world == rank`) and every
//! rank must apply the *complete* round in canonical chunk order before
//! the next round starts — that is the whole determinism argument. The
//! [`DiskExchange`] is that barrier: each rank writes one blob holding
//! its owned chunks for the round (an empty blob when it owns none —
//! presence is the signal), then polls for every other rank's blob.
//! `DiskStore` writes are temp + fsync + rename, so a blob is either
//! absent or complete; no locking, no sockets, and the store directory
//! doubles as the job's mailbox (workers on a shared filesystem work).
//!
//! Blob keys are content-hashed from (plan key, level, round, rank), so
//! one store can host many plans and a re-run of the same plan *reuses*
//! stale blobs only if the plan key is identical — which by
//! construction means the same bytes would be produced anyway. Blobs
//! are never deleted mid-run (a slow rank may still need round r − 1);
//! the coordinator removes the store directory when the job is done.
//!
//! Payload codecs live here too: level-0 survivor pair lists and the
//! per-chunk `(tests, Removals)` payloads for deeper levels. Both are
//! fixed little-endian layouts validated on decode — a truncated or
//! alien blob is an error, never a silent wrong merge.

use crate::service::cache::{ContentHasher, Key};
use crate::service::store::DiskStore;
use crate::skeleton::batch::Removals;
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// Encode a level-0 survivor (or candidate) pair list: `u32` count,
/// then `(u32 i, u32 j)` per pair, little-endian.
pub fn encode_pairs(pairs: &[(u32, u32)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + pairs.len() * 8);
    out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
    for &(i, j) in pairs {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&j.to_le_bytes());
    }
    out
}

/// Inverse of [`encode_pairs`]; rejects any size mismatch.
pub fn decode_pairs(b: &[u8]) -> Result<Vec<(u32, u32)>> {
    if b.len() < 4 {
        bail!("pair blob truncated: {} bytes", b.len());
    }
    let len = u32::from_le_bytes(b[0..4].try_into().unwrap()) as usize;
    if b.len() != 4 + len * 8 {
        bail!("pair blob size mismatch: {} bytes for {len} pairs", b.len());
    }
    let mut out = Vec::with_capacity(len);
    for c in b[4..].chunks_exact(8) {
        out.push((
            u32::from_le_bytes(c[0..4].try_into().unwrap()),
            u32::from_le_bytes(c[4..8].try_into().unwrap()),
        ));
    }
    Ok(out)
}

/// Encode one level-≥1 chunk result: `u64` test count, then the
/// [`Removals`] wire format.
pub fn encode_level_chunk(r: &Removals, tests: u64) -> Vec<u8> {
    let body = r.to_bytes();
    let mut out = Vec::with_capacity(8 + body.len());
    out.extend_from_slice(&tests.to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Inverse of [`encode_level_chunk`].
pub fn decode_level_chunk(b: &[u8]) -> Result<(Removals, u64)> {
    if b.len() < 8 {
        bail!("chunk blob truncated: {} bytes", b.len());
    }
    let tests = u64::from_le_bytes(b[0..8].try_into().unwrap());
    let r = Removals::from_bytes(&b[8..])?;
    Ok((r, tests))
}

/// One rank's handle on the per-round barrier. Constructed per worker
/// process (or per thread in the in-process conformance harness) over a
/// store directory shared by all ranks of the plan.
pub struct DiskExchange {
    store: DiskStore,
    plan_key: Key,
    rank: usize,
    world: usize,
    poll: Duration,
    timeout: Duration,
}

impl DiskExchange {
    /// `store` should be opened with an effectively unbounded budget
    /// (eviction mid-run would tear the barrier); `rank < world`.
    pub fn new(store: DiskStore, plan_key: Key, rank: usize, world: usize) -> DiskExchange {
        assert!(world >= 1 && rank < world, "rank {rank} of world {world}");
        DiskExchange {
            store,
            plan_key,
            rank,
            world,
            poll: Duration::from_millis(2),
            timeout: Duration::from_secs(600),
        }
    }

    /// Override the poll interval and peer timeout (tests use short
    /// timeouts; huge jobs on slow shared filesystems may need more).
    pub fn with_timing(mut self, poll: Duration, timeout: Duration) -> DiskExchange {
        self.poll = poll;
        self.timeout = timeout;
        self
    }

    /// `(rank, world)` — the driver derives chunk ownership from this.
    pub fn topology(&self) -> (usize, usize) {
        (self.rank, self.world)
    }

    fn blob_key(&self, level: u32, round: u64, rank: usize) -> Key {
        let mut h = ContentHasher::new();
        h.write(b"cupc-shard-blob/v1");
        h.write_u64(self.plan_key.0);
        h.write_u64(self.plan_key.1);
        h.write_u64(level as u64);
        h.write_u64(round);
        h.write_u64(rank as u64);
        h.finish()
    }

    /// Publish this rank's owned chunks for `(level, round)` and collect
    /// the full round: returns `n_chunks` payloads ordered by chunk
    /// sequence number. Every rank must call this with the same
    /// `(level, round, n_chunks)` — the canonical emit order guarantees
    /// they do — and owns the seqs with `seq % world == rank`. Errors on
    /// peer timeout, on a duplicate / out-of-range / missing seq, and on
    /// a publish that cannot be read back (e.g. an unwritable store).
    pub fn exchange(
        &mut self,
        level: u32,
        round: u64,
        n_chunks: usize,
        mine: Vec<(u32, Vec<u8>)>,
    ) -> Result<Vec<Vec<u8>>> {
        let mut blob = Vec::new();
        blob.extend_from_slice(&(mine.len() as u32).to_le_bytes());
        for (seq, payload) in &mine {
            blob.extend_from_slice(&seq.to_le_bytes());
            blob.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            blob.extend_from_slice(payload);
        }
        self.store.put_shard(self.blob_key(level, round, self.rank), &blob);
        drop(blob);

        let mut merged: Vec<Option<Vec<u8>>> = vec![None; n_chunks];
        let deadline = Instant::now() + self.timeout;
        for rank in 0..self.world {
            let key = self.blob_key(level, round, rank);
            let raw = loop {
                // polling own rank too: if our own put failed silently
                // (store puts are best-effort) the barrier must fail
                // loudly here, not deadlock a peer
                match self.store.get_shard(key) {
                    Some(r) => break r,
                    None if Instant::now() >= deadline => bail!(
                        "shard barrier timeout: rank {rank} missing at level {level} round {round} \
                         (plan {:016x}{:016x})",
                        self.plan_key.0,
                        self.plan_key.1,
                    ),
                    None => std::thread::sleep(self.poll),
                }
            };
            let ctx = || format!("rank {rank} blob, level {level} round {round}");
            if raw.len() < 4 {
                bail!("{}: truncated ({} bytes)", ctx(), raw.len());
            }
            let n_owned = u32::from_le_bytes(raw[0..4].try_into().unwrap()) as usize;
            let mut at = 4usize;
            for _ in 0..n_owned {
                if raw.len() < at + 8 {
                    bail!("{}: truncated entry header", ctx());
                }
                let seq = u32::from_le_bytes(raw[at..at + 4].try_into().unwrap()) as usize;
                let len = u32::from_le_bytes(raw[at + 4..at + 8].try_into().unwrap()) as usize;
                at += 8;
                if raw.len() < at + len {
                    bail!("{}: truncated entry payload", ctx());
                }
                if seq >= n_chunks {
                    bail!("{}: chunk seq {seq} out of range (round has {n_chunks})", ctx());
                }
                if seq % self.world != rank {
                    bail!("{}: chunk seq {seq} not owned by rank {rank}", ctx());
                }
                if merged[seq].is_some() {
                    bail!("{}: duplicate chunk seq {seq}", ctx());
                }
                merged[seq] = Some(raw[at..at + len].to_vec());
                at += len;
            }
            if at != raw.len() {
                bail!("{}: {} trailing bytes", ctx(), raw.len() - at);
            }
        }
        merged
            .into_iter()
            .enumerate()
            .map(|(seq, b)| b.with_context(|| format!("chunk seq {seq} missing from every rank")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cupc_exch_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn open(dir: &PathBuf) -> DiskStore {
        DiskStore::open(dir, u64::MAX).unwrap()
    }

    #[test]
    fn pair_codec_roundtrips_and_rejects_corruption() {
        let pairs = vec![(0u32, 1u32), (0, 4), (2, 3), (1000, 2000)];
        let b = encode_pairs(&pairs);
        assert_eq!(decode_pairs(&b).unwrap(), pairs);
        assert_eq!(decode_pairs(&encode_pairs(&[])).unwrap(), vec![]);
        assert!(decode_pairs(&b[..b.len() - 1]).is_err(), "truncation");
        assert!(decode_pairs(&[1, 0, 0]).is_err(), "short header");
    }

    #[test]
    fn level_chunk_codec_roundtrips() {
        // a 2-entry l=2 candidate list in its own wire format:
        // (3,7 | S={1,5}) then (0,2 | S={4,6})
        let mut raw = Vec::new();
        for v in [2u32, 2, 3, 7, 0, 2, 1, 5, 4, 6] {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let r = Removals::from_bytes(&raw).unwrap();
        let b = encode_level_chunk(&r, 42);
        let (got, tests) = decode_level_chunk(&b).unwrap();
        assert_eq!(tests, 42);
        assert_eq!(got.to_bytes(), r.to_bytes());
        assert!(decode_level_chunk(&b[..7]).is_err());
        assert!(decode_level_chunk(&b[..b.len() - 2]).is_err());
    }

    /// Two ranks over one directory: both collect the identical merged
    /// round, ordered by chunk seq, across multiple (level, round)
    /// coordinates.
    #[test]
    fn two_ranks_merge_rounds_in_chunk_order() {
        let dir = tmp_dir("merge");
        let plan: Key = (11, 22);
        let payload = |seq: u32| vec![seq as u8; 3 + seq as usize];
        let results = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2usize)
                .map(|rank| {
                    let dir = &dir;
                    scope.spawn(move || {
                        let mut ex = DiskExchange::new(open(dir), plan, rank, 2).with_timing(
                            Duration::from_millis(1),
                            Duration::from_secs(20),
                        );
                        let mut out = Vec::new();
                        for (level, round, n_chunks) in [(0u32, 0u64, 5usize), (1, 0, 3), (1, 1, 1)]
                        {
                            let mine: Vec<(u32, Vec<u8>)> = (0..n_chunks as u32)
                                .filter(|s| *s as usize % 2 == rank)
                                .map(|s| (s, payload(s)))
                                .collect();
                            out.push(ex.exchange(level, round, n_chunks, mine).unwrap());
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        assert_eq!(results[0], results[1], "every rank sees the same merge");
        for (i, n_chunks) in [5usize, 3, 1].into_iter().enumerate() {
            let want: Vec<Vec<u8>> = (0..n_chunks as u32).map(payload).collect();
            assert_eq!(results[0][i], want, "round {i} in seq order");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A rank that owns nothing this round still publishes (presence is
    /// the barrier) and still receives the full round.
    #[test]
    fn empty_ownership_still_synchronizes() {
        let dir = tmp_dir("empty");
        let plan: Key = (5, 5);
        let mut a = DiskExchange::new(open(&dir), plan, 0, 2)
            .with_timing(Duration::from_millis(1), Duration::from_secs(20));
        let mut b = DiskExchange::new(open(&dir), plan, 1, 2)
            .with_timing(Duration::from_millis(1), Duration::from_secs(20));
        // one chunk: rank 0 owns seq 0, rank 1 owns nothing
        let t = std::thread::scope(|scope| {
            let h = scope.spawn(move || b.exchange(2, 3, 1, Vec::new()).unwrap());
            let got_a = a.exchange(2, 3, 1, vec![(0, b"x".to_vec())]).unwrap();
            (got_a, h.join().unwrap())
        });
        assert_eq!(t.0, vec![b"x".to_vec()]);
        assert_eq!(t.0, t.1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_peer_times_out_with_context() {
        let dir = tmp_dir("timeout");
        let mut ex = DiskExchange::new(open(&dir), (1, 2), 0, 2)
            .with_timing(Duration::from_millis(1), Duration::from_millis(30));
        let err = ex
            .exchange(0, 0, 2, vec![(0, vec![7])])
            .expect_err("rank 1 never shows up");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("timeout"), "{msg}");
        let _ = fs::remove_dir_all(&dir);
    }

    /// Malformed blobs fail the merge loudly. The reading loop visits
    /// rank 0 first, so publishing a bad blob *as* rank 0 exercises the
    /// validation without needing a live peer.
    #[test]
    fn malformed_ownership_is_rejected() {
        let dir = tmp_dir("badseq");
        let plan: Key = (3, 9);
        // rank 0 claims seq 1, which rank 1 owns
        let mut bad = DiskExchange::new(open(&dir), plan, 0, 2)
            .with_timing(Duration::from_millis(1), Duration::from_millis(200));
        let err = bad
            .exchange(1, 0, 2, vec![(1, vec![1])])
            .expect_err("foreign seq must be rejected");
        assert!(format!("{err:#}").contains("not owned"), "{err:#}");
        // rank 0 claims a seq past the round's chunk count
        let mut oob = DiskExchange::new(open(&dir), plan, 0, 2)
            .with_timing(Duration::from_millis(1), Duration::from_millis(200));
        let err = oob
            .exchange(2, 0, 1, vec![(4, vec![1])])
            .expect_err("seq past n_chunks must be rejected");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }
}
