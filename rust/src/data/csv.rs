//! Minimal CSV reader/writer for numeric data matrices (samples × vars).
//!
//! Accepts an optional header row (detected by non-numeric first field),
//! comma / tab / semicolon separators, and blank-line tolerance. This is
//! the `read.csv` analog of the R pcalg workflow the paper integrates
//! with.

use crate::stats::corr::DataMatrix;
use anyhow::{bail, Context, Result};

/// Parse CSV text into a data matrix (+ optional column names).
///
/// Tolerates a UTF-8 BOM, CRLF line endings, trailing newlines, blank
/// lines and `#` comments. Ragged rows are a clear error (never a
/// panic), reported with the 1-based line number.
pub fn parse_csv(text: &str) -> Result<(DataMatrix, Option<Vec<String>>)> {
    // Excel and friends prepend a BOM; without stripping it the first
    // field of a headerless file fails to parse as a number and the row
    // would silently be taken for a header.
    let text = text.strip_prefix('\u{feff}').unwrap_or(text);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut header: Option<Vec<String>> = None;
    let mut n: Option<usize> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let sep = if line.contains('\t') {
            '\t'
        } else if line.contains(';') && !line.contains(',') {
            ';'
        } else {
            ','
        };
        let fields: Vec<&str> = line.split(sep).map(|f| f.trim()).collect();
        if rows.is_empty() && header.is_none() {
            // header detection: any non-numeric field
            if fields.iter().any(|f| f.parse::<f64>().is_err()) {
                header = Some(fields.iter().map(|s| s.to_string()).collect());
                n = Some(fields.len());
                continue;
            }
        }
        let vals: Result<Vec<f64>> = fields
            .iter()
            .map(|f| {
                f.parse::<f64>()
                    .with_context(|| format!("line {}: bad number {f:?}", lineno + 1))
            })
            .collect();
        let vals = vals?;
        if let Some(nn) = n {
            if vals.len() != nn {
                bail!(
                    "line {}: expected {} fields, got {}",
                    lineno + 1,
                    nn,
                    vals.len()
                );
            }
        } else {
            n = Some(vals.len());
        }
        rows.push(vals);
    }
    let n = n.context("empty csv")?;
    let m = rows.len();
    if m == 0 {
        bail!("csv has a header but no data rows");
    }
    let mut x = Vec::with_capacity(m * n);
    for r in rows {
        x.extend(r);
    }
    Ok((DataMatrix::new(x, m, n), header))
}

/// Load a CSV file from disk.
pub fn load_csv(path: &std::path::Path) -> Result<(DataMatrix, Option<Vec<String>>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text)
}

/// Write a data matrix as CSV (with v0..v{n-1} header).
pub fn write_csv(path: &std::path::Path, data: &DataMatrix) -> Result<()> {
    use std::io::Write;
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    let names: Vec<String> = (0..data.n).map(|i| format!("v{i}")).collect();
    writeln!(w, "{}", names.join(","))?;
    for s in 0..data.m {
        let row: Vec<String> = (0..data.n).map(|v| format!("{}", data.at(s, v))).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_csv() {
        let (d, h) = parse_csv("1,2,3\n4,5,6\n").unwrap();
        assert!(h.is_none());
        assert_eq!((d.m, d.n), (2, 3));
        assert_eq!(d.at(1, 2), 6.0);
    }

    #[test]
    fn parses_header_and_tabs() {
        let (d, h) = parse_csv("a\tb\n1\t2\n3\t4\n").unwrap();
        assert_eq!(h.unwrap(), vec!["a", "b"]);
        assert_eq!((d.m, d.n), (2, 2));
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let (d, _) = parse_csv("# comment\n1,2\n\n3,4\n").unwrap();
        assert_eq!(d.m, 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2\n3\n").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_csv("1,2\nx,y\n").is_err());
        assert!(parse_csv("").is_err());
    }

    #[test]
    fn roundtrip_via_file() {
        let d = DataMatrix::new(vec![1.5, -2.0, 0.25, 3.0], 2, 2);
        let tmp = std::env::temp_dir().join("cupc_test_roundtrip.csv");
        write_csv(&tmp, &d).unwrap();
        let (d2, h) = load_csv(&tmp).unwrap();
        assert_eq!(h.unwrap(), vec!["v0", "v1"]);
        assert_eq!(d.x, d2.x);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn header_detection_no_header_when_all_numeric() {
        // an all-numeric first row is data, not a header
        let (d, h) = parse_csv("0.5,1.5\n2.5,3.5\n").unwrap();
        assert!(h.is_none());
        assert_eq!(d.m, 2);
        assert_eq!(d.at(0, 0), 0.5);
    }

    #[test]
    fn trailing_newlines_and_missing_final_newline() {
        let with = parse_csv("1,2\n3,4\n\n\n").unwrap().0;
        let without = parse_csv("1,2\n3,4").unwrap().0;
        assert_eq!(with.x, without.x);
        assert_eq!((with.m, with.n), (2, 2));
    }

    #[test]
    fn crlf_line_endings() {
        let (d, h) = parse_csv("a,b\r\n1,2\r\n3,4\r\n").unwrap();
        assert_eq!(h.unwrap(), vec!["a", "b"]);
        assert_eq!((d.m, d.n), (2, 2));
        assert_eq!(d.at(1, 1), 4.0);
    }

    #[test]
    fn utf8_bom_does_not_fake_a_header() {
        // BOM + numeric first row: still headerless data
        let (d, h) = parse_csv("\u{feff}1,2\n3,4\n").unwrap();
        assert!(h.is_none(), "BOM must not turn a data row into a header");
        assert_eq!((d.m, d.n), (2, 2));
        // BOM + real header still detected
        let (d2, h2) = parse_csv("\u{feff}x,y\n1,2\n").unwrap();
        assert_eq!(h2.unwrap(), vec!["x", "y"]);
        assert_eq!(d2.m, 1);
    }

    #[test]
    fn ragged_row_is_a_clear_error_with_line_number() {
        let err = parse_csv("1,2,3\n4,5\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("expected 3"), "{msg}");

        // ragged against a header's width, CRLF included
        let err = parse_csv("a,b\r\n1,2,3\r\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("expected 2"), "{msg}");
    }

    #[test]
    fn header_only_file_is_an_error() {
        let err = parse_csv("a,b,c\n").unwrap_err();
        assert!(format!("{err:#}").contains("no data rows"));
    }

    #[test]
    fn semicolon_separator() {
        let (d, h) = parse_csv("x;y\n1.5;2.5\n").unwrap();
        assert_eq!(h.unwrap(), vec!["x", "y"]);
        assert_eq!(d.at(0, 1), 2.5);
    }

    #[test]
    fn roundtrip_preserves_awkward_values_exactly() {
        // Display-formatted f64 is the shortest exact representation, so
        // write_csv → parse_csv must be bit-exact even for awkward values.
        let vals = vec![
            0.1,
            -1.0 / 3.0,
            1e-300,
            -2.5e17,
            f64::MIN_POSITIVE,
            123456789.123456789,
        ];
        let d = DataMatrix::new(vals.clone(), 3, 2);
        let tmp = std::env::temp_dir().join("cupc_test_awkward_roundtrip.csv");
        write_csv(&tmp, &d).unwrap();
        let (d2, _) = load_csv(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(d2.x, vals, "roundtrip must be bit-exact");
    }
}
