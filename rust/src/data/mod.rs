//! Data ingestion: CSV loading for observational data matrices.

pub mod csv;
