//! `cupc` — command-line leader for the cuPC reproduction.
//!
//! Subcommands:
//!   run         PC-stable on a dataset (registry name or CSV file)
//!   batch       run a JSON manifest of jobs under one thread budget
//!               with a shared content-addressed result cache
//!   simulate    generate a synthetic dataset CSV (paper §5.6 protocol)
//!   experiment  regenerate a paper table/figure (table2, fig5..fig10)
//!   engines     smoke-check the native and XLA engines against each other

mod cmd;

fn main() {
    let args = cupc::util::cli::Args::from_env();
    let code = match cmd::dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
