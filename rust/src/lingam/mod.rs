//! ParaLiNGAM: parallel DirectLiNGAM for linear non-Gaussian acyclic
//! models (Shahbazinia, Salehkaleybar & Hashemi, arxiv 2109.13993) —
//! the first causal-order engine family behind the two-kind registry
//! ([`crate::family`]).
//!
//! DirectLiNGAM finds a causal *order* by repeatedly electing a root:
//! in each round, every active pair (i, j) contributes the pairwise
//! likelihood-ratio measure D(i, j) ([`measure`]); the variable whose
//! score `Σ_j min(0, D)²` is smallest is appended to the order and the
//! remaining variables are residualized against it. A final pass
//! regresses each variable on its order predecessors (original
//! standardized data) and keeps coefficients above
//! [`measure::PRUNE_THRESHOLD`], yielding a DAG rather than a CPDAG —
//! no orientation phase, no sepsets, no correlation matrix.
//!
//! ParaLiNGAM's contribution is batching the O(k²) measure sweep of
//! each round across workers; here that is [`Executor::run_weighted`]
//! with one atomic task per pair, which the generic
//! [`crate::family::run_order`] driver reduces serially in canonical
//! order — bit-identical for any thread count, either CI kernel
//! (unused by this family), and warm or cold cache.
//!
//! All quantities are f64 end to end; `tools/lingam_oracle.py` mirrors
//! this module draw for draw and gates the shipped grid points on
//! decision margins (root-score gaps, pruning-coefficient distance
//! from the threshold) that dwarf any cross-implementation
//! summation-order deltas.

pub mod measure;

use crate::api::OrderResult;
use crate::family::CausalOrder;
use crate::skeleton::pipeline::Executor;
use crate::skeleton::Config;
use crate::stats::corr::DataMatrix;
use anyhow::{ensure, Result};
use measure::{standardize, PRUNE_THRESHOLD};

/// Sequential dot product (canonical sample order — the bitwise
/// contract depends on every sum being evaluated in one fixed order).
fn dot(x: &[f64], y: &[f64]) -> f64 {
    let mut s = 0.0;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}

/// Solve the k×k system `a · w = b` by Gaussian elimination with
/// partial pivoting (row-major `a`, overwritten). The normal equations
/// of the pruning regressions are tiny (k < n), so a dense direct
/// solve is exact enough — the oracle certifies every shipped grid
/// point's coefficients sit ≥ 0.01 from the pruning gate, 10 orders
/// of magnitude above solver-vs-LAPACK deltas.
fn solve(a: &mut [f64], b: &mut [f64], k: usize) -> Result<Vec<f64>> {
    for col in 0..k {
        let mut piv = col;
        for row in col + 1..k {
            if a[row * k + col].abs() > a[piv * k + col].abs() {
                piv = row;
            }
        }
        ensure!(
            a[piv * k + col].abs() > 1e-12,
            "singular normal equations at column {col} (collinear predecessors)"
        );
        if piv != col {
            for cc in 0..k {
                a.swap(piv * k + cc, col * k + cc);
            }
            b.swap(piv, col);
        }
        for row in col + 1..k {
            let f = a[row * k + col] / a[col * k + col];
            if f == 0.0 {
                continue;
            }
            for cc in col..k {
                a[row * k + cc] -= f * a[col * k + cc];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; k];
    for row in (0..k).rev() {
        let mut s = b[row];
        for cc in row + 1..k {
            s -= a[row * k + cc] * x[cc];
        }
        x[row] = s / a[row * k + row];
    }
    Ok(x)
}

/// The ParaLiNGAM strategy: standardized data columns, residualized in
/// place between rounds, plus the frozen originals for pruning.
pub struct ParaLingam {
    m: usize,
    /// Working columns — residualized against each elected root.
    cols: Vec<Vec<f64>>,
    /// Frozen standardized originals, for the pruning regressions.
    original: Vec<Vec<f64>>,
    /// Variables not yet placed, ascending.
    active: Vec<usize>,
}

impl ParaLingam {
    pub fn new(data: &DataMatrix) -> ParaLingam {
        let (m, n) = (data.m, data.n);
        let mut cols = Vec::with_capacity(n);
        for v in 0..n {
            let raw: Vec<f64> = (0..m).map(|s| data.at(s, v)).collect();
            cols.push(standardize(&raw));
        }
        ParaLingam {
            m,
            original: cols.clone(),
            cols,
            active: (0..n).collect(),
        }
    }

    /// OLS of `order[p]` on `order[..p]` over the original standardized
    /// data; returns the kept `(parent, child, weight)` rows in
    /// predecessor order.
    fn regress_position(&self, order: &[usize], p: usize) -> Result<Vec<(usize, usize, f64)>> {
        let child = order[p];
        let preds = &order[..p];
        let k = preds.len();
        let mut a = vec![0.0; k * k];
        let mut b = vec![0.0; k];
        for (q, &pq) in preds.iter().enumerate() {
            for (r, &pr) in preds.iter().enumerate() {
                a[q * k + r] = dot(&self.original[pq], &self.original[pr]) / self.m as f64;
            }
            b[q] = dot(&self.original[pq], &self.original[child]) / self.m as f64;
        }
        let w = solve(&mut a, &mut b, k)?;
        let mut out = Vec::new();
        for (q, &parent) in preds.iter().enumerate() {
            if w[q].abs() > PRUNE_THRESHOLD {
                out.push((parent, child, w[q]));
            }
        }
        Ok(out)
    }
}

impl CausalOrder for ParaLingam {
    fn label(&self) -> &'static str {
        "paralingam"
    }

    fn samples(&self) -> usize {
        self.m
    }

    fn active(&self) -> &[usize] {
        &self.active
    }

    fn measure(&self, a: usize, b: usize) -> f64 {
        measure::measure(&self.cols[a], &self.cols[b])
    }

    fn eliminate(&mut self, root: usize) {
        let root_col = self.cols[root].clone();
        let m = self.m as f64;
        for &v in &self.active {
            if v == root {
                continue;
            }
            let c = dot(&self.cols[v], &root_col) / m;
            let resid: Vec<f64> = self.cols[v]
                .iter()
                .zip(&root_col)
                .map(|(x, r)| x - c * r)
                .collect();
            self.cols[v] = standardize(&resid);
        }
        self.active.retain(|&v| v != root);
    }

    fn prune(&self, order: &[usize], exec: &mut Executor<'_>) -> Result<Vec<(usize, usize, f64)>> {
        if order.len() < 2 {
            return Ok(Vec::new());
        }
        // task id t regresses order position t+1; weight ≈ the normal
        // equations' gram cost so shards balance on the real work
        let weights: Vec<u64> = (1..order.len())
            .map(|p| (p * p * self.m).max(1) as u64)
            .collect();
        let shard_results = exec.run_weighted(&weights, |ids, _engine| {
            let mut out = Vec::new();
            for &id in ids {
                out.extend(self.regress_position(order, id + 1)?);
            }
            Ok(out)
        })?;
        // canonical concatenation: child positions ascending, parents
        // in predecessor order within each child
        Ok(shard_results.into_iter().flatten().collect())
    }
}

/// Whole-run entry point registered as the `lingam` family (tag 7):
/// data in, causal order + pruned DAG out, through the generic
/// [`crate::family::run_order`] driver.
pub fn run(data: &DataMatrix, cfg: &Config) -> Result<OrderResult> {
    let mut strategy = ParaLingam::new(data);
    crate::family::run_order(&mut strategy, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// x0 → x1 → x2 with uniform noise: DirectLiNGAM must recover the
    /// chain order and exactly the two true edges.
    fn chain_data(m: usize, seed: u64) -> DataMatrix {
        let mut rng = Pcg::seeded(seed);
        let s = 3f64.sqrt();
        let mut x = vec![0.0; m * 3];
        for row in 0..m {
            let x0 = rng.uniform_in(-s, s);
            let x1 = 0.8 * x0 + rng.uniform_in(-s, s);
            let x2 = 0.7 * x1 + rng.uniform_in(-s, s);
            x[row * 3] = x0;
            x[row * 3 + 1] = x1;
            x[row * 3 + 2] = x2;
        }
        DataMatrix::new(x, m, 3)
    }

    #[test]
    fn recovers_a_chain_and_its_edges() {
        let data = chain_data(4000, 21);
        let cfg = Config {
            threads: 2,
            ..Config::default()
        };
        let res = run(&data, &cfg).unwrap();
        assert_eq!(res.order, vec![0, 1, 2]);
        let got: Vec<(usize, usize)> = res.edges.iter().map(|&(a, b, _)| (a, b)).collect();
        assert_eq!(got, vec![(0, 1), (1, 2)]);
        for &(_, _, w) in &res.edges {
            assert!(w > 0.5, "edge weight {w} implausibly small");
        }
        // two elimination rounds for three variables, each electing one
        assert_eq!(res.rounds.len(), 2);
        assert_eq!(res.rounds[0].tests, 3);
        assert_eq!(res.rounds[0].removed, 1);
        assert_eq!(res.rounds[1].tests, 1);
    }

    /// The bitwise contract inside one process: order, edges (weights
    /// included, bit for bit), and per-round stats must not depend on
    /// the worker count.
    #[test]
    fn results_are_bit_identical_across_thread_counts() {
        let data = chain_data(2000, 22);
        let base = run(
            &data,
            &Config {
                threads: 1,
                ..Config::default()
            },
        )
        .unwrap();
        for threads in [2, 4, 7] {
            let res = run(
                &data,
                &Config {
                    threads,
                    ..Config::default()
                },
            )
            .unwrap();
            assert_eq!(res.order, base.order, "threads={threads}");
            assert_eq!(res.edges.len(), base.edges.len());
            for (a, b) in res.edges.iter().zip(&base.edges) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1, b.1);
                assert_eq!(a.2.to_bits(), b.2.to_bits(), "threads={threads}");
            }
            let stats: Vec<(usize, u64, usize, usize)> = res
                .rounds
                .iter()
                .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                .collect();
            let want: Vec<(usize, u64, usize, usize)> = base
                .rounds
                .iter()
                .map(|l| (l.level, l.tests, l.removed, l.edges_after))
                .collect();
            assert_eq!(stats, want, "threads={threads}");
        }
    }

    #[test]
    fn degenerate_inputs_yield_empty_results() {
        let cfg = Config::default();
        let one = run(&DataMatrix::new(vec![1.0, 2.0, 3.0], 3, 1), &cfg).unwrap();
        assert_eq!(one.order, vec![0]);
        assert!(one.edges.is_empty());
        assert!(one.rounds.is_empty());

        let none = run(&DataMatrix::new(vec![], 0, 0), &cfg).unwrap();
        assert!(none.order.is_empty());
        assert!(none.edges.is_empty());
    }

    #[test]
    fn singular_regressions_error_instead_of_panicking() {
        // x1 is an exact copy of x0: the pruning normal equations for
        // x2 on {x0, x1} are singular
        let mut rng = Pcg::seeded(5);
        let m = 512;
        let mut x = vec![0.0; m * 3];
        for row in 0..m {
            let v = rng.uniform_in(-1.0, 1.0);
            x[row * 3] = v;
            x[row * 3 + 1] = v;
            x[row * 3 + 2] = v + 0.3 * rng.uniform_in(-1.0, 1.0);
        }
        let data = DataMatrix::new(x, m, 3);
        let err = run(&data, &Config::default());
        assert!(
            err.is_err(),
            "collinear duplicate columns must surface as an error"
        );
    }
}
