//! The DirectLiNGAM pairwise root-decision measure and its
//! maximum-entropy approximation (Hyvärinen 1998), exactly as
//! `tools/lingam_oracle.py` mirrors them.
//!
//! Everything here is f64 and sequentially summed in sample order: each
//! D(i, j) is computed wholly inside one executor task, so the only
//! reproducibility requirement is that a *single* evaluation is
//! deterministic — which sequential f64 arithmetic gives for free on
//! any thread count and either CI kernel (the kernel is a PC-engine
//! knob; this module never touches it). See docs/NUMERICS.md.

/// Hyvärinen's maximum-entropy approximation constants — the same
/// values the reference DirectLiNGAM implementation uses.
pub const K1: f64 = 79.047;
pub const K2: f64 = 7.4129;
pub const GAMMA: f64 = 0.37457;

/// Differential entropy of a standard Gaussian, `(1 + ln 2π) / 2`.
pub fn h_nu() -> f64 {
    (1.0 + (2.0 * std::f64::consts::PI).ln()) / 2.0
}

/// Coefficient-magnitude gate for the pruning regressions: keep an edge
/// iff `|b| > PRUNE_THRESHOLD` on standardized data.
pub const PRUNE_THRESHOLD: f64 = 0.05;

/// Standardize one column to zero mean / unit variance (population
/// denominator `1/m`). A (near-)constant column (`sd <= 1e-12`)
/// standardizes to all-zeros, mirroring `stats::corr` and the oracle.
pub fn standardize(col: &[f64]) -> Vec<f64> {
    let m = col.len();
    let mut mean = 0.0;
    for &x in col {
        mean += x;
    }
    mean /= m as f64;
    let mut var = 0.0;
    for &x in col {
        let d = x - mean;
        var += d * d;
    }
    let sd = (var / m as f64).sqrt();
    if sd <= 1e-12 {
        return vec![0.0; m];
    }
    col.iter().map(|&x| (x - mean) / sd).collect()
}

/// Ĥ(u): the maximum-entropy approximation of differential entropy for
/// an (approximately) standardized sample.
pub fn entropy(u: &[f64]) -> f64 {
    let m = u.len() as f64;
    let mut lc = 0.0;
    let mut ue = 0.0;
    for &x in u {
        lc += x.cosh().ln();
        ue += x * (-(x * x) / 2.0).exp();
    }
    lc /= m;
    ue /= m;
    h_nu() - K1 * (lc - GAMMA) * (lc - GAMMA) - K2 * ue * ue
}

/// D(i, j) for two standardized columns: positive iff `i` is the more
/// plausible cause of `j`. Antisymmetric by construction — the driver
/// evaluates each unordered pair once and negates for the other side.
pub fn measure(xi: &[f64], xj: &[f64]) -> f64 {
    let m = xi.len();
    debug_assert_eq!(m, xj.len());
    let mut c = 0.0;
    for (a, b) in xi.iter().zip(xj) {
        c += a * b;
    }
    c /= m as f64;
    let s2 = (1.0 - c * c).max(1e-12);
    let s = s2.sqrt();
    let mut ri_j = Vec::with_capacity(m);
    let mut rj_i = Vec::with_capacity(m);
    for (a, b) in xi.iter().zip(xj) {
        ri_j.push((a - c * b) / s);
        rj_i.push((b - c * a) / s);
    }
    (entropy(xj) + entropy(&ri_j)) - (entropy(xi) + entropy(&rj_i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn standardize_gives_zero_mean_unit_variance() {
        let mut rng = Pcg::seeded(7);
        let col: Vec<f64> = (0..500).map(|_| 3.0 + 2.5 * rng.normal()).collect();
        let z = standardize(&col);
        let m = z.len() as f64;
        let mean: f64 = z.iter().sum::<f64>() / m;
        let var: f64 = z.iter().map(|x| x * x).sum::<f64>() / m;
        assert!(mean.abs() < 1e-12, "mean {mean}");
        assert!((var - 1.0).abs() < 1e-12, "var {var}");
    }

    #[test]
    fn constant_column_standardizes_to_zeros() {
        assert!(standardize(&[4.2; 64]).iter().all(|&x| x == 0.0));
    }

    /// A standard Gaussian sample should sit near the entropy ceiling
    /// H_NU; a uniform sample (lower entropy at unit variance) clearly
    /// below it. The measure only uses differences, but the absolute
    /// anchoring catches sign/constant mistakes.
    #[test]
    fn entropy_ranks_gaussian_above_uniform() {
        let mut rng = Pcg::seeded(11);
        let g: Vec<f64> = (0..20000).map(|_| rng.normal()).collect();
        let s = 3f64.sqrt();
        let u: Vec<f64> = (0..20000).map(|_| rng.uniform_in(-s, s)).collect();
        let hg = entropy(&standardize(&g));
        let hu = entropy(&standardize(&u));
        assert!((hg - h_nu()).abs() < 0.01, "gaussian {hg} vs {}", h_nu());
        assert!(hg > hu + 0.05, "gaussian {hg} <= uniform {hu}");
    }

    /// On x → y with uniform noise, D(x, y) must be positive (x is the
    /// cause) and exactly antisymmetric as the driver assumes.
    #[test]
    fn measure_points_from_cause_to_effect() {
        let mut rng = Pcg::seeded(13);
        let s = 3f64.sqrt();
        let x: Vec<f64> = (0..8000).map(|_| rng.uniform_in(-s, s)).collect();
        let y: Vec<f64> = x.iter().map(|&v| 0.8 * v + rng.uniform_in(-s, s)).collect();
        let zx = standardize(&x);
        let zy = standardize(&y);
        let d = measure(&zx, &zy);
        assert!(d > 1e-4, "cause score {d}");
    }
}
