//! The top-level engine-family registry: every causal-discovery engine
//! the crate ships, across *both* kinds — CI-test PC schedules (the
//! [`skeleton`](crate::skeleton) families, tags 0..6) and causal-order
//! engines (root-finding rounds → causal order → regression pruning,
//! the [`lingam`](crate::lingam) family, tag 7).
//!
//! This is the seam the service, CLI, and cache layers dispatch on.
//! The `skeleton::family` table keeps only the *implementation* columns
//! (run function, schedule factory); the identity columns — canonical
//! name, aliases, cache tag — live here so a non-PC family registers in
//! exactly the same place and inherits manifest parsing, cache keys,
//! report labels, and USAGE text without touching those layers.
//!
//! Adding a family is now: write the leaf module, append one
//! [`EngineFamily`] row here with a fresh `tag` (PC kinds also append a
//! `skeleton::family::FamilyInfo` row), and everything else picks it
//! up. The registry tests below enforce the invariants a new row must
//! keep: globally unique names, aliases and tags across both kinds; PC
//! tags 0..6 pinned forever; parse/name round-trips.
//!
//! ```
//! use cupc::family::{self, FamilyId};
//! use cupc::skeleton::Variant;
//!
//! // any registered alias resolves, case-insensitively, to either kind
//! assert_eq!(family::parse("CUPS"), Some(FamilyId::Pc(Variant::CupcS)));
//! assert_eq!(family::parse("paralingam"), Some(FamilyId::Lingam));
//! assert_eq!(family::parse("no-such-engine"), None);
//!
//! // PC spellings still resolve to a plain Variant for PC-only layers
//! assert_eq!(Variant::parse("reversed"), Some(Variant::Reversed));
//! // ...but causal-order spellings deliberately do not
//! assert_eq!(Variant::parse("lingam"), None);
//!
//! assert_eq!(family::FAMILIES.len(), 8);
//! ```

use crate::api::OrderResult;
use crate::skeleton::pipeline::Executor;
use crate::skeleton::{Config, LevelStats, Variant};
use crate::stats::corr::DataMatrix;
use crate::util::timer::Timer;
use anyhow::Result;

/// Identity of one registered engine family. PC families carry their
/// skeleton [`Variant`]; causal-order families are their own arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FamilyId {
    /// A CI-test PC family (skeleton → orientation → CPDAG).
    Pc(Variant),
    /// The ParaLiNGAM causal-order family (order → pruned DAG).
    Lingam,
}

impl FamilyId {
    /// The skeleton variant, for PC families only. Layers that are
    /// PC-specific (shard plans, the batched level loop) go through
    /// this and reject `None` with a family-named error.
    pub fn variant(self) -> Option<Variant> {
        match self {
            FamilyId::Pc(v) => Some(v),
            FamilyId::Lingam => None,
        }
    }
}

/// Whole-run entry point of a causal-order family: observational data
/// in, causal order + pruned DAG out. The correlation layer is not
/// involved — the engine consumes raw columns.
pub type RunOrderFn = fn(&DataMatrix, &Config) -> Result<OrderResult>;

/// Which of the two engine kinds a registry row is.
pub enum FamilyKind {
    /// Runs through the PC pipeline (`skeleton::run` + orientation);
    /// the implementation columns live in `skeleton::family`.
    Pc,
    /// Runs through the [`CausalOrder`] driver; the row carries its
    /// whole-run function directly.
    Order(RunOrderFn),
}

/// One registered engine family (either kind).
pub struct EngineFamily {
    pub id: FamilyId,
    /// Canonical CLI/report spelling.
    pub name: &'static str,
    /// Accepted parse spellings (lowercase; include `name`).
    pub aliases: &'static [&'static str],
    /// Stable tag for content hashing — cache keys depend on it, so a
    /// tag is **never renumbered or reused**; new families append.
    pub tag: u8,
    pub kind: FamilyKind,
}

/// Every engine family, in tag order: the seven PC families (tags 0..6,
/// identical spellings to the pre-split `skeleton::family` registry so
/// no manifest, cache key, or report line moved), then the causal-order
/// families appended after them.
pub const FAMILIES: &[EngineFamily] = &[
    EngineFamily {
        id: FamilyId::Pc(Variant::Serial),
        name: "serial",
        aliases: &["serial", "stable", "stable.fast"],
        tag: 0,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::ParallelCpu),
        name: "parcpu",
        aliases: &["parcpu", "parallel-cpu", "parallel-pc"],
        tag: 1,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::CupcE),
        name: "cupc-e",
        aliases: &["cupe", "cupc-e", "e"],
        tag: 2,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::CupcS),
        name: "cupc-s",
        aliases: &["cups", "cupc-s", "s"],
        tag: 3,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::Baseline1),
        name: "baseline1",
        aliases: &["baseline1", "b1"],
        tag: 4,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::Baseline2),
        name: "baseline2",
        aliases: &["baseline2", "b2"],
        tag: 5,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Pc(Variant::Reversed),
        name: "reversed",
        aliases: &["reversed", "reversed-order", "rop"],
        tag: 6,
        kind: FamilyKind::Pc,
    },
    EngineFamily {
        id: FamilyId::Lingam,
        name: "lingam",
        aliases: &["lingam", "paralingam", "direct-lingam"],
        tag: 7,
        kind: FamilyKind::Order(crate::lingam::run),
    },
];

/// The registry row for a family id. Every constructible `FamilyId`
/// has exactly one row (enforced by `registry_covers_every_id`), so
/// this never panics on a constructed id.
pub fn of(id: FamilyId) -> &'static EngineFamily {
    FAMILIES
        .iter()
        .find(|f| f.id == id)
        .unwrap_or_else(|| panic!("family {id:?} is not registered in family::FAMILIES"))
}

/// Resolve a cache/wire tag back to its family, if any.
pub fn by_tag(tag: u8) -> Option<&'static EngineFamily> {
    FAMILIES.iter().find(|f| f.tag == tag)
}

/// Parse a CLI/manifest spelling (case-insensitive) against every
/// family's alias list, across both kinds.
pub fn parse(s: &str) -> Option<FamilyId> {
    let lower = s.to_ascii_lowercase();
    FAMILIES
        .iter()
        .find(|f| f.aliases.contains(&lower.as_str()))
        .map(|f| f.id)
}

/// One causal-order strategy under the generic [`run_order`] driver —
/// the counterpart of `RoundSchedule` for the second engine kind.
///
/// The driver owns the round loop; the strategy owns the data. The
/// split mirrors the PC seam: measure sweeps are batched through
/// [`Executor::run_weighted`] so each pairwise statistic is computed
/// wholly inside one task (exactly once, any shard split), and the
/// driver reduces the concatenated shard results serially in canonical
/// pair order — bit-identical for any thread count.
pub trait CausalOrder: Sync {
    /// Short name for progress lines.
    fn label(&self) -> &'static str;
    /// Sample count (the per-pair work weight).
    fn samples(&self) -> usize;
    /// Variables not yet placed in the order, ascending.
    fn active(&self) -> &[usize];
    /// The pairwise root-decision statistic D(a, b) for two active
    /// variables, `a < b`: positive iff `a` is the more plausible
    /// cause. Must be pure (called concurrently across workers).
    fn measure(&self, a: usize, b: usize) -> f64;
    /// Commit `root` as the next element of the causal order and
    /// residualize the remaining active variables against it.
    fn eliminate(&mut self, root: usize);
    /// Regress every variable on its order predecessors and keep the
    /// significant coefficients: the final DAG as `(parent, child,
    /// weight)` rows, in canonical (child-position, parent-position)
    /// order.
    fn prune(&self, order: &[usize], exec: &mut Executor<'_>) -> Result<Vec<(usize, usize, f64)>>;
}

/// Drive a [`CausalOrder`] strategy to a full [`OrderResult`]:
/// root-finding rounds (one per order position), then regression
/// pruning. Per-round stats reuse [`LevelStats`] with `level` = round,
/// `tests` = pairwise measures evaluated, `removed` = 1 (the chosen
/// root leaves the active set), `edges_after` = variables still
/// active — so the service report and stats layers need no new row
/// type.
///
/// Between rounds the executor re-leases through `cfg.width_hook`
/// exactly like the PC level loop, so elastic batch scheduling covers
/// causal-order jobs with zero scheduler changes.
pub fn run_order(strategy: &mut dyn CausalOrder, cfg: &Config) -> Result<OrderResult> {
    let total = Timer::start();
    let m = strategy.samples();
    let mut exec = Executor::pool_with(cfg.threads.max(1), cfg.kernel);
    let mut order: Vec<usize> = Vec::new();
    let mut rounds: Vec<LevelStats> = Vec::new();
    let mut round = 0usize;
    loop {
        let active: Vec<usize> = strategy.active().to_vec();
        if active.len() <= 1 {
            break;
        }
        if round > 0 {
            if let Some(hook) = &cfg.width_hook {
                exec.set_width(hook.0.width_for_level(round));
            }
        }
        let t = Timer::start();
        let k = active.len();
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(k * (k - 1) / 2);
        for ai in 0..k {
            for bi in ai + 1..k {
                pairs.push((ai, bi));
            }
        }
        // every pair is one atomic task of weight m; run_weighted
        // assigns it to exactly one shard and returns shard results in
        // canonical order
        let weights = vec![m as u64; pairs.len()];
        let sref: &dyn CausalOrder = &*strategy;
        let shard_results = exec.run_weighted(&weights, |ids, _engine| {
            let mut out = Vec::with_capacity(ids.len());
            for &id in ids {
                let (ai, bi) = pairs[id];
                out.push((id, sref.measure(active[ai], active[bi])));
            }
            Ok(out)
        })?;
        // serial reduction in canonical pair order: the score sums see
        // the same addends in the same order for any width
        let mut scores = vec![0.0f64; k];
        for (id, d) in shard_results.into_iter().flatten() {
            let (ai, bi) = pairs[id];
            let da = d.min(0.0);
            scores[ai] += da * da;
            let db = (-d).min(0.0);
            scores[bi] += db * db;
        }
        // argmin with smallest-index tie-break (strict < keeps the
        // earliest minimum)
        let mut best = 0usize;
        for (i, &s) in scores.iter().enumerate().skip(1) {
            if s < scores[best] {
                best = i;
            }
        }
        let root = active[best];
        order.push(root);
        strategy.eliminate(root);
        rounds.push(LevelStats {
            level: round,
            tests: pairs.len() as u64,
            removed: 1,
            edges_after: k - 1,
            seconds: t.elapsed_s(),
        });
        round += 1;
    }
    if let Some(&last) = strategy.active().first() {
        order.push(last);
    }
    if let Some(hook) = &cfg.width_hook {
        // pruning is "the round after the last", like orientation
        exec.set_width(hook.0.width_for_level(round));
    }
    let edges = strategy.prune(&order, &mut exec)?;
    Ok(OrderResult {
        order,
        edges,
        rounds,
        seconds: total.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::{variant_name, variant_tag};

    const ALL_IDS: [FamilyId; 8] = [
        FamilyId::Pc(Variant::Serial),
        FamilyId::Pc(Variant::ParallelCpu),
        FamilyId::Pc(Variant::CupcE),
        FamilyId::Pc(Variant::CupcS),
        FamilyId::Pc(Variant::Baseline1),
        FamilyId::Pc(Variant::Baseline2),
        FamilyId::Pc(Variant::Reversed),
        FamilyId::Lingam,
    ];

    #[test]
    fn registry_covers_every_id() {
        // `of` panics if an id is missing; enumerate them all so adding
        // an enum arm without a registry row fails here.
        for id in ALL_IDS {
            assert_eq!(of(id).id, id);
        }
        assert_eq!(FAMILIES.len(), ALL_IDS.len());
    }

    #[test]
    fn names_aliases_and_tags_are_globally_unique() {
        let mut names: Vec<&str> = FAMILIES.iter().map(|f| f.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), FAMILIES.len(), "duplicate canonical name");

        let mut aliases: Vec<&str> = FAMILIES
            .iter()
            .flat_map(|f| f.aliases.iter().copied())
            .collect();
        let n_aliases = aliases.len();
        aliases.sort_unstable();
        aliases.dedup();
        assert_eq!(aliases.len(), n_aliases, "an alias maps to two families");

        let mut tags: Vec<u8> = FAMILIES.iter().map(|f| f.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), FAMILIES.len(), "duplicate cache-key tag");
    }

    /// Cache keys and shard plans depend on these exact numbers: the PC
    /// tags 0..6 and their spellings are pinned forever, and every new
    /// family appends (lingam = 7).
    #[test]
    fn tags_and_names_are_pinned() {
        for (id, tag, name) in [
            (FamilyId::Pc(Variant::Serial), 0, "serial"),
            (FamilyId::Pc(Variant::ParallelCpu), 1, "parcpu"),
            (FamilyId::Pc(Variant::CupcE), 2, "cupc-e"),
            (FamilyId::Pc(Variant::CupcS), 3, "cupc-s"),
            (FamilyId::Pc(Variant::Baseline1), 4, "baseline1"),
            (FamilyId::Pc(Variant::Baseline2), 5, "baseline2"),
            (FamilyId::Pc(Variant::Reversed), 6, "reversed"),
            (FamilyId::Lingam, 7, "lingam"),
        ] {
            let f = of(id);
            assert_eq!(f.tag, tag, "{name}");
            assert_eq!(f.name, name);
            assert_eq!(by_tag(tag).map(|f| f.id), Some(id));
        }
    }

    /// `Variant::parse` and `variant_tag` round-trip through the new
    /// registry for every entry: PC rows resolve to their variant with
    /// the registry's tag and name; causal-order rows resolve here but
    /// deliberately not through `Variant::parse`.
    #[test]
    fn variant_parse_and_tag_roundtrip_through_the_registry() {
        for f in FAMILIES {
            assert_eq!(parse(f.name), Some(f.id), "{}", f.name);
            assert_eq!(parse(&f.name.to_ascii_uppercase()), Some(f.id));
            for a in f.aliases {
                assert_eq!(parse(a), Some(f.id), "alias {a}");
            }
            assert!(f.aliases.contains(&f.name), "{}: name must parse", f.name);
            match f.id.variant() {
                Some(v) => {
                    assert_eq!(Variant::parse(f.name), Some(v));
                    assert_eq!(variant_tag(v), f.tag);
                    assert_eq!(variant_name(v), f.name);
                }
                None => {
                    for a in f.aliases {
                        assert_eq!(Variant::parse(a), None, "{a} must not be a PC variant");
                    }
                }
            }
        }
        assert_eq!(parse("nope"), None);
    }

    #[test]
    fn aliases_are_lowercase() {
        for f in FAMILIES {
            for a in f.aliases {
                assert_eq!(*a, a.to_ascii_lowercase(), "{}: alias {a:?}", f.name);
            }
        }
    }

    /// The PC rows here and the implementation rows in
    /// `skeleton::family` stay in lockstep: same variants, same order.
    #[test]
    fn pc_rows_mirror_the_skeleton_registry() {
        let pc: Vec<Variant> = FAMILIES.iter().filter_map(|f| f.id.variant()).collect();
        let skel: Vec<Variant> = crate::skeleton::family::FAMILIES
            .iter()
            .map(|f| f.variant)
            .collect();
        assert_eq!(pc, skel);
        for f in FAMILIES {
            match (&f.kind, f.id.variant()) {
                (FamilyKind::Pc, Some(_)) | (FamilyKind::Order(_), None) => {}
                _ => panic!("{}: kind / id mismatch", f.name),
            }
        }
    }
}
