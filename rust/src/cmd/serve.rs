//! `cupc serve` — run the long-lived batch daemon.
//!
//! Binds the loopback-only serve protocol (`service::proto`), keeps the
//! two-layer content-addressed cache warm across requests, and shares
//! one elastic thread budget between every connected client's jobs.
//! SIGTERM / SIGINT request a clean shutdown: the accept loop stops,
//! in-flight requests finish streaming, and the process exits 0.

use super::batch::cache_budgets_from_args;
use anyhow::Result;
use cupc::service::server::{ServeOptions, Server};
use cupc::skeleton::available_threads;
use cupc::util::cli::Args;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Set by the signal handler; a watcher thread bridges it to the
/// server's shutdown flag (an async-signal handler may only touch
/// static atomics — never an `Arc` or a lock).
static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    SIGNALLED.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
fn install_signal_handlers() {
    // libc's `signal(2)` without the libc crate (the build is hermetic).
    // SIGINT=2 and SIGTERM=15 on every unix this crate targets; the
    // previous disposition is irrelevant, so the return value is unused.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    unsafe {
        signal(2, on_signal);
        signal(15, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

pub fn main(args: &Args) -> Result<()> {
    let (cache_bytes, disk_bytes) = cache_budgets_from_args(args)?;
    let opts = ServeOptions {
        addr: args.get_or("addr", "127.0.0.1:7717"),
        threads: args.get_usize("threads", available_threads())?,
        cache_bytes,
        cache_dir: args.get("cache-dir").map(PathBuf::from),
        disk_bytes,
        max_conns: args.get_usize("max-conns", 16)?,
        max_queued_jobs: args.get_usize("max-queued-jobs", 64)?,
        idle_timeout: Duration::from_secs(args.get_u64("idle-timeout-s", 300)?),
        frame_timeout: Duration::from_secs(args.get_u64("frame-timeout-s", 10)?),
        verbose: args.has_flag("verbose"),
    };
    if opts.cache_dir.is_none() && args.get("cache-disk-mb").is_some() {
        eprintln!("warning: --cache-disk-mb has no effect without --cache-dir");
    }

    install_signal_handlers();
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || loop {
            if SIGNALLED.load(Ordering::SeqCst) {
                eprintln!("serve: signal received, draining in-flight requests");
                shutdown.store(true, Ordering::SeqCst);
                return;
            }
            std::thread::sleep(Duration::from_millis(100));
        });
    }

    let server = Server::bind(opts.clone(), shutdown)?;
    let addr = server.local_addr()?;
    eprintln!(
        "serve: listening on {addr}, {} worker(s), cache {} MiB{}, \
         max {} connection(s) / {} queued job(s)",
        opts.threads,
        opts.cache_bytes >> 20,
        match &opts.cache_dir {
            Some(d) => format!(", disk cache {} ({} MiB)", d.display(), opts.disk_bytes >> 20),
            None => String::new(),
        },
        opts.max_conns,
        opts.max_queued_jobs
    );
    server.run()?;
    eprintln!("serve: shut down cleanly");
    Ok(())
}
