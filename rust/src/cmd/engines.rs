//! `cupc engines` — cross-check the native engine against the XLA
//! artifacts on random batches (the runtime smoke test). Requires the
//! `xla` cargo feature; without it the subcommand explains how to get it.

#[cfg(not(feature = "xla"))]
use anyhow::Result;
#[cfg(not(feature = "xla"))]
use cupc::util::cli::Args;

#[cfg(not(feature = "xla"))]
pub fn main(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "the `engines` cross-check drives the XLA PJRT runtime and this binary was built \
         without it; rebuild with `cargo build --features xla` (and run `make artifacts` \
         for the AOT kernels) to enable it"
    )
}

#[cfg(feature = "xla")]
pub use with_xla::main;

#[cfg(feature = "xla")]
mod with_xla {
    use anyhow::{bail, Result};
    use cupc::runtime::XlaEngine;
    use cupc::skeleton::engine::{CiEngine, NativeEngine};
    use cupc::util::cli::Args;
    use cupc::util::rng::Pcg;
    use std::path::Path;

    pub fn main(args: &Args) -> Result<()> {
        let dir = args.get_or("artifacts", "artifacts");
        let mut xla = XlaEngine::new(Path::new(&dir))?;
        let mut nat = NativeEngine::new();
        let mut rng = Pcg::seeded(args.get_u64("seed", 0));

        // level 0
        let c: Vec<f32> = (0..5000).map(|_| rng.uniform_in(-0.95, 0.95) as f32).collect();
        let zx = xla.level0(&c)?;
        let zn = nat.level0(&c)?;
        let d0 = max_diff(&zx, &zn);
        println!("level0   : {} tests, max |Δz| = {d0:.2e}", c.len());

        for l in 1..=xla.max_level() {
            let b = 600usize;
            let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
            let zx = xla.ci_e(l, b, &c_ij, &m1, &m2)?;
            let zn = nat.ci_e(l, b, &c_ij, &m1, &m2)?;
            let de = max_diff(&zx, &zn);

            let rows = 40usize;
            let k = xla.k();
            let (cs, m1s, m2s) = random_s_batch(&mut rng, rows, k, l);
            let valid = vec![k as u32; rows];
            let zxs = xla.ci_s(l, rows, k, &cs, &m1s, &m2s, &valid)?;
            let zns = nat.ci_s(l, rows, k, &cs, &m1s, &m2s, &valid)?;
            let ds = max_diff(&zxs, &zns);
            println!("level {l:>2} : ci_e max |Δz| = {de:.2e}   ci_s max |Δz| = {ds:.2e}");
            if de > 2e-3 || ds > 2e-3 {
                bail!("engines disagree at level {l}: ci_e {de:.2e}, ci_s {ds:.2e}");
            }
        }
        println!("engines agree (dispatches: {})", xla.dispatches);
        Ok(())
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }

    /// Random but *valid* correlation blocks: sample (2+l) standardized
    /// variables, correlate, slice — same construction as the pytest oracle.
    pub fn random_batch(rng: &mut Pcg, b: usize, l: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let nv = 2 + l;
        let m = 64;
        let mut c_ij = Vec::with_capacity(b);
        let mut m1 = Vec::with_capacity(b * 2 * l);
        let mut m2 = Vec::with_capacity(b * l * l);
        let mut corr = vec![0.0f64; nv * nv];
        for _ in 0..b {
            random_corr(rng, nv, m, &mut corr);
            c_ij.push(corr[1] as f32);
            for s in 0..l {
                m1.push(corr[2 + s] as f32); // C[0, 2+s]
            }
            for s in 0..l {
                m1.push(corr[nv + 2 + s] as f32); // C[1, 2+s]
            }
            for a in 0..l {
                for bb in 0..l {
                    m2.push(corr[(2 + a) * nv + 2 + bb] as f32);
                }
            }
        }
        (c_ij, m1, m2)
    }

    pub fn random_s_batch(
        rng: &mut Pcg,
        rows: usize,
        k: usize,
        l: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let nv = 1 + k + l;
        let m = 64;
        let mut c_ij = Vec::with_capacity(rows * k);
        let mut m1 = Vec::with_capacity(rows * k * 2 * l);
        let mut m2 = Vec::with_capacity(rows * l * l);
        let mut corr = vec![0.0f64; nv * nv];
        for _ in 0..rows {
            random_corr(rng, nv, m, &mut corr);
            for j in 0..k {
                c_ij.push(corr[1 + j] as f32);
            }
            for j in 0..k {
                for s in 0..l {
                    m1.push(corr[1 + k + s] as f32); // C[0, S]
                }
                for s in 0..l {
                    m1.push(corr[(1 + j) * nv + 1 + k + s] as f32); // C[j, S]
                }
            }
            for a in 0..l {
                for bb in 0..l {
                    m2.push(corr[(1 + k + a) * nv + (1 + k + bb)] as f32);
                }
            }
        }
        (c_ij, m1, m2)
    }

    fn random_corr(rng: &mut Pcg, nv: usize, m: usize, out: &mut [f64]) {
        // X: m×nv with light cross-mixing, standardized, C = XᵀX/m
        let mut x = vec![0.0f64; m * nv];
        for row in 0..m {
            let shared = rng.normal() * 0.5;
            for v in 0..nv {
                x[row * nv + v] = rng.normal() + shared;
            }
        }
        for v in 0..nv {
            let mut mean = 0.0;
            for row in 0..m {
                mean += x[row * nv + v];
            }
            mean /= m as f64;
            let mut var = 0.0;
            for row in 0..m {
                let d = x[row * nv + v] - mean;
                var += d * d;
            }
            let inv = 1.0 / (var / m as f64).sqrt().max(1e-12);
            for row in 0..m {
                x[row * nv + v] = (x[row * nv + v] - mean) * inv;
            }
        }
        for a in 0..nv {
            for b in 0..nv {
                let mut acc = 0.0;
                for row in 0..m {
                    acc += x[row * nv + a] * x[row * nv + b];
                }
                out[a * nv + b] = acc / m as f64;
            }
        }
    }
}
