//! `cupc engines` — cross-check the native engine against the XLA
//! artifacts on random batches (the runtime smoke test). Requires the
//! `xla` cargo feature; without it the subcommand explains how to get it.
//!
//! Batch generation lives in `cupc::sim::batches` so the ns/test bench
//! (`cargo bench --bench engines`) drives the kernels with the exact
//! same input distribution.

#[cfg(not(feature = "xla"))]
use anyhow::Result;
#[cfg(not(feature = "xla"))]
use cupc::util::cli::Args;

#[cfg(not(feature = "xla"))]
pub fn main(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "the `engines` cross-check drives the XLA PJRT runtime and this binary was built \
         without it; rebuild with `cargo build --features xla` (and run `make artifacts` \
         for the AOT kernels) to enable it"
    )
}

#[cfg(feature = "xla")]
pub use with_xla::main;

#[cfg(feature = "xla")]
mod with_xla {
    use anyhow::{bail, Result};
    use cupc::runtime::XlaEngine;
    use cupc::sim::batches::{random_batch, random_s_batch};
    use cupc::skeleton::engine::{CiEngine, NativeEngine};
    use cupc::util::cli::Args;
    use cupc::util::rng::Pcg;
    use std::path::Path;

    pub fn main(args: &Args) -> Result<()> {
        let dir = args.get_or("artifacts", "artifacts");
        let mut xla = XlaEngine::new(Path::new(&dir))?;
        let mut nat = NativeEngine::new();
        let mut rng = Pcg::seeded(args.get_u64("seed", 0)?);

        // level 0
        let c: Vec<f32> = (0..5000).map(|_| rng.uniform_in(-0.95, 0.95) as f32).collect();
        let zx = xla.level0(&c)?;
        let zn = nat.level0(&c)?;
        let d0 = max_diff(&zx, &zn);
        println!("level0   : {} tests, max |Δz| = {d0:.2e}", c.len());

        for l in 1..=xla.max_level() {
            let b = 600usize;
            let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
            let zx = xla.ci_e(l, b, &c_ij, &m1, &m2)?;
            let zn = nat.ci_e(l, b, &c_ij, &m1, &m2)?;
            let de = max_diff(&zx, &zn);

            let rows = 40usize;
            let k = xla.k();
            let (cs, m1s, m2s) = random_s_batch(&mut rng, rows, k, l);
            let valid = vec![k as u32; rows];
            let zxs = xla.ci_s(l, rows, k, &cs, &m1s, &m2s, &valid)?;
            let zns = nat.ci_s(l, rows, k, &cs, &m1s, &m2s, &valid)?;
            let ds = max_diff(&zxs, &zns);
            println!("level {l:>2} : ci_e max |Δz| = {de:.2e}   ci_s max |Δz| = {ds:.2e}");
            if de > 2e-3 || ds > 2e-3 {
                bail!("engines disagree at level {l}: ci_e {de:.2e}, ci_s {ds:.2e}");
            }
        }
        println!("engines agree (dispatches: {})", xla.dispatches);
        Ok(())
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }
}
