//! `cupc experiment <id>` — regenerate a paper table/figure.

use anyhow::{bail, Context, Result};
use cupc::experiments::{self, fig10, ExpOpts, Scale};
use cupc::skeleton::EngineKind;
use cupc::util::cli::Args;
use std::path::PathBuf;

pub fn opts_from_args(args: &Args) -> Result<ExpOpts> {
    let scale = match args.get_or("scale", "small").as_str() {
        "small" => Scale::Small,
        "paper" => Scale::Paper,
        other => bail!("unknown scale {other:?} (small|paper)"),
    };
    let engine = match args.get_or("engine", "native").as_str() {
        "native" => EngineKind::Native,
        "xla" => EngineKind::Xla,
        other => bail!("unknown engine {other:?} (native|xla)"),
    };
    Ok(ExpOpts {
        scale,
        engine,
        reps: args.get_usize("reps", 1)?,
        artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
    })
}

pub fn main(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("experiment id required: table2|fig5|fig6|fig7|fig8|fig9|fig10")?
        .as_str();
    let opts = opts_from_args(args)?;
    eprintln!("experiment {id} scale={:?} engine={:?}", opts.scale, opts.engine);
    match id {
        "table2" => {
            let rows = experiments::table2::run(&opts)?;
            experiments::table2::print(&rows);
        }
        "fig5" => {
            let rows = experiments::fig5::run(&opts)?;
            experiments::fig5::print(&rows);
        }
        "fig6" => {
            let rows = experiments::fig6::run(&opts)?;
            experiments::fig6::print(&rows);
        }
        "fig7" => {
            // default: one sparse + one dense dataset to bound runtime
            let filter = args.get("datasets").map(|s| s.to_string());
            let maps = match &filter {
                Some(f) => {
                    let list: Vec<&str> = f.split(',').collect();
                    experiments::fig7::run(&opts, Some(&list))?
                }
                None => experiments::fig7::run(&opts, Some(&["nci60", "dream5-insilico"]))?,
            };
            experiments::fig7::print(&maps);
        }
        "fig8" => {
            let filter = args.get("datasets").map(|s| s.to_string());
            let maps = match &filter {
                Some(f) => {
                    let list: Vec<&str> = f.split(',').collect();
                    experiments::fig8::run(&opts, Some(&list))?
                }
                None => experiments::fig8::run(&opts, Some(&["nci60", "dream5-insilico"]))?,
            };
            experiments::fig8::print(&maps);
        }
        "fig9" => {
            let out = experiments::fig9::run(&opts)?;
            experiments::fig9::print(&out);
        }
        "fig10" => {
            let sweep_arg = args.get_or("sweep", "all");
            let graphs = args.get_usize("graphs", match opts.scale {
                Scale::Small => 10,
                Scale::Paper => 10,
            })?;
            let sweeps: Vec<fig10::Sweep> = if sweep_arg == "all" {
                vec![fig10::Sweep::N, fig10::Sweep::M, fig10::Sweep::D]
            } else {
                vec![fig10::Sweep::parse(&sweep_arg)
                    .with_context(|| format!("unknown sweep {sweep_arg:?} (n|m|d)"))?]
            };
            for sweep in sweeps {
                let points = fig10::run(&opts, sweep, graphs)?;
                fig10::print(&points, sweep);
            }
        }
        "ablation" => {
            let rows = experiments::ablation::run(&opts)?;
            experiments::ablation::print(&rows);
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}
