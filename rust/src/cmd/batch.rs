//! `cupc batch` — run a JSON manifest of PC jobs under one shared
//! thread budget and content-addressed result cache.
//!
//! Writes two JSON-lines files: the deterministic results stream
//! (bit-identical for any `--job-threads` / `--threads`, any
//! between-level re-lease schedule, and cold vs. warm cache — memory or
//! disk) and an observational stats sidecar (timings, lease widths,
//! per-layer cache outcomes). With `--cache-dir` the content-addressed
//! layers persist on disk, so repeated invocations — and concurrent
//! processes sharing the directory — start warm. See `service::job` for
//! the manifest schema and `service::store` for the on-disk format.

use anyhow::{Context, Result};
use cupc::service::{render_results, render_stats, run_batch, BatchOptions, Cache, Manifest};
use cupc::skeleton::available_threads;
use cupc::util::cli::{mb_to_bytes_u64, mb_to_bytes_usize, Args};
use std::path::PathBuf;

/// The cache budgets shared by `batch` and `serve`: `--cache-mb` /
/// `--cache-disk-mb` in MiB, converted with *checked* multiplication —
/// the old `get_usize(..) << 20` wrapped a huge value to a tiny/zero
/// budget in release builds (silently disabling the cache) and panicked
/// in debug.
pub fn cache_budgets_from_args(args: &Args) -> Result<(usize, u64)> {
    let cache_bytes = mb_to_bytes_usize(args.get_usize("cache-mb", 256)?, "cache-mb")?;
    let disk_bytes = mb_to_bytes_u64(args.get_u64("cache-disk-mb", 1024)?, "cache-disk-mb")?;
    Ok((cache_bytes, disk_bytes))
}

pub fn main(args: &Args) -> Result<()> {
    let manifest_path = args
        .get("manifest")
        .context("--manifest <jobs.json> required")?;
    let out = args.get_or("out", "results.jsonl");
    let stats_path = args
        .get("stats")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{out}.stats.jsonl"));
    let (cache_bytes, disk_bytes) = cache_budgets_from_args(args)?;
    let opts = BatchOptions {
        job_threads: args.get_usize("job-threads", available_threads())?,
        threads: args.get_usize("threads", available_threads())?,
        cache_bytes,
        cache_dir: args.get("cache-dir").map(PathBuf::from),
        disk_bytes,
        verbose: args.has_flag("verbose"),
    };

    if opts.cache_dir.is_none() && args.get("cache-disk-mb").is_some() {
        eprintln!("warning: --cache-disk-mb has no effect without --cache-dir");
    }

    let manifest = Manifest::load(std::path::Path::new(manifest_path))?;
    eprintln!(
        "batch: {} jobs, job-threads {}, thread budget {}, cache {} MiB{}",
        manifest.jobs.len(),
        opts.job_threads,
        opts.threads,
        opts.cache_bytes >> 20,
        match &opts.cache_dir {
            Some(d) => format!(
                ", disk cache {} ({} MiB)",
                d.display(),
                opts.disk_bytes >> 20
            ),
            None => String::new(),
        }
    );

    let t = cupc::util::timer::Timer::start();
    let cache = Cache::new(opts.cache_bytes);
    let output = run_batch(&manifest, &opts, &cache)?;
    std::fs::write(&out, render_results(&manifest.jobs, &output.reports))
        .with_context(|| format!("writing {out}"))?;
    std::fs::write(
        &stats_path,
        render_stats(
            &manifest.jobs,
            &output.reports,
            &output.cache,
            output.disk.as_ref(),
        ),
    )
    .with_context(|| format!("writing {stats_path}"))?;

    println!("== batch results ==");
    for (spec, rep) in manifest.jobs.iter().zip(&output.reports) {
        println!(
            "{:<24} {:<9} n={:<5} edges={:<6} corr={:<4} result={:<4} w={}..{} {:.3}s",
            spec.name,
            spec.variant_name(),
            rep.core.n,
            rep.core.skeleton_edges.len(),
            rep.corr_cache.name(),
            rep.result_cache.name(),
            rep.threads_used,
            rep.threads_peak,
            rep.seconds_load + rep.seconds_corr + rep.seconds_run
        );
    }
    let c = &output.cache;
    println!(
        "cache: {} hits / {} misses / {} evictions, {} entries, {} KiB in use",
        c.hits,
        c.misses,
        c.evictions,
        c.entries,
        c.bytes >> 10
    );
    if let Some(d) = &output.disk {
        println!(
            "disk:  {} hits / {} misses / {} evictions / {} dropped, {} entries, {} KiB in use",
            d.hits,
            d.misses,
            d.evictions,
            d.dropped,
            d.entries,
            d.bytes >> 10
        );
    }
    println!("wrote {out} + {stats_path} in {:.3}s", t.elapsed_s());
    Ok(())
}
