//! `cupc batch` — run a JSON manifest of PC jobs under one shared
//! thread budget and content-addressed result cache.
//!
//! Writes two JSON-lines files: the deterministic results stream
//! (bit-identical for any `--job-threads` / `--threads` and warm vs.
//! cold cache) and an observational stats sidecar (timings, lease
//! widths, cache hit/miss). See `service::job` for the manifest schema.

use anyhow::{Context, Result};
use cupc::service::{render_results, render_stats, run_batch, BatchOptions, Cache, Manifest};
use cupc::skeleton::available_threads;
use cupc::util::cli::Args;

fn hit(b: bool) -> &'static str {
    if b {
        "hit"
    } else {
        "miss"
    }
}

pub fn main(args: &Args) -> Result<()> {
    let manifest_path = args
        .get("manifest")
        .context("--manifest <jobs.json> required")?;
    let out = args.get_or("out", "results.jsonl");
    let stats_path = args
        .get("stats")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{out}.stats.jsonl"));
    let opts = BatchOptions {
        job_threads: args.get_usize("job-threads", available_threads()),
        threads: args.get_usize("threads", available_threads()),
        cache_bytes: args.get_usize("cache-mb", 256) << 20,
        verbose: args.has_flag("verbose"),
    };

    let manifest = Manifest::load(std::path::Path::new(manifest_path))?;
    eprintln!(
        "batch: {} jobs, job-threads {}, thread budget {}, cache {} MiB",
        manifest.jobs.len(),
        opts.job_threads,
        opts.threads,
        opts.cache_bytes >> 20
    );

    let t = cupc::util::timer::Timer::start();
    let cache = Cache::new(opts.cache_bytes);
    let output = run_batch(&manifest, &opts, &cache)?;
    std::fs::write(&out, render_results(&manifest.jobs, &output.reports))
        .with_context(|| format!("writing {out}"))?;
    std::fs::write(
        &stats_path,
        render_stats(&manifest.jobs, &output.reports, &output.cache),
    )
    .with_context(|| format!("writing {stats_path}"))?;

    println!("== batch results ==");
    for (spec, rep) in manifest.jobs.iter().zip(&output.reports) {
        println!(
            "{:<24} {:<9} n={:<5} edges={:<6} corr={:<4} result={:<4} {:.3}s",
            spec.name,
            spec.variant_name(),
            rep.core.n,
            rep.core.skeleton_edges.len(),
            hit(rep.corr_cache_hit),
            hit(rep.result_cache_hit),
            rep.seconds_load + rep.seconds_corr + rep.seconds_run
        );
    }
    let c = &output.cache;
    println!(
        "cache: {} hits / {} misses / {} evictions, {} entries, {} KiB in use",
        c.hits,
        c.misses,
        c.evictions,
        c.entries,
        c.bytes >> 10
    );
    println!("wrote {out} + {stats_path} in {:.3}s", t.elapsed_s());
    Ok(())
}
