//! `cupc simulate` — generate a synthetic dataset CSV (paper §5.6).

use anyhow::{Context, Result};
use cupc::data::csv::write_csv;
use cupc::sim::datasets;
use cupc::util::cli::Args;

pub fn main(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 1000)?;
    let m = args.get_usize("m", 10000)?;
    let d = args.get_f64("d", 0.1)?;
    let seed = args.get_u64("seed", 1)?;
    let out = args.get("out").context("--out <file.csv> required")?;

    let ds = datasets::generate_er(n, m, d, seed);
    write_csv(std::path::Path::new(out), &ds.data)?;
    // also write the ground-truth skeleton alongside for evaluation
    let truth_path = format!("{out}.truth.csv");
    let truth = ds.dag.directed_dense();
    {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(&truth_path)?);
        for i in 0..n {
            let row: Vec<String> = (0..n).map(|j| truth[i * n + j].to_string()).collect();
            writeln!(f, "{}", row.join(","))?;
        }
    }
    println!(
        "wrote {out} (n={n} m={m} d={d} seed={seed}, {} true edges) + {truth_path}",
        ds.dag.n_edges()
    );
    Ok(())
}
