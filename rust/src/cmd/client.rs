//! `cupc client` — a one-shot client for a running `cupc serve` daemon.
//!
//! Ships a manifest file over the serve protocol, reassembles the
//! streamed records into a results file byte-identical to what `cupc
//! batch` would write for the same manifest, and can probe liveness
//! (`--ping`) or fetch the daemon's stats record (`--stats`). The CI
//! serve-smoke job drives the daemon entirely through this subcommand.

use anyhow::{Context, Result};
use cupc::service::proto::Priority;
use cupc::service::server::Client;
use cupc::util::cli::Args;

pub fn main(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7717");
    let mut client = Client::connect(&addr)?;
    if args.has_flag("ping") {
        client.ping()?;
        println!("pong");
        return Ok(());
    }
    if args.has_flag("stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }
    let manifest_path = args
        .get("manifest")
        .context("--manifest <jobs.json> required (or --ping / --stats)")?;
    let priority = Priority::parse(&args.get_or("priority", "normal"))?;
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading manifest {manifest_path}"))?;
    let results = client.submit(&text, priority)?;
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, &results).with_context(|| format!("writing {out}"))?;
            eprintln!("client: wrote {} record(s) to {out}", results.lines().count());
        }
        None => print!("{results}"),
    }
    Ok(())
}
