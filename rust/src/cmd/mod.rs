//! CLI subcommand dispatch (binary-only module).

pub mod batch;
pub mod client;
pub mod engines;
pub mod experiment;
pub mod run;
pub mod serve;
pub mod shard;
pub mod simulate;

use anyhow::{bail, Result};
use cupc::util::cli::Args;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => run::main(args),
        Some("batch") => batch::main(args),
        Some("serve") => serve::main(args),
        Some("shard") => shard::main(args),
        Some("client") => client::main(args),
        Some("simulate") => simulate::main(args),
        Some("experiment") => experiment::main(args),
        Some("engines") => engines::main(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

pub const USAGE: &str = "\
cupc — GPU-schedule parallel PC-stable (cuPC reproduction)

USAGE:
  cupc run --dataset <name|csv> [--variant cups|cupe|serial|parcpu|b1|b2|reversed|lingam]
           [--engine native|xla] [--alpha 0.01] [--max-level L]
           [--beta B --gamma G --theta T --delta D] [--threads N]
           [--orient standard|majority] [--verbose]
  cupc batch --manifest jobs.json [--out results.jsonl] [--stats FILE]
           [--job-threads J] [--threads N] [--cache-mb 256]
           [--cache-dir DIR] [--cache-disk-mb 1024] [--verbose]
  cupc shard --manifest jobs.json --workers K --store DIR
           [--out results.jsonl] [--stats FILE] [--threads N]
           [--adjacency auto|dense|sparse] [--window-runs R]
           [--window-slots S]
  cupc serve [--addr 127.0.0.1:7717] [--threads N] [--cache-mb 256]
           [--cache-dir DIR] [--cache-disk-mb 1024] [--max-conns 16]
           [--max-queued-jobs 64] [--idle-timeout-s 300]
           [--frame-timeout-s 10] [--verbose]
  cupc client [--addr 127.0.0.1:7717] --manifest jobs.json
           [--out results.jsonl] [--priority low|normal|high]
           | --ping | --stats
  cupc simulate --n 1000 --m 10000 --d 0.1 --seed 1 --out data.csv
  cupc experiment <table2|fig5|fig6|fig7|fig8|fig9|fig10|ablation>
           [--scale small|paper] [--engine native|xla] [--reps 1]
  cupc engines [--artifacts DIR]

Datasets: nci60 mcc br51 scerevisiae saureus dream5-insilico (+ -mini)";
