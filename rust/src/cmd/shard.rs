//! `cupc shard` — run ONE manifest job with its skeleton split across
//! worker processes.
//!
//! The coordinator resolves the data source, computes the correlation
//! matrix once, publishes it plus a [`ShardPlan`] into the shared
//! `--store` directory, spawns `--workers − 1` copies of this binary in
//! the (internal) worker role, and participates itself as rank 0. Ranks
//! synchronize per skeleton round through
//! [`cupc::oocore::exchange::DiskExchange`] blobs in the same directory;
//! every rank applies the identical merged removal stream, so every
//! rank — and in particular rank 0 — finishes with the bit-identical
//! skeleton a single-process run produces. The coordinator then orients
//! and writes the same `results.jsonl` line `cupc batch` would
//! (`tests/oocore_conformance.rs` and the CI oocore-smoke job compare
//! them byte for byte).
//!
//! The store directory is the only coupling between ranks: it must be
//! shared (same filesystem) and writable by all of them.

use anyhow::{bail, ensure, Context, Result};
use cupc::api::finish_orientation;
use cupc::oocore::shard::{
    format_plan_key, parse_plan_key, publish_plan, run_skeleton_sharded, ShardPlan,
};
use cupc::service::report::{result_line, stats_line, JobReport};
use cupc::service::scheduler::load_data;
use cupc::service::{cache, CacheOutcome, DiskStore, JobResultCore, Manifest};
use cupc::skeleton::{available_threads, family, AdjMode};
use cupc::util::cli::Args;
use cupc::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;

pub fn main(args: &Args) -> Result<()> {
    if args.get("role") == Some("worker") {
        worker(args)
    } else {
        coordinator(args)
    }
}

fn parse_adj(s: &str) -> Result<AdjMode> {
    match s.to_ascii_lowercase().as_str() {
        "auto" => Ok(AdjMode::Auto),
        "dense" => Ok(AdjMode::Dense),
        "sparse" => Ok(AdjMode::Sparse),
        other => bail!("--adjacency must be auto|dense|sparse, got {other:?}"),
    }
}

fn coordinator(args: &Args) -> Result<()> {
    let manifest_path = args
        .get("manifest")
        .context("--manifest <jobs.json> required")?;
    let store_dir = PathBuf::from(
        args.get("store")
            .context("--store <DIR> required (the directory ranks exchange through)")?,
    );
    let world = args.get_usize("workers", 2)?;
    ensure!(world >= 1, "--workers must be >= 1");
    let out = args.get_or("out", "results.jsonl");
    let threads = args.get_usize("threads", available_threads())?;

    let manifest = Manifest::load(Path::new(manifest_path))?;
    ensure!(
        manifest.jobs.len() == 1,
        "cupc shard runs exactly one job per invocation; the manifest has {} \
         (split it, or use cupc batch)",
        manifest.jobs.len()
    );
    let spec = &manifest.jobs[0];
    let variant = spec.pc_variant().with_context(|| {
        format!(
            "family {} is not a PC family and cannot be sharded \
             (sharding splits the CI-test skeleton across ranks)",
            spec.variant_name()
        )
    })?;
    let fam = family::of(variant);
    ensure!(
        fam.schedule.is_some(),
        "variant {} has no batched schedule and cannot be sharded \
         (pick one of the cupc-e/cupc-s/baseline/reversed families)",
        spec.variant_name()
    );

    let mut cfg = spec.config(threads);
    if let Some(s) = args.get("adjacency") {
        cfg.ooc.adjacency = parse_adj(s)?;
    }
    cfg.ooc.window_runs = args.get_usize("window-runs", cfg.ooc.window_runs)?.max(1);
    cfg.ooc.window_slots = args.get_u64("window-slots", cfg.ooc.window_slots)?.max(1);

    let t = Timer::start();
    let data = load_data(spec).with_context(|| format!("job {:?}", spec.name))?;
    let seconds_load = t.elapsed_s();
    let t = Timer::start();
    let corr = spec.corr.matrix(&data, threads);
    let seconds_corr = t.elapsed_s();

    // the store doubles as the exchange medium: open it un-evictable so
    // a byte budget can never tear a round barrier mid-run
    let store = DiskStore::open(&store_dir, u64::MAX)?;
    let dk = cache::data_key(&data, spec.corr);
    store.put_corr(dk, &corr);
    ensure!(
        store.get_corr(dk, data.n * data.n).is_some(),
        "could not persist the correlation matrix in {} (puts are \
         best-effort; workers would starve)",
        store_dir.display()
    );
    let plan = ShardPlan::new(data.n, data.m, dk, &cfg, world);
    let key = publish_plan(&store, &plan)?;
    eprintln!(
        "shard: job {:?} n={} m={} world={} plan={}",
        spec.name,
        data.n,
        data.m,
        world,
        format_plan_key(key)
    );

    let exe = std::env::current_exe().context("resolving the cupc binary for workers")?;
    let mut children = Vec::new();
    for rank in 1..world {
        let child = Command::new(&exe)
            .arg("shard")
            .arg("--role")
            .arg("worker")
            .arg("--store")
            .arg(&store_dir)
            .arg("--plan")
            .arg(format_plan_key(key))
            .arg("--rank")
            .arg(rank.to_string())
            .spawn()
            .with_context(|| format!("spawning shard worker rank {rank}"))?;
        children.push((rank, child));
    }

    let t = Timer::start();
    let r0 = run_skeleton_sharded(store, key, 0, None);
    if r0.is_err() {
        // rank 0 died; don't leave workers polling for up to the
        // exchange timeout
        for (_, child) in &mut children {
            let _ = child.kill();
        }
    }
    let mut failures = Vec::new();
    for (rank, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
            Err(e) => failures.push(format!("rank {rank} could not be reaped: {e}")),
        }
    }
    let (_, skel) = r0?;
    ensure!(failures.is_empty(), "worker failure(s): {}", failures.join("; "));
    let ooc = skel.ooc;

    let res = finish_orientation(&corr, data.m, &cfg, skel)
        .with_context(|| format!("job {:?}", spec.name))?;
    let seconds_run = t.elapsed_s();
    let core = JobResultCore::from_pc(&res, data.n, data.m);

    std::fs::write(&out, format!("{}\n", result_line(spec, &core)))
        .with_context(|| format!("writing {out}"))?;
    if let Some(stats_path) = args.get("stats") {
        let rep = JobReport {
            core: Arc::new(core.clone()),
            seconds_load,
            seconds_corr,
            seconds_run,
            // a sharded run always computes fresh (results are identical
            // to the cached single-process bytes anyway)
            corr_cache: CacheOutcome::Miss,
            result_cache: CacheOutcome::Miss,
            threads_used: threads,
            threads_peak: threads,
            adjacency: ooc.adjacency,
            peak_window_bytes: ooc.peak_window_bytes,
        };
        std::fs::write(stats_path, format!("{}\n", stats_line(spec, &rep)))
            .with_context(|| format!("writing {stats_path}"))?;
    }
    println!(
        "{:<24} {:<9} n={:<5} edges={:<6} world={} adjacency={} peak_window_bytes={} {:.3}s",
        spec.name,
        spec.variant_name(),
        core.n,
        core.skeleton_edges.len(),
        world,
        ooc.adjacency,
        ooc.peak_window_bytes,
        seconds_load + seconds_corr + seconds_run
    );
    println!("wrote {out}");
    Ok(())
}

/// The internal worker role (`--role worker`): join the exchange as the
/// given rank, run the sharded skeleton to completion, and exit. The
/// skeleton result itself stays in this process — correctness is
/// enforced by the exchange protocol (every rank applies the identical
/// removal stream), not by shipping graphs back.
fn worker(args: &Args) -> Result<()> {
    let store_dir = args
        .get("store")
        .context("--store <DIR> required for the worker role")?;
    let plan_hex = args
        .get("plan")
        .context("--plan <HEX> required for the worker role")?;
    let rank: usize = args
        .get("rank")
        .context("--rank <R> required for the worker role")?
        .parse()
        .context("--rank must be a non-negative integer")?;
    let store = DiskStore::open(Path::new(store_dir), u64::MAX)?;
    let key = parse_plan_key(plan_hex)?;
    let (plan, skel) = run_skeleton_sharded(store, key, rank, None)?;
    eprintln!(
        "shard worker rank {rank}/{}: {} edges, adjacency {}",
        plan.world,
        skel.graph.n_edges(),
        skel.ooc.adjacency
    );
    Ok(())
}
