//! `cupc run` — one engine family on a registry dataset or CSV file.
//!
//! `--variant` accepts any name or alias from the top-level engine-family
//! registry: the seven PC schedules print the usual CPDAG summary, while
//! causal-order families (`lingam`) print the recovered order and the
//! regression-pruned DAG.

use anyhow::{bail, Context, Result};
use cupc::data::csv::load_csv;
use cupc::metrics::{level_time_shares, skeleton_metrics};
use cupc::prelude::*;
use cupc::sim::datasets;
use cupc::stats::corr::DataMatrix;
use cupc::util::cli::Args;
use std::path::PathBuf;

pub fn config_from_args(args: &Args) -> Result<(Config, FamilyId)> {
    let base = Config::default();
    let mut cfg = Config {
        alpha: args.get_f64("alpha", base.alpha)?,
        threads: args.get_usize("threads", base.threads)?,
        beta: args.get_usize("beta", base.beta)?,
        gamma: args.get_usize("gamma", base.gamma)?,
        theta: args.get_usize("theta", base.theta)?,
        delta: args.get_usize("delta", base.delta)?,
        artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
        verbose: args.has_flag("verbose"),
        ..base
    };
    if let Some(l) = args.get("max-level") {
        cfg.max_level = Some(l.parse().context("--max-level")?);
    }
    let mut family = FamilyId::Pc(cfg.variant);
    if let Some(v) = args.get("variant") {
        family = cupc::family::parse(v)
            .with_context(|| format!("unknown variant {v:?}"))?;
        if let Some(variant) = family.variant() {
            cfg.variant = variant;
        }
    }
    cfg.engine = match args.get_or("engine", "native").as_str() {
        "native" => EngineKind::Native,
        "xla" => EngineKind::Xla,
        other => bail!("unknown engine {other:?} (native|xla)"),
    };
    cfg.orient = match args.get_or("orient", "standard").as_str() {
        "standard" => cupc::skeleton::OrientRule::Standard,
        "majority" => cupc::skeleton::OrientRule::Majority,
        other => bail!("unknown orient rule {other:?} (standard|majority)"),
    };
    Ok((cfg, family))
}

pub fn main(args: &Args) -> Result<()> {
    let (cfg, family) = config_from_args(args)?;
    let name = args
        .get("dataset")
        .context("--dataset <registry name or .csv path> required")?;

    let (data, truth) = if name.ends_with(".csv") {
        let (d, _names) = load_csv(std::path::Path::new(name))?;
        (d, None)
    } else {
        let spec = datasets::spec(name)
            .with_context(|| format!("unknown dataset {name:?} (see `cupc` for the list)"))?;
        let ds = datasets::generate(spec);
        (ds.data, Some(ds.dag.skeleton_dense()))
    };

    eprintln!(
        "running {} engine={:?} on {name}: n={} m={} alpha={}",
        cupc::family::of(family).name,
        cfg.engine,
        data.n,
        data.m,
        cfg.alpha
    );
    match cupc::api::run_family(family, &data, &cfg)? {
        EngineResult::Pc(res) => print_pc(&res, &data, truth.as_deref()),
        EngineResult::Order(res) => print_order(&res, &data, truth.as_deref()),
    }
    Ok(())
}

fn print_pc(res: &PcResult, data: &DataMatrix, truth: Option<&[u8]>) {
    println!("== result ==");
    println!("variables        : {}", data.n);
    println!("samples          : {}", data.m);
    println!("edges (skeleton) : {}", res.skeleton.graph.n_edges());
    println!("directed edges   : {}", res.cpdag.directed_edges().len());
    println!("undirected edges : {}", res.cpdag.undirected_edges().len());
    println!("corr time        : {:.3}s", res.corr_seconds);
    println!("skeleton time    : {:.3}s", res.skeleton.total_seconds());
    println!("orient time      : {:.3}s", res.orient_seconds);
    println!("total time       : {:.3}s", res.total_seconds());
    println!("CI tests         : {}", res.skeleton.total_tests());
    println!(
        "orientation      : {} triples, {} census tests, {} meek sweeps",
        res.orient.triples, res.orient.census_tests, res.orient.meek_sweeps
    );
    println!("-- per level --");
    for (ls, (lvl, share)) in res
        .skeleton
        .levels
        .iter()
        .zip(level_time_shares(&res.skeleton.levels))
    {
        println!(
            "level {lvl}: tests={} removed={} edges_after={} time={:.3}s ({share:.1}%)",
            ls.tests, ls.removed, ls.edges_after, ls.seconds
        );
    }
    if let Some(truth) = truth {
        print_truth(&res.skeleton.graph.snapshot(), truth, data.n);
    }
}

fn print_order(res: &OrderResult, data: &DataMatrix, truth: Option<&[u8]>) {
    println!("== result ==");
    println!("variables        : {}", data.n);
    println!("samples          : {}", data.m);
    println!("directed edges   : {}", res.edges.len());
    println!("total time       : {:.3}s", res.seconds);
    println!(
        "causal order     : {}",
        res.order
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!("-- per round --");
    for ls in &res.rounds {
        println!(
            "round {}: measures={} active_after={} time={:.3}s",
            ls.level, ls.tests, ls.edges_after, ls.seconds
        );
    }
    println!("-- edges (cause -> effect : weight) --");
    for &(i, j, w) in &res.edges {
        println!("{i} -> {j} : {w:+.4}");
    }
    if let Some(truth) = truth {
        let mut est = vec![0u8; data.n * data.n];
        for &(i, j, _) in &res.edges {
            est[i * data.n + j] = 1;
            est[j * data.n + i] = 1;
        }
        print_truth(&est, truth, data.n);
    }
}

fn print_truth(est: &[u8], truth: &[u8], n: usize) {
    let m = skeleton_metrics(est, truth, n);
    println!("-- vs ground truth --");
    println!(
        "TP={} FP={} FN={} precision={:.3} recall={:.3} F1={:.3}",
        m.tp, m.fp, m.fn_, m.precision, m.recall, m.f1
    );
}
