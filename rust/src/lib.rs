//! # cupc — parallel PC-stable causal structure learning
//!
//! A reproduction of *"cuPC: CUDA-based Parallel PC Algorithm for Causal
//! Structure Learning on GPU"* (Zarebavani et al., IEEE TPDS 2019) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: the PC-stable level loop,
//!   adjacency compaction, combination enumeration, batch packing, early
//!   termination, sepset bookkeeping and edge orientation.
//! * **L2/L1 (python/compile, build-time only)** — JAX computations
//!   wrapping Pallas kernels for the CI-test hot spot, AOT-lowered to HLO
//!   text artifacts.
//! * **Runtime** — [`runtime`] loads the artifacts through the XLA PJRT
//!   CPU client and executes them from the L3 hot loop. Python is never
//!   on the request path.
//!
//! Entry point: [`api::pc_stable_corr`] / [`api::pc_stable_data`]
//! (or the `cupc` binary). Fleets of runs — many datasets, alphas,
//! correlation kinds — go through the [`service`] batch layer
//! (`cupc batch`), which schedules jobs under one thread budget and
//! caches correlation matrices and results content-addressed.
//!
//! The same execution frame hosts more than CI-test PC: the [`family`]
//! registry holds every engine family across two kinds — PC round
//! schedules and causal-order engines ([`lingam`], ParaLiNGAM) — and
//! the service, CLI, and cache layers dispatch on it uniformly.

pub mod api;
pub mod data;
pub mod experiments;
pub mod family;
pub mod graph;
pub mod lingam;
pub mod metrics;
pub mod oocore;
pub mod orient;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod skeleton;
pub mod stats;
pub mod util;

pub mod prelude {
    //! Convenient re-exports for downstream users.
    pub use crate::api::{pc_stable_corr, pc_stable_data, EngineResult, OrderResult, PcResult};
    pub use crate::family::FamilyId;
    pub use crate::graph::adj::AdjMatrix;
    pub use crate::graph::cpdag::Cpdag;
    pub use crate::skeleton::{Config, EngineKind, Variant};
    pub use crate::stats::corr::correlation_matrix;
}
