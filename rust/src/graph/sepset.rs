//! Separation-set storage: SepSet[i,j] = the conditioning set S that
//! rendered Vi ⊥ Vj | S during skeleton discovery. Needed by the
//! v-structure orientation step (a v-structure i → k ← j is declared iff
//! k ∉ SepSet(i,j)).
//!
//! Concurrent writers are fine: each (i,j) is written at most once per
//! run because only the thread that *wins* the edge removal stores S
//! (matching the paper's "store S in SepSet" right after removal).
//!
//! # Level-0 complement representation
//!
//! At level 0 every removed pair is separated by the *empty* set. For a
//! sparse graph at large n that is almost all of the n(n−1)/2 pairs —
//! storing each as a `HashMap` entry holding an empty `Vec` costs
//! gigabytes at n = 10 000 and is the single largest memory term of a
//! big run. The out-of-core path therefore records level 0 as its
//! **complement**: the (small) sorted list of pairs that *survived*,
//! via [`SepSets::store_empty_complement`]. Every read path —
//! [`SepSets::get`], [`SepSets::contains`], [`SepSets::len`],
//! [`SepSets::sorted_entries`] — answers exactly as if each removed
//! pair had been stored with an explicit empty set, so the two
//! representations are observationally interchangeable (pinned by the
//! tests below and by `tests/oocore_conformance.rs`).

use std::collections::HashMap;
use std::sync::Mutex;

struct Level0Complement {
    n: usize,
    /// sorted (i, j) with i < j: the pairs that SURVIVED level 0
    survivors: Vec<(u32, u32)>,
}

impl Level0Complement {
    /// True iff `key` is a pair this complement declares removed at
    /// level 0 (i.e. a valid i<j pair absent from the survivor list).
    fn covered(&self, key: (u32, u32)) -> bool {
        key.0 < key.1
            && (key.1 as usize) < self.n
            && self.survivors.binary_search(&key).is_err()
    }

    /// Number of pairs the complement represents.
    fn removed_pairs(&self) -> usize {
        self.n * (self.n - 1) / 2 - self.survivors.len()
    }
}

struct Inner {
    map: HashMap<(u32, u32), Vec<u32>>,
    level0: Option<Level0Complement>,
}

pub struct SepSets {
    inner: Mutex<Inner>,
}

impl Default for SepSets {
    fn default() -> Self {
        Self::new()
    }
}

impl SepSets {
    pub fn new() -> Self {
        SepSets {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                level0: None,
            }),
        }
    }

    fn key(i: usize, j: usize) -> (u32, u32) {
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        (a as u32, b as u32)
    }

    /// Record level 0 by complement: every valid pair NOT in
    /// `survivors` (sorted, i < j) reads back as separated by the empty
    /// set. Must be called before any explicit store for those pairs —
    /// the out-of-core driver calls it once, right after the level-0
    /// sweep, before any deeper level runs.
    pub fn store_empty_complement(&self, n: usize, survivors: Vec<(u32, u32)>) {
        debug_assert!(survivors.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.level0.is_none(), "complement stored once per run");
        g.level0 = Some(Level0Complement { n, survivors });
    }

    /// Record S for the removed edge (i,j). First write wins — a pair
    /// already covered by the level-0 complement is a no-op, exactly as
    /// if its empty set had been stored explicitly first.
    pub fn store(&self, i: usize, j: usize, s: &[u32]) {
        let key = Self::key(i, j);
        let mut g = self.inner.lock().unwrap();
        if g.level0.as_ref().is_some_and(|c| c.covered(key)) {
            return;
        }
        g.map.entry(key).or_insert_with(|| s.to_vec());
    }

    pub fn get(&self, i: usize, j: usize) -> Option<Vec<u32>> {
        let key = Self::key(i, j);
        let g = self.inner.lock().unwrap();
        if let Some(s) = g.map.get(&key) {
            return Some(s.clone());
        }
        if g.level0.as_ref().is_some_and(|c| c.covered(key)) {
            return Some(Vec::new());
        }
        None
    }

    pub fn contains(&self, i: usize, j: usize, k: usize) -> bool {
        // complement pairs hold the empty set, which contains nothing,
        // so only the explicit map can answer true
        self.inner
            .lock()
            .unwrap()
            .map
            .get(&Self::key(i, j))
            .map(|s| s.contains(&(k as u32)))
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.map.len() + g.level0.as_ref().map_or(0, |c| c.removed_pairs())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic dump sorted by key (for tests / golden
    /// comparisons). Materializes any complement pairs, so this is
    /// O(n²) under the out-of-core representation — test-sized use only.
    pub fn sorted_entries(&self) -> Vec<((u32, u32), Vec<u32>)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.map.iter().map(|(k, s)| (*k, s.clone())).collect();
        if let Some(c) = &g.level0 {
            for i in 0..c.n as u32 {
                for j in (i + 1)..c.n as u32 {
                    if c.covered((i, j)) {
                        v.push(((i, j), Vec::new()));
                    }
                }
            }
        }
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_get_symmetric() {
        let s = SepSets::new();
        s.store(3, 1, &[7, 9]);
        assert_eq!(s.get(1, 3), Some(vec![7, 9]));
        assert_eq!(s.get(3, 1), Some(vec![7, 9]));
        assert!(s.get(1, 2).is_none());
    }

    #[test]
    fn first_write_wins() {
        let s = SepSets::new();
        s.store(0, 1, &[5]);
        s.store(1, 0, &[6]);
        assert_eq!(s.get(0, 1), Some(vec![5]));
    }

    #[test]
    fn contains_checks_membership() {
        let s = SepSets::new();
        s.store(2, 4, &[1, 3]);
        assert!(s.contains(2, 4, 3));
        assert!(!s.contains(2, 4, 9));
        assert!(!s.contains(0, 1, 3), "missing pair is not separated");
    }

    #[test]
    fn empty_set_is_stored() {
        let s = SepSets::new();
        s.store(0, 1, &[]);
        assert_eq!(s.get(0, 1), Some(vec![]));
        assert!(!s.contains(0, 1, 0));
    }

    #[test]
    fn sorted_entries_deterministic() {
        let s = SepSets::new();
        s.store(5, 2, &[0]);
        s.store(1, 3, &[4]);
        let e = s.sorted_entries();
        assert_eq!(e[0].0, (1, 3));
        assert_eq!(e[1].0, (2, 5));
    }

    /// The complement representation must be observationally identical
    /// to storing every removed pair with an explicit empty set.
    #[test]
    fn complement_matches_explicit_empty_stores() {
        let n = 6usize;
        // survivors of a fictional level 0
        let survivors = vec![(0u32, 2u32), (1, 4), (3, 5)];
        let dense = SepSets::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if !survivors.contains(&(i as u32, j as u32)) {
                    dense.store(i, j, &[]);
                }
            }
        }
        let sparse = SepSets::new();
        sparse.store_empty_complement(n, survivors.clone());

        assert_eq!(dense.len(), sparse.len());
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                assert_eq!(dense.get(i, j), sparse.get(i, j), "get({i},{j})");
                for k in 0..n {
                    assert_eq!(
                        dense.contains(i, j, k),
                        sparse.contains(i, j, k),
                        "contains({i},{j},{k})"
                    );
                }
            }
        }
        assert_eq!(dense.sorted_entries(), sparse.sorted_entries());
    }

    /// Later-level stores layer identically over either representation:
    /// a covered pair's store is a no-op (first-write-wins with the
    /// level-0 empty set) and a survivor's store lands in the map.
    #[test]
    fn complement_respects_first_write_wins() {
        let sparse = SepSets::new();
        sparse.store_empty_complement(4, vec![(0, 1), (2, 3)]);
        // (0,2) was removed at level 0: storing again must not override
        sparse.store(0, 2, &[9]);
        assert_eq!(sparse.get(0, 2), Some(vec![]));
        // (2,3) survived: a later-level store is the first write
        sparse.store(2, 3, &[0]);
        assert_eq!(sparse.get(2, 3), Some(vec![0]));
        assert!(sparse.contains(2, 3, 0));
        // (0,1) survived to the end: never separated
        assert_eq!(sparse.get(0, 1), None);
    }
}
