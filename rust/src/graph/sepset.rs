//! Separation-set storage: SepSet[i,j] = the conditioning set S that
//! rendered Vi ⊥ Vj | S during skeleton discovery. Needed by the
//! v-structure orientation step (a v-structure i → k ← j is declared iff
//! k ∉ SepSet(i,j)).
//!
//! Concurrent writers are fine: each (i,j) is written at most once per
//! run because only the thread that *wins* the edge removal stores S
//! (matching the paper's "store S in SepSet" right after removal).

use std::collections::HashMap;
use std::sync::Mutex;

pub struct SepSets {
    inner: Mutex<HashMap<(u32, u32), Vec<u32>>>,
}

impl Default for SepSets {
    fn default() -> Self {
        Self::new()
    }
}

impl SepSets {
    pub fn new() -> Self {
        SepSets {
            inner: Mutex::new(HashMap::new()),
        }
    }

    fn key(i: usize, j: usize) -> (u32, u32) {
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        (a as u32, b as u32)
    }

    /// Record S for the removed edge (i,j). First write wins.
    pub fn store(&self, i: usize, j: usize, s: &[u32]) {
        let mut g = self.inner.lock().unwrap();
        g.entry(Self::key(i, j)).or_insert_with(|| s.to_vec());
    }

    pub fn get(&self, i: usize, j: usize) -> Option<Vec<u32>> {
        self.inner.lock().unwrap().get(&Self::key(i, j)).cloned()
    }

    pub fn contains(&self, i: usize, j: usize, k: usize) -> bool {
        self.inner
            .lock()
            .unwrap()
            .get(&Self::key(i, j))
            .map(|s| s.contains(&(k as u32)))
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic dump sorted by key (for tests / golden comparisons).
    pub fn sorted_entries(&self) -> Vec<((u32, u32), Vec<u32>)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.iter().map(|(k, s)| (*k, s.clone())).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_get_symmetric() {
        let s = SepSets::new();
        s.store(3, 1, &[7, 9]);
        assert_eq!(s.get(1, 3), Some(vec![7, 9]));
        assert_eq!(s.get(3, 1), Some(vec![7, 9]));
        assert!(s.get(1, 2).is_none());
    }

    #[test]
    fn first_write_wins() {
        let s = SepSets::new();
        s.store(0, 1, &[5]);
        s.store(1, 0, &[6]);
        assert_eq!(s.get(0, 1), Some(vec![5]));
    }

    #[test]
    fn contains_checks_membership() {
        let s = SepSets::new();
        s.store(2, 4, &[1, 3]);
        assert!(s.contains(2, 4, 3));
        assert!(!s.contains(2, 4, 9));
        assert!(!s.contains(0, 1, 3), "missing pair is not separated");
    }

    #[test]
    fn empty_set_is_stored() {
        let s = SepSets::new();
        s.store(0, 1, &[]);
        assert_eq!(s.get(0, 1), Some(vec![]));
        assert!(!s.contains(0, 1, 0));
    }

    #[test]
    fn sorted_entries_deterministic() {
        let s = SepSets::new();
        s.store(5, 2, &[0]);
        s.store(1, 3, &[4]);
        let e = s.sorted_entries();
        assert_eq!(e[0].0, (1, 3));
        assert_eq!(e[1].0, (2, 5));
    }
}
