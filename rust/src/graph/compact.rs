//! The compacted adjacency `A'_G` of the paper (Fig. 2): for every row i,
//! the sorted list of current neighbors. Built from a frozen snapshot of
//! `A_G` at the start of each level (the `G'` of PC-stable), it is the
//! structure conditioning sets are drawn from.
//!
//! The paper compacts on the GPU with a parallel scan; here compaction is
//! a cheap O(n²) pass the coordinator performs once per level (measured
//! in the level timings, as the paper includes it too).

/// Compacted adjacency: CSR-like, rows sorted ascending.
#[derive(Clone, Debug)]
pub struct CompactAdj {
    n: usize,
    /// concatenated neighbor lists
    items: Vec<u32>,
    /// row offsets, len n+1
    offsets: Vec<u32>,
}

impl CompactAdj {
    /// Build from a dense row-major 0/1 snapshot.
    pub fn from_snapshot(snap: &[u8], n: usize) -> Self {
        assert_eq!(snap.len(), n * n);
        let mut items = Vec::new();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u32);
        for i in 0..n {
            for j in 0..n {
                if snap[i * n + j] != 0 {
                    items.push(j as u32);
                }
            }
            offsets.push(items.len() as u32);
        }
        CompactAdj { n, items, offsets }
    }

    /// Build directly from CSR parts — the out-of-core sparse adjacency
    /// compacts its live neighbor lists straight into this form without
    /// ever materializing the O(n²) dense snapshot. Rows must be sorted
    /// ascending and `offsets` must have length n+1 with `offsets[0]==0`
    /// and `offsets[n]==items.len()` (debug-asserted).
    pub fn from_parts(n: usize, items: Vec<u32>, offsets: Vec<u32>) -> Self {
        debug_assert_eq!(offsets.len(), n + 1);
        debug_assert_eq!(offsets.first().copied(), Some(0));
        debug_assert_eq!(offsets.last().copied(), Some(items.len() as u32));
        CompactAdj { n, items, offsets }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbors of row i (sorted).
    #[inline]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.items[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// n'_i — number of neighbors of i.
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// n' = max_i n'_i.
    pub fn max_row_len(&self) -> usize {
        (0..self.n).map(|i| self.row_len(i)).max().unwrap_or(0)
    }

    /// Total directed entries (2 × undirected edge count).
    pub fn total_entries(&self) -> usize {
        self.items.len()
    }

    /// The row with j removed, materialized into `out` (the candidate
    /// pool `adj(Vi, G') \ {Vj}` of Algorithm 1 line 8).
    pub fn row_without(&self, i: usize, j: usize, out: &mut Vec<u32>) {
        out.clear();
        out.extend(self.row(i).iter().copied().filter(|&x| x as usize != j));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::adj::AdjMatrix;

    fn example_graph() -> AdjMatrix {
        // the Fig. 2 style example: 5 nodes, some removals
        let g = AdjMatrix::complete(5);
        g.remove_edge(0, 3);
        g.remove_edge(1, 4);
        g.remove_edge(2, 3);
        g
    }

    #[test]
    fn rows_match_neighbors() {
        let g = example_graph();
        let c = CompactAdj::from_snapshot(&g.snapshot(), g.n());
        for i in 0..5 {
            let want: Vec<u32> = g.neighbors(i).iter().map(|&x| x as u32).collect();
            assert_eq!(c.row(i), &want[..], "row {i}");
            assert_eq!(c.row_len(i), want.len());
        }
    }

    #[test]
    fn max_row_len() {
        let g = example_graph();
        let c = CompactAdj::from_snapshot(&g.snapshot(), g.n());
        assert_eq!(c.max_row_len(), 3);
        assert_eq!(c.total_entries(), 2 * g.n_edges());
    }

    #[test]
    fn empty_graph() {
        let g = AdjMatrix::empty(4);
        let c = CompactAdj::from_snapshot(&g.snapshot(), 4);
        assert_eq!(c.max_row_len(), 0);
        assert_eq!(c.total_entries(), 0);
        assert!(c.row(2).is_empty());
    }

    #[test]
    fn row_without_filters() {
        let g = example_graph();
        let c = CompactAdj::from_snapshot(&g.snapshot(), g.n());
        let mut out = Vec::new();
        c.row_without(0, 2, &mut out);
        assert_eq!(out, vec![1, 4]);
        c.row_without(0, 9, &mut out); // j not present: row unchanged
        assert_eq!(out, vec![1, 2, 4]);
    }

    #[test]
    fn compaction_is_frozen_snapshot() {
        // removals after compaction must not affect it: the G' semantics.
        let g = example_graph();
        let c = CompactAdj::from_snapshot(&g.snapshot(), g.n());
        let before = c.row(0).to_vec();
        g.remove_edge(0, 1);
        assert_eq!(c.row(0), &before[..]);
    }
}
