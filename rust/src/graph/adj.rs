//! Dense symmetric adjacency matrix with lock-free concurrent edge
//! removal — the Rust analogue of cuPC's `A_G` updated by many threads.
//!
//! Edges are stored as `AtomicU8` so the threaded CPU engine and any
//! future multi-worker coordinator can remove edges while other workers
//! keep testing; removal is monotone (1 → 0 only), which is exactly the
//! property PC-stable's order-independence relies on.

use std::sync::atomic::{AtomicU8, Ordering};

/// The one mutation the skeleton's apply stage needs from an adjacency
/// representation: symmetric monotone edge removal with a first-win
/// answer. Implemented by the dense [`AdjMatrix`], the out-of-core
/// [`crate::oocore::sparse::SparseAdj`], and the [`crate::oocore::sparse::Adj`]
/// dispatch enum, so `Removals::apply` works on any of them.
pub trait EdgeRemove {
    /// Remove (i,j) symmetrically; true iff this call removed it.
    fn remove_edge(&self, i: usize, j: usize) -> bool;
}

pub struct AdjMatrix {
    n: usize,
    a: Vec<AtomicU8>,
}

impl EdgeRemove for AdjMatrix {
    fn remove_edge(&self, i: usize, j: usize) -> bool {
        AdjMatrix::remove_edge(self, i, j)
    }
}

impl AdjMatrix {
    /// Fully connected undirected graph over n variables (no self loops).
    pub fn complete(n: usize) -> Self {
        let mut a = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                a.push(AtomicU8::new(u8::from(i != j)));
            }
        }
        AdjMatrix { n, a }
    }

    /// Empty graph.
    pub fn empty(n: usize) -> Self {
        let a = (0..n * n).map(|_| AtomicU8::new(0)).collect();
        AdjMatrix { n, a }
    }

    /// Build from a row-major 0/1 matrix (symmetrized with OR).
    pub fn from_dense(d: &[u8], n: usize) -> Self {
        assert_eq!(d.len(), n * n);
        let g = AdjMatrix::empty(n);
        for i in 0..n {
            for j in 0..n {
                if i != j && (d[i * n + j] != 0 || d[j * n + i] != 0) {
                    g.a[i * n + j].store(1, Ordering::Relaxed);
                }
            }
        }
        g
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.a[i * self.n + j].load(Ordering::Relaxed) != 0
    }

    /// Remove (i,j) symmetrically. Returns true if this call removed it
    /// (false if it was already gone — the "another thread won" case).
    pub fn remove_edge(&self, i: usize, j: usize) -> bool {
        let was = self.a[i * self.n + j].swap(0, Ordering::Relaxed);
        self.a[j * self.n + i].store(0, Ordering::Relaxed);
        was != 0
    }

    pub fn add_edge(&self, i: usize, j: usize) {
        assert_ne!(i, j, "no self loops");
        self.a[i * self.n + j].store(1, Ordering::Relaxed);
        self.a[j * self.n + i].store(1, Ordering::Relaxed);
    }

    pub fn degree(&self, i: usize) -> usize {
        (0..self.n).filter(|&j| self.has_edge(i, j)).count()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&j| self.has_edge(i, j)).collect()
    }

    pub fn n_edges(&self) -> usize {
        let mut c = 0;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.has_edge(i, j) {
                    c += 1;
                }
            }
        }
        c
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.has_edge(i, j) {
                    v.push((i, j));
                }
            }
        }
        v
    }

    /// Snapshot into a plain dense matrix — the `G → G'` copy of
    /// PC-stable (Algorithm 1 line 5): conditioning sets are drawn from
    /// the frozen copy while removals mutate the live graph.
    pub fn snapshot(&self) -> Vec<u8> {
        self.a.iter().map(|x| x.load(Ordering::Relaxed)).collect()
    }

    /// Deep copy (used by engines that restart from the same input).
    pub fn clone_graph(&self) -> AdjMatrix {
        AdjMatrix::from_dense(&self.snapshot(), self.n)
    }
}

impl std::fmt::Debug for AdjMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AdjMatrix(n={}, edges={})", self.n, self.n_edges())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_graph_edge_count() {
        let g = AdjMatrix::complete(10);
        assert_eq!(g.n_edges(), 45);
        assert_eq!(g.max_degree(), 9);
        assert!(!g.has_edge(3, 3));
    }

    #[test]
    fn remove_is_symmetric_and_idempotent() {
        let g = AdjMatrix::complete(4);
        assert!(g.remove_edge(1, 2));
        assert!(!g.has_edge(1, 2));
        assert!(!g.has_edge(2, 1));
        assert!(!g.remove_edge(1, 2), "second removal must report false");
        assert!(!g.remove_edge(2, 1));
        assert_eq!(g.n_edges(), 5);
    }

    #[test]
    fn snapshot_is_frozen() {
        let g = AdjMatrix::complete(3);
        let snap = g.snapshot();
        g.remove_edge(0, 1);
        assert_eq!(snap[1], 1, "snapshot must not see later removals");
        assert!(!g.has_edge(0, 1));
    }

    #[test]
    fn neighbors_sorted() {
        let g = AdjMatrix::complete(5);
        g.remove_edge(2, 0);
        g.remove_edge(2, 4);
        assert_eq!(g.neighbors(2), vec![1, 3]);
    }

    #[test]
    fn from_dense_symmetrizes() {
        let mut d = vec![0u8; 9];
        d[1] = 1; // only 0->1 set
        let g = AdjMatrix::from_dense(&d, 3);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert_eq!(g.n_edges(), 1);
    }

    #[test]
    fn concurrent_removal_exactly_one_winner() {
        let g = std::sync::Arc::new(AdjMatrix::complete(64));
        let wins = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = g.clone();
                let wins = wins.clone();
                s.spawn(move || {
                    if g.remove_edge(10, 20) {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(wins.load(Ordering::Relaxed), 1);
    }
}
