//! Graph substrate: dense adjacency with atomic edge removal, the
//! compacted representation `A'_G` of the paper (Fig. 2), separation-set
//! storage, and the CPDAG mixed graph produced by orientation.

pub mod adj;
pub mod compact;
pub mod cpdag;
pub mod sepset;
