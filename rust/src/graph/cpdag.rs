//! Completed partially directed acyclic graph (CPDAG): the mixed graph
//! PC-stable outputs after orientation. Directed edges i→j are those
//! oriented the same way in every DAG of the Markov equivalence class;
//! the rest stay undirected.

/// Edge mark between an ordered pair.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Mark {
    None,
    /// undirected i — j
    Undirected,
    /// directed i → j
    Directed,
}

pub struct Cpdag {
    n: usize,
    /// m[i*n+j]: 0 none, 1 undirected, 2 directed i→j
    m: Vec<u8>,
}

impl Cpdag {
    pub fn new(n: usize) -> Self {
        Cpdag {
            n,
            m: vec![0; n * n],
        }
    }

    /// Start from an undirected skeleton snapshot.
    pub fn from_skeleton(snap: &[u8], n: usize) -> Self {
        let mut g = Cpdag::new(n);
        for i in 0..n {
            for j in 0..n {
                if i != j && snap[i * n + j] != 0 {
                    g.m[i * n + j] = 1;
                }
            }
        }
        g
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mark(&self, i: usize, j: usize) -> Mark {
        match self.m[i * self.n + j] {
            0 => Mark::None,
            1 => Mark::Undirected,
            _ => Mark::Directed,
        }
    }

    /// Any connection between i and j?
    pub fn adjacent(&self, i: usize, j: usize) -> bool {
        self.m[i * self.n + j] != 0 || self.m[j * self.n + i] != 0
    }

    pub fn is_undirected(&self, i: usize, j: usize) -> bool {
        self.m[i * self.n + j] == 1 && self.m[j * self.n + i] == 1
    }

    /// i → j (and not j → i)?
    pub fn is_directed(&self, i: usize, j: usize) -> bool {
        self.m[i * self.n + j] == 2
    }

    /// Orient i → j, overwriting the undirected mark.
    pub fn orient(&mut self, i: usize, j: usize) {
        self.m[i * self.n + j] = 2;
        self.m[j * self.n + i] = 0;
    }

    /// Orient only if currently undirected. Returns whether it acted.
    pub fn orient_if_undirected(&mut self, i: usize, j: usize) -> bool {
        if self.is_undirected(i, j) {
            self.orient(i, j);
            true
        } else {
            false
        }
    }

    pub fn undirected_edges(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.is_undirected(i, j) {
                    v.push((i, j));
                }
            }
        }
        v
    }

    pub fn directed_edges(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for i in 0..self.n {
            for j in 0..self.n {
                if self.is_directed(i, j) {
                    v.push((i, j));
                }
            }
        }
        v
    }

    pub fn n_edges(&self) -> usize {
        self.undirected_edges().len() + self.directed_edges().len()
    }

    /// Parents of j (i with i→j).
    pub fn parents(&self, j: usize) -> Vec<usize> {
        (0..self.n).filter(|&i| self.is_directed(i, j)).collect()
    }

    /// All neighbors regardless of mark.
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&j| self.adjacent(i, j)).collect()
    }

    /// Number of neighbors regardless of mark (the orientation
    /// pipeline's shard-weight input — no allocation).
    pub fn degree(&self, i: usize) -> usize {
        (0..self.n).filter(|&j| self.adjacent(i, j)).count()
    }

    /// Skeleton as dense 0/1 (symmetric).
    pub fn skeleton(&self) -> Vec<u8> {
        let mut s = vec![0u8; self.n * self.n];
        for i in 0..self.n {
            for j in 0..self.n {
                if self.adjacent(i, j) {
                    s[i * self.n + j] = 1;
                }
            }
        }
        s
    }

    /// Equality on marks (for order-independence tests).
    pub fn same_as(&self, other: &Cpdag) -> bool {
        self.n == other.n && self.m == other.m
    }
}

impl std::fmt::Debug for Cpdag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Cpdag(n={}, directed={}, undirected={})",
            self.n,
            self.directed_edges().len(),
            self.undirected_edges().len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_skeleton_all_undirected() {
        let snap = vec![0, 1, 1, 1, 0, 1, 1, 1, 0];
        let g = Cpdag::from_skeleton(&snap, 3);
        assert_eq!(g.undirected_edges().len(), 3);
        assert!(g.directed_edges().is_empty());
    }

    #[test]
    fn orient_replaces_undirected() {
        let snap = vec![0, 1, 1, 0];
        let mut g = Cpdag::from_skeleton(&snap, 2);
        assert!(g.is_undirected(0, 1));
        g.orient(0, 1);
        assert!(g.is_directed(0, 1));
        assert!(!g.is_directed(1, 0));
        assert!(!g.is_undirected(0, 1));
        assert!(g.adjacent(1, 0));
        assert_eq!(g.parents(1), vec![0]);
    }

    #[test]
    fn orient_if_undirected_noop_on_directed() {
        let snap = vec![0, 1, 1, 0];
        let mut g = Cpdag::from_skeleton(&snap, 2);
        assert!(g.orient_if_undirected(0, 1));
        assert!(!g.orient_if_undirected(1, 0), "must not flip an arrow");
        assert!(g.is_directed(0, 1));
    }

    #[test]
    fn skeleton_roundtrip() {
        let snap = vec![0, 1, 0, 1, 0, 1, 0, 1, 0];
        let mut g = Cpdag::from_skeleton(&snap, 3);
        g.orient(0, 1);
        assert_eq!(g.skeleton(), snap);
        assert_eq!(g.n_edges(), 2);
    }

    #[test]
    fn degree_counts_any_mark() {
        let snap = vec![0, 1, 1, 1, 0, 0, 1, 0, 0];
        let mut g = Cpdag::from_skeleton(&snap, 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 1);
        g.orient(0, 1);
        assert_eq!(g.degree(0), 2, "an arrowhead is still an adjacency");
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.neighbors(0).len(), g.degree(0));
    }

    #[test]
    fn same_as_detects_differences() {
        let snap = vec![0, 1, 1, 0];
        let a = Cpdag::from_skeleton(&snap, 2);
        let mut b = Cpdag::from_skeleton(&snap, 2);
        assert!(a.same_as(&b));
        b.orient(0, 1);
        assert!(!a.same_as(&b));
    }
}
