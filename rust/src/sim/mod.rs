//! Synthetic data generation: random DAGs, linear-SEM sampling (the
//! paper's §5.6 protocol) and the Table-1 dataset analogs.

pub mod batches;
pub mod dag;
pub mod datasets;
pub mod scenarios;
pub mod sem;
