//! Linear structural equation model sampling (paper §5.6):
//! Vi = Ni + Σ_{j<i} A[i,j]·Vj with independent noise, sampled in
//! topological order. The default noise is standard normal; the lingam
//! engine family needs *non*-Gaussian noise (linear-Gaussian SEMs are
//! only identifiable up to the Markov equivalence class), so
//! [`NoiseKind`] adds unit-variance uniform and Laplace generators.
//! `tools/lingam_oracle.py::draw_noise` mirrors these draw for draw.

use super::dag::WeightedDag;
use crate::stats::corr::DataMatrix;
use crate::util::rng::Pcg;
use std::f64::consts::FRAC_1_SQRT_2;

/// Exogenous-noise distribution for SEM sampling. Every kind is
/// zero-mean unit-variance so downstream correlation magnitudes are
/// comparable across kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseKind {
    /// Standard normal (Box-Muller) — the paper's §5.6 default.
    Gaussian,
    /// Uniform on (−√3, √3): variance (2√3)²/12 = 1.
    Uniform,
    /// Laplace with scale 1/√2 (inverse-CDF draw): variance 2·b² = 1.
    Laplace,
}

impl NoiseKind {
    /// One noise draw. Draw-identical to `lingam_oracle.py::draw_noise`.
    pub fn draw(self, rng: &mut Pcg) -> f64 {
        match self {
            NoiseKind::Gaussian => rng.normal(),
            NoiseKind::Uniform => {
                let s = 3f64.sqrt();
                rng.uniform_in(-s, s)
            }
            NoiseKind::Laplace => loop {
                let u = rng.uniform();
                if u == 0.0 {
                    // inverse CDF needs u in (0, 1); uniform() can emit
                    // exactly 0, whose image is −∞
                    continue;
                }
                let x = if u < 0.5 {
                    (2.0 * u).ln()
                } else {
                    -((2.0 * (1.0 - u)).ln())
                };
                return x * FRAC_1_SQRT_2;
            },
        }
    }
}

/// Sample `m` observations from the linear SEM induced by `dag` with
/// standard-normal noise. Returns a row-major (m × n) data matrix.
pub fn sample(dag: &WeightedDag, m: usize, rng: &mut Pcg) -> DataMatrix {
    sample_with_noise(dag, m, rng, NoiseKind::Gaussian)
}

/// [`sample`] with an explicit noise kind. The draw order (one noise
/// draw per cell, sample-major then variable-major) is identical across
/// kinds, so two kinds under one seed share a DAG but not data.
pub fn sample_with_noise(dag: &WeightedDag, m: usize, rng: &mut Pcg, noise: NoiseKind) -> DataMatrix {
    let n = dag.n;
    let mut x = vec![0.0f64; m * n];
    for s in 0..m {
        let row = &mut x[s * n..(s + 1) * n];
        for i in 0..n {
            let mut v = noise.draw(rng);
            for &(j, w) in &dag.parents[i] {
                v += w * row[j as usize];
            }
            row[i] = v;
        }
    }
    DataMatrix::new(x, m, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn deterministic_by_seed() {
        let dag = WeightedDag::random_er(10, 0.3, &mut Pcg::seeded(5));
        let a = sample(&dag, 20, &mut Pcg::seeded(6));
        let b = sample(&dag, 20, &mut Pcg::seeded(6));
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn child_correlates_with_parent() {
        // single edge 0 → 1 with strong weight
        let dag = WeightedDag {
            n: 2,
            parents: vec![vec![], vec![(0, 0.9)]],
        };
        let data = sample(&dag, 4000, &mut Pcg::seeded(7));
        let c = correlation_matrix(&data, 1);
        // rho = 0.9 / sqrt(1 + 0.81) ≈ 0.669
        assert!((c[1] - 0.669).abs() < 0.05, "c01={}", c[1]);
    }

    #[test]
    fn disconnected_variables_uncorrelated() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(1, 0.8)]],
        };
        let data = sample(&dag, 8000, &mut Pcg::seeded(8));
        let c = correlation_matrix(&data, 1);
        assert!(c[1].abs() < 0.05, "c01={}", c[1]); // 0 vs 1
        assert!(c[2].abs() < 0.05, "c02={}", c[2]); // 0 vs 2
        assert!(c[1 * 3 + 2] > 0.5, "c12={}", c[5]);
    }

    #[test]
    fn sample_is_the_gaussian_noise_kind() {
        let dag = WeightedDag::random_er(8, 0.3, &mut Pcg::seeded(12));
        let a = sample(&dag, 50, &mut Pcg::seeded(13));
        let b = sample_with_noise(&dag, 50, &mut Pcg::seeded(13), NoiseKind::Gaussian);
        assert_eq!(a.x, b.x, "sample() must stay draw-identical to Gaussian");
    }

    #[test]
    fn every_noise_kind_is_zero_mean_unit_variance() {
        let dag = WeightedDag {
            n: 1,
            parents: vec![vec![]],
        };
        for kind in [NoiseKind::Gaussian, NoiseKind::Uniform, NoiseKind::Laplace] {
            let data = sample_with_noise(&dag, 20000, &mut Pcg::seeded(14), kind);
            let m = data.x.len() as f64;
            let mean: f64 = data.x.iter().sum::<f64>() / m;
            let var: f64 = data.x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m;
            assert!(mean.abs() < 0.05, "{kind:?}: mean={mean}");
            assert!((var - 1.0).abs() < 0.06, "{kind:?}: var={var}");
        }
    }

    #[test]
    fn uniform_noise_is_bounded_and_laplace_is_not_gaussian() {
        let dag = WeightedDag {
            n: 1,
            parents: vec![vec![]],
        };
        let s = 3f64.sqrt();
        let uni = sample_with_noise(&dag, 5000, &mut Pcg::seeded(15), NoiseKind::Uniform);
        assert!(uni.x.iter().all(|v| v.abs() < s), "uniform must stay in (−√3, √3)");
        // excess kurtosis: uniform −1.2, gaussian 0, laplace +3 — the
        // separation the lingam measure feeds on
        let kurt = |xs: &[f64]| {
            let m = xs.len() as f64;
            let s4: f64 = xs.iter().map(|v| v.powi(4)).sum::<f64>() / m;
            let s2: f64 = xs.iter().map(|v| v * v).sum::<f64>() / m;
            s4 / (s2 * s2) - 3.0
        };
        let lap = sample_with_noise(&dag, 20000, &mut Pcg::seeded(16), NoiseKind::Laplace);
        assert!(kurt(&uni.x) < -0.9, "uniform kurtosis {}", kurt(&uni.x));
        assert!(kurt(&lap.x) > 1.5, "laplace kurtosis {}", kurt(&lap.x));
    }

    #[test]
    fn noise_gives_unit_ish_variance_for_roots() {
        let dag = WeightedDag {
            n: 1,
            parents: vec![vec![]],
        };
        let data = sample(&dag, 10000, &mut Pcg::seeded(9));
        let mean: f64 = data.x.iter().sum::<f64>() / data.x.len() as f64;
        let var: f64 =
            data.x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / data.x.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.05);
    }
}
