//! Linear structural equation model sampling (paper §5.6):
//! Vi = Ni + Σ_{j<i} A[i,j]·Vj with independent standard-normal noise,
//! sampled in topological order.

use super::dag::WeightedDag;
use crate::stats::corr::DataMatrix;
use crate::util::rng::Pcg;

/// Sample `m` observations from the linear SEM induced by `dag`.
/// Returns a row-major (m × n) data matrix.
pub fn sample(dag: &WeightedDag, m: usize, rng: &mut Pcg) -> DataMatrix {
    let n = dag.n;
    let mut x = vec![0.0f64; m * n];
    for s in 0..m {
        let row = &mut x[s * n..(s + 1) * n];
        for i in 0..n {
            let mut v = rng.normal();
            for &(j, w) in &dag.parents[i] {
                v += w * row[j as usize];
            }
            row[i] = v;
        }
    }
    DataMatrix::new(x, m, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::corr::correlation_matrix;

    #[test]
    fn deterministic_by_seed() {
        let dag = WeightedDag::random_er(10, 0.3, &mut Pcg::seeded(5));
        let a = sample(&dag, 20, &mut Pcg::seeded(6));
        let b = sample(&dag, 20, &mut Pcg::seeded(6));
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn child_correlates_with_parent() {
        // single edge 0 → 1 with strong weight
        let dag = WeightedDag {
            n: 2,
            parents: vec![vec![], vec![(0, 0.9)]],
        };
        let data = sample(&dag, 4000, &mut Pcg::seeded(7));
        let c = correlation_matrix(&data, 1);
        // rho = 0.9 / sqrt(1 + 0.81) ≈ 0.669
        assert!((c[1] - 0.669).abs() < 0.05, "c01={}", c[1]);
    }

    #[test]
    fn disconnected_variables_uncorrelated() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(1, 0.8)]],
        };
        let data = sample(&dag, 8000, &mut Pcg::seeded(8));
        let c = correlation_matrix(&data, 1);
        assert!(c[1].abs() < 0.05, "c01={}", c[1]); // 0 vs 1
        assert!(c[2].abs() < 0.05, "c02={}", c[2]); // 0 vs 2
        assert!(c[1 * 3 + 2] > 0.5, "c12={}", c[5]);
    }

    #[test]
    fn noise_gives_unit_ish_variance_for_roots() {
        let dag = WeightedDag {
            n: 1,
            parents: vec![vec![]],
        };
        let data = sample(&dag, 10000, &mut Pcg::seeded(9));
        let mean: f64 = data.x.iter().sum::<f64>() / data.x.len() as f64;
        let var: f64 =
            data.x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / data.x.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.05);
    }
}
