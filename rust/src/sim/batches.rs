//! Random packed-batch generators for the CI engines — *valid*
//! correlation structure, not arbitrary floats: each slot is built by
//! sampling standardized variables, correlating, and slicing, the same
//! construction as the pytest oracle in python/compile.
//!
//! Shared by the `cupc engines` cross-check (XLA vs native) and the
//! `cargo bench --bench engines` ns/test baseline, so both drive the
//! kernels with the exact same input distribution.

use crate::util::rng::Pcg;

/// A random ci_e batch: `b` slots at level `l`, laid out as
/// `c_ij[b]`, `m1[b·2·l]`, `m2[b·l·l]`.
pub fn random_batch(rng: &mut Pcg, b: usize, l: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nv = 2 + l;
    let m = 64;
    let mut c_ij = Vec::with_capacity(b);
    let mut m1 = Vec::with_capacity(b * 2 * l);
    let mut m2 = Vec::with_capacity(b * l * l);
    let mut corr = vec![0.0f64; nv * nv];
    for _ in 0..b {
        random_corr(rng, nv, m, &mut corr);
        c_ij.push(corr[1] as f32);
        for s in 0..l {
            m1.push(corr[2 + s] as f32); // C[0, 2+s]
        }
        for s in 0..l {
            m1.push(corr[nv + 2 + s] as f32); // C[1, 2+s]
        }
        for a in 0..l {
            for bb in 0..l {
                m2.push(corr[(2 + a) * nv + 2 + bb] as f32);
            }
        }
    }
    (c_ij, m1, m2)
}

/// A random ci_s batch: `rows` conditioning sets × `k` tests at level
/// `l`, laid out as `c_ij[rows·k]`, `m1[rows·k·2·l]`, `m2[rows·l·l]`.
pub fn random_s_batch(
    rng: &mut Pcg,
    rows: usize,
    k: usize,
    l: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nv = 1 + k + l;
    let m = 64;
    let mut c_ij = Vec::with_capacity(rows * k);
    let mut m1 = Vec::with_capacity(rows * k * 2 * l);
    let mut m2 = Vec::with_capacity(rows * l * l);
    let mut corr = vec![0.0f64; nv * nv];
    for _ in 0..rows {
        random_corr(rng, nv, m, &mut corr);
        for j in 0..k {
            c_ij.push(corr[1 + j] as f32);
        }
        for j in 0..k {
            for s in 0..l {
                m1.push(corr[1 + k + s] as f32); // C[0, S]
            }
            for s in 0..l {
                m1.push(corr[(1 + j) * nv + 1 + k + s] as f32); // C[j, S]
            }
        }
        for a in 0..l {
            for bb in 0..l {
                m2.push(corr[(1 + k + a) * nv + (1 + k + bb)] as f32);
            }
        }
    }
    (c_ij, m1, m2)
}

/// Fill `out` with a valid nv×nv correlation matrix: X is m×nv with
/// light cross-mixing, standardized per column, C = XᵀX/m.
fn random_corr(rng: &mut Pcg, nv: usize, m: usize, out: &mut [f64]) {
    let mut x = vec![0.0f64; m * nv];
    for row in 0..m {
        let shared = rng.normal() * 0.5;
        for v in 0..nv {
            x[row * nv + v] = rng.normal() + shared;
        }
    }
    for v in 0..nv {
        let mut mean = 0.0;
        for row in 0..m {
            mean += x[row * nv + v];
        }
        mean /= m as f64;
        let mut var = 0.0;
        for row in 0..m {
            let d = x[row * nv + v] - mean;
            var += d * d;
        }
        let inv = 1.0 / (var / m as f64).sqrt().max(1e-12);
        for row in 0..m {
            x[row * nv + v] = (x[row * nv + v] - mean) * inv;
        }
    }
    for a in 0..nv {
        for b in 0..nv {
            let mut acc = 0.0;
            for row in 0..m {
                acc += x[row * nv + a] * x[row * nv + b];
            }
            out[a * nv + b] = acc / m as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e_batch_shapes_and_ranges() {
        let mut rng = Pcg::seeded(7);
        let (b, l) = (11usize, 3usize);
        let (c_ij, m1, m2) = random_batch(&mut rng, b, l);
        assert_eq!(c_ij.len(), b);
        assert_eq!(m1.len(), b * 2 * l);
        assert_eq!(m2.len(), b * l * l);
        for &c in &c_ij {
            assert!(c.abs() <= 1.0 + 1e-5, "correlation out of range: {c}");
        }
        // M2 diagonals are exactly 1 (standardized variables)
        for s in 0..b {
            for d in 0..l {
                let v = m2[s * l * l + d * l + d];
                assert!((v - 1.0).abs() < 1e-5, "m2 diag {v}");
            }
        }
    }

    #[test]
    fn s_batch_shapes_and_symmetry() {
        let mut rng = Pcg::seeded(8);
        let (rows, k, l) = (5usize, 4usize, 2usize);
        let (c_ij, m1, m2) = random_s_batch(&mut rng, rows, k, l);
        assert_eq!(c_ij.len(), rows * k);
        assert_eq!(m1.len(), rows * k * 2 * l);
        assert_eq!(m2.len(), rows * l * l);
        for r in 0..rows {
            for a in 0..l {
                for b in 0..l {
                    let ab = m2[r * l * l + a * l + b];
                    let ba = m2[r * l * l + b * l + a];
                    assert!((ab - ba).abs() < 1e-6, "m2 not symmetric");
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic_in_the_seed() {
        let a = random_batch(&mut Pcg::seeded(42), 4, 2);
        let b = random_batch(&mut Pcg::seeded(42), 4, 2);
        assert_eq!(a, b);
    }

    /// The batches must be consumable by the native engine (valid enough
    /// correlation structure for the pinv path).
    #[test]
    fn native_engine_accepts_generated_batches() {
        use crate::skeleton::engine::{CiEngine, NativeEngine};
        let mut rng = Pcg::seeded(9);
        let mut e = NativeEngine::new();
        let l = 4;
        let (c_ij, m1, m2) = random_batch(&mut rng, 6, l);
        let z = e.ci_e(l, 6, &c_ij, &m1, &m2).unwrap();
        assert_eq!(z.len(), 6);
        assert!(z.iter().all(|v| v.is_finite()));
        let (cs, m1s, m2s) = random_s_batch(&mut rng, 3, 2, l);
        let zs = e.ci_s(l, 3, 2, &cs, &m1s, &m2s, &[2, 2, 2]).unwrap();
        assert_eq!(zs.len(), 6);
        assert!(zs.iter().all(|v| v.is_finite()));
    }
}
