//! Conformance scenario grid: a reusable set of simulated workloads over
//! which every schedule ([`crate::skeleton::Variant`]) must produce the
//! *identical* PC-stable result — the paper's §2.4 order-independence
//! invariant turned into an executable gate (used by
//! `tests/conformance_engines.rs`, and available to benches/examples).
//!
//! The grid crosses ER densities × sample counts × significance levels ×
//! `max_level` caps, all seeded through [`Pcg`] so every point is fully
//! deterministic. Sizes are chosen so the whole grid runs across all six
//! variants in CI-image time.

use super::dag::WeightedDag;
use super::sem;
use crate::skeleton::{Config, OrientRule, Variant};
use crate::stats::corr::correlation_matrix;
use crate::util::rng::Pcg;

/// One grid point: a simulated dataset plus the run parameters every
/// variant is held to.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    /// number of variables
    pub n: usize,
    /// number of samples
    pub m: usize,
    /// ER edge density of the ground-truth DAG
    pub density: f64,
    /// CI-test significance level
    pub alpha: f64,
    /// optional cap on the level loop
    pub max_level: Option<usize>,
    /// master seed (graph stream and sample stream derive from it)
    pub seed: u64,
}

impl Scenario {
    /// The run configuration for this scenario under `variant`.
    ///
    /// Orientation uses [`OrientRule::Majority`] so the *CPDAG* — not just
    /// the skeleton — is schedule-invariant and can be compared bitwise
    /// across variants (first-found sepsets are schedule-dependent; the
    /// majority census is not).
    pub fn config(&self, variant: Variant) -> Config {
        Config {
            alpha: self.alpha,
            max_level: self.max_level,
            variant,
            threads: 2,
            orient: OrientRule::Majority,
            ..Config::default()
        }
    }

    /// Generate the scenario's input: ground-truth DAG, sampled data, and
    /// the correlation matrix the skeleton runs on. Deterministic in
    /// `seed` (graph and noise draw from separate Pcg streams).
    pub fn generate(&self) -> ScenarioInput {
        let dag = WeightedDag::random_er(self.n, self.density, &mut Pcg::new(self.seed, 1));
        let data = sem::sample(&dag, self.m, &mut Pcg::new(self.seed, 2));
        let corr = correlation_matrix(&data, 1);
        ScenarioInput {
            truth: dag,
            corr,
            n: self.n,
            m: self.m,
        }
    }
}

/// Generated workload for one scenario.
pub struct ScenarioInput {
    pub truth: WeightedDag,
    /// row-major n×n correlation matrix
    pub corr: Vec<f64>,
    pub n: usize,
    pub m: usize,
}

/// The six schedules under conformance test, in a fixed order.
pub const ALL_VARIANTS: [Variant; 6] = [
    Variant::Serial,
    Variant::ParallelCpu,
    Variant::CupcE,
    Variant::CupcS,
    Variant::Baseline1,
    Variant::Baseline2,
];

/// The default conformance grid: ≥ 8 points crossing density (sparse →
/// dense), sample count (underpowered → comfortable), alpha (0.01 /
/// 0.05) and `max_level` caps (uncapped, 1, 2, 3).
pub fn default_grid() -> Vec<Scenario> {
    fn sc(
        name: &'static str,
        n: usize,
        m: usize,
        density: f64,
        alpha: f64,
        max_level: Option<usize>,
        seed: u64,
    ) -> Scenario {
        Scenario {
            name,
            n,
            m,
            density,
            alpha,
            max_level,
            seed,
        }
    }
    vec![
        sc("sparse-a01", 16, 200, 0.10, 0.01, None, 901),
        sc("sparse-a05", 16, 200, 0.10, 0.05, None, 902),
        sc("mid-lowm", 24, 150, 0.15, 0.01, None, 903),
        sc("mid-highm", 24, 600, 0.15, 0.01, None, 904),
        sc("dense-cap2", 24, 300, 0.30, 0.01, Some(2), 905),
        sc("dense-a05-cap2", 24, 300, 0.30, 0.05, Some(2), 906),
        sc("wide-lowm", 32, 120, 0.08, 0.01, None, 907),
        sc("wide-cap1", 32, 400, 0.12, 0.01, Some(1), 908),
        sc("dense-cap3", 20, 500, 0.35, 0.01, Some(3), 909),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_at_least_eight_points_with_unique_names() {
        let grid = default_grid();
        assert!(grid.len() >= 8, "grid too small: {}", grid.len());
        let mut names: Vec<&str> = grid.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), grid.len(), "duplicate scenario names");
    }

    #[test]
    fn grid_crosses_the_advertised_axes() {
        let grid = default_grid();
        let distinct = |f: fn(&Scenario) -> u64| {
            let mut v: Vec<u64> = grid.iter().map(f).collect();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        assert!(distinct(|s| (s.density * 1000.0) as u64) >= 3, "densities");
        assert!(distinct(|s| s.m as u64) >= 3, "sample counts");
        assert!(distinct(|s| (s.alpha * 1000.0) as u64) >= 2, "alphas");
        assert!(
            distinct(|s| s.max_level.map(|l| l as u64 + 1).unwrap_or(0)) >= 3,
            "max_level caps"
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let sc = &default_grid()[0];
        let a = sc.generate();
        let b = sc.generate();
        assert_eq!(a.corr, b.corr);
        assert_eq!(a.truth.skeleton_dense(), b.truth.skeleton_dense());
        assert_eq!((a.n, a.m), (sc.n, sc.m));
    }

    #[test]
    fn config_carries_scenario_parameters() {
        let sc = &default_grid()[4];
        let cfg = sc.config(Variant::CupcS);
        assert_eq!(cfg.alpha, sc.alpha);
        assert_eq!(cfg.max_level, sc.max_level);
        assert_eq!(cfg.variant, Variant::CupcS);
        assert_eq!(cfg.orient, OrientRule::Majority);
    }
}
