//! Conformance scenario grid: a reusable set of simulated workloads over
//! which every schedule ([`crate::skeleton::Variant`]) must produce the
//! *identical* PC-stable result — the paper's §2.4 order-independence
//! invariant turned into an executable gate (used by
//! `tests/conformance_engines.rs`, the batch-determinism suite in
//! `tests/batch_runner.rs`, and available to benches/examples; grid
//! points are addressable by name as `service` job sources).
//!
//! The grid crosses topologies (ER densities and GRN preferential
//! attachment) × sample counts × significance levels × `max_level` caps
//! × correlation kinds (Pearson and Spearman "Rank PC"), all seeded
//! through [`Pcg`] so every point is fully deterministic. Sizes are
//! chosen so the whole grid runs across every registered variant in
//! CI-image time.

use super::dag::WeightedDag;
use super::datasets::Topology;
use super::sem::{self, NoiseKind};
use crate::skeleton::{Config, OrientRule, Variant};
use crate::stats::corr::{CorrKind, DataMatrix};
use crate::util::rng::Pcg;

/// One grid point: a simulated dataset plus the run parameters every
/// variant is held to.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    /// number of variables
    pub n: usize,
    /// number of samples
    pub m: usize,
    /// ground-truth DAG family (ER density or GRN attachment params)
    pub topology: Topology,
    /// CI-test significance level
    pub alpha: f64,
    /// optional cap on the level loop
    pub max_level: Option<usize>,
    /// master seed (graph stream and sample stream derive from it)
    pub seed: u64,
    /// correlation estimator feeding the CI tests (ignored by
    /// causal-order families, which consume the raw data)
    pub corr: CorrKind,
    /// exogenous-noise distribution for SEM sampling; the PC grids use
    /// Gaussian, the lingam grid needs non-Gaussian noise
    pub noise: NoiseKind,
}

impl Scenario {
    /// The run configuration for this scenario under `variant`.
    ///
    /// Orientation uses [`OrientRule::Majority`] so the *CPDAG* — not just
    /// the skeleton — is schedule-invariant and can be compared bitwise
    /// across variants (first-found sepsets are schedule-dependent; the
    /// majority census is not).
    pub fn config(&self, variant: Variant) -> Config {
        Config {
            alpha: self.alpha,
            max_level: self.max_level,
            variant,
            threads: 2,
            orient: OrientRule::Majority,
            ..Config::default()
        }
    }

    /// Generate the scenario's raw inputs: ground-truth DAG + sampled
    /// data, deterministic in `seed` (graph and noise draw from separate
    /// Pcg streams). The batch service uses this to key its
    /// content-addressed cache on the data bytes.
    pub fn generate_data(&self) -> (WeightedDag, DataMatrix) {
        let mut rng_g = Pcg::new(self.seed, 1);
        let dag = match self.topology {
            Topology::Er(d) => WeightedDag::random_er(self.n, d, &mut rng_g),
            Topology::Grn(avg, maxp) => WeightedDag::random_grn(self.n, avg, maxp, &mut rng_g),
        };
        let data = sem::sample_with_noise(&dag, self.m, &mut Pcg::new(self.seed, 2), self.noise);
        (dag, data)
    }

    /// Generate the scenario's full conformance input: ground-truth DAG,
    /// sampled data, and the correlation matrix the skeleton runs on.
    pub fn generate(&self) -> ScenarioInput {
        let (dag, data) = self.generate_data();
        let corr = self.corr.matrix(&data, 1);
        ScenarioInput {
            truth: dag,
            corr,
            n: self.n,
            m: self.m,
        }
    }
}

/// Generated workload for one scenario.
pub struct ScenarioInput {
    pub truth: WeightedDag,
    /// row-major n×n correlation matrix
    pub corr: Vec<f64>,
    pub n: usize,
    pub m: usize,
}

/// Every registered schedule, under conformance test in a fixed order
/// (registry tag order — `all_variants_match_the_family_registry` keeps
/// this list and [`crate::skeleton::family::FAMILIES`] in lockstep).
pub const ALL_VARIANTS: [Variant; 7] = [
    Variant::Serial,
    Variant::ParallelCpu,
    Variant::CupcE,
    Variant::CupcS,
    Variant::Baseline1,
    Variant::Baseline2,
    Variant::Reversed,
];

/// Look up a grid point by name (the `service` job-source address).
/// Searches the default conformance grid, the out-of-core grid, and the
/// lingam (non-Gaussian) grid.
pub fn find(name: &str) -> Option<Scenario> {
    default_grid()
        .into_iter()
        .chain(oocore_grid())
        .chain(lingam_grid())
        .find(|s| s.name == name)
}

/// The lingam scenario grid: non-Gaussian-noise SEMs on which
/// DirectLiNGAM provably recovers the exact ground-truth DAG.
/// `tools/lingam_oracle.py::LINGAM_GRID` must stay in lockstep with this
/// list (name, n, m, topology, seed, noise) — its margin gate certifies
/// that every root election clears a 1e-9 score gap and every pruning
/// coefficient sits ≥ 0.01 from the 0.05 threshold, which is what lets
/// `tests/lingam_conformance.rs` pin the oracle's orders and DAGs as
/// exact expectations. `alpha`/`max_level` are inert for the lingam
/// family but keep the points runnable under PC variants too; `corr`
/// stays Pearson only for the cache's corr layer — lingam consumes the
/// raw data.
pub fn lingam_grid() -> Vec<Scenario> {
    fn lg(
        name: &'static str,
        n: usize,
        m: usize,
        topology: Topology,
        seed: u64,
        noise: NoiseKind,
    ) -> Scenario {
        Scenario {
            name,
            n,
            m,
            topology,
            alpha: 0.01,
            max_level: None,
            seed,
            corr: CorrKind::Pearson,
            noise,
        }
    }
    vec![
        lg("lingam-uniform", 12, 5000, Topology::Er(0.2), 918, NoiseKind::Uniform),
        lg("lingam-laplace", 10, 5000, Topology::Er(0.25), 916, NoiseKind::Laplace),
        lg("lingam-grn", 14, 4000, Topology::Grn(1.8, 4), 953, NoiseKind::Uniform),
    ]
}

/// The out-of-core scenario grid: sizes where the sparse adjacency and
/// streamed windows actually engage (n past
/// [`crate::oocore::sparse::SPARSE_MIN_N`], low ER density so level 0
/// prunes hard). Deliberately *not* part of [`default_grid`] — the
/// cross-variant conformance suite iterates that grid over every PC
/// family, which would be CI-prohibitive at these sizes. These points
/// are addressable by name (`scenario:oocore-2k` job sources, the CI
/// oocore-smoke manifest) and driven by `tests/oocore_conformance.rs`.
pub fn oocore_grid() -> Vec<Scenario> {
    fn oc(
        name: &'static str,
        n: usize,
        m: usize,
        density: f64,
        alpha: f64,
        max_level: Option<usize>,
        seed: u64,
    ) -> Scenario {
        Scenario {
            name,
            n,
            m,
            topology: Topology::Er(density),
            alpha,
            max_level,
            seed,
            corr: CorrKind::Pearson,
            noise: NoiseKind::Gaussian,
        }
    }
    vec![
        // ~4 expected neighbors per node: sparse enough that the CSR
        // representation wins after level 0, big enough to clear the
        // SPARSE_MIN_N floor
        oc("oocore-2k", 2048, 256, 4.0 / 2048.0, 0.01, None, 914),
        // the bounded-memory headline size (release-build test only);
        // max_level caps the run so the gate stays minutes, not hours
        oc("oocore-10k", 10_000, 128, 0.0002, 0.001, Some(2), 915),
    ]
}

/// The default conformance grid: ≥ 8 points crossing density (sparse →
/// dense), sample count (underpowered → comfortable), alpha (0.01 /
/// 0.05), `max_level` caps (uncapped, 1, 2, 3), GRN topologies and
/// Spearman (Rank-PC) inputs. New points are appended — index-based
/// slices in the conformance suite rely on the original nine staying
/// put.
pub fn default_grid() -> Vec<Scenario> {
    fn sc(
        name: &'static str,
        n: usize,
        m: usize,
        density: f64,
        alpha: f64,
        max_level: Option<usize>,
        seed: u64,
    ) -> Scenario {
        Scenario {
            name,
            n,
            m,
            topology: Topology::Er(density),
            alpha,
            max_level,
            seed,
            corr: CorrKind::Pearson,
            noise: NoiseKind::Gaussian,
        }
    }
    fn sx(
        name: &'static str,
        n: usize,
        m: usize,
        topology: Topology,
        alpha: f64,
        max_level: Option<usize>,
        seed: u64,
        corr: CorrKind,
    ) -> Scenario {
        Scenario {
            name,
            n,
            m,
            topology,
            alpha,
            max_level,
            seed,
            corr,
            noise: NoiseKind::Gaussian,
        }
    }
    vec![
        sc("sparse-a01", 16, 200, 0.10, 0.01, None, 901),
        sc("sparse-a05", 16, 200, 0.10, 0.05, None, 902),
        sc("mid-lowm", 24, 150, 0.15, 0.01, None, 903),
        sc("mid-highm", 24, 600, 0.15, 0.01, None, 904),
        sc("dense-cap2", 24, 300, 0.30, 0.01, Some(2), 905),
        sc("dense-a05-cap2", 24, 300, 0.30, 0.05, Some(2), 906),
        sc("wide-lowm", 32, 120, 0.08, 0.01, None, 907),
        sc("wide-cap1", 32, 400, 0.12, 0.01, Some(1), 908),
        sc("dense-cap3", 20, 500, 0.35, 0.01, Some(3), 909),
        // GRN-topology points: scale-free-ish in-degree, the
        // gene-expression analog workload (ROADMAP scenario-grid growth)
        sx("grn-mid", 24, 300, Topology::Grn(1.8, 5), 0.01, None, 910, CorrKind::Pearson),
        sx("grn-a05-cap2", 28, 250, Topology::Grn(2.2, 6), 0.05, Some(2), 911, CorrKind::Pearson),
        // Spearman (Rank-PC) points: the rank-correlation front-end over
        // both topology families
        sx("rank-er", 20, 300, Topology::Er(0.15), 0.01, None, 912, CorrKind::Spearman),
        sx("rank-grn", 24, 400, Topology::Grn(1.5, 5), 0.01, Some(2), 913, CorrKind::Spearman),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_at_least_eight_points_with_unique_names() {
        let grid = default_grid();
        assert!(grid.len() >= 8, "grid too small: {}", grid.len());
        let mut names: Vec<&str> = grid.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), grid.len(), "duplicate scenario names");
    }

    #[test]
    fn grid_crosses_the_advertised_axes() {
        let grid = default_grid();
        let distinct = |f: fn(&Scenario) -> u64| {
            let mut v: Vec<u64> = grid.iter().map(f).collect();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        let topo_tag = |s: &Scenario| match s.topology {
            Topology::Er(d) => (d * 1000.0) as u64,
            Topology::Grn(avg, maxp) => 1_000_000 + (avg * 1000.0) as u64 + maxp as u64,
        };
        assert!(distinct(topo_tag) >= 4, "topologies");
        assert!(distinct(|s| s.m as u64) >= 3, "sample counts");
        assert!(distinct(|s| (s.alpha * 1000.0) as u64) >= 2, "alphas");
        assert!(
            distinct(|s| s.max_level.map(|l| l as u64 + 1).unwrap_or(0)) >= 3,
            "max_level caps"
        );
        assert!(
            grid.iter()
                .any(|s| matches!(s.topology, Topology::Grn(..))),
            "GRN coverage"
        );
        assert!(
            grid.iter().any(|s| s.corr == CorrKind::Spearman),
            "Spearman coverage"
        );
        assert!(
            grid.iter()
                .any(|s| matches!(s.topology, Topology::Grn(..)) && s.corr == CorrKind::Spearman),
            "GRN × Spearman crossing"
        );
    }

    #[test]
    fn generation_is_deterministic() {
        for sc in [&default_grid()[0], &find("rank-grn").unwrap()] {
            let a = sc.generate();
            let b = sc.generate();
            assert_eq!(a.corr, b.corr, "{}", sc.name);
            assert_eq!(a.truth.skeleton_dense(), b.truth.skeleton_dense());
            assert_eq!((a.n, a.m), (sc.n, sc.m));
        }
    }

    #[test]
    fn generate_uses_the_scenario_corr_kind() {
        let rank = find("rank-er").unwrap();
        let (_, data) = rank.generate_data();
        let input = rank.generate();
        assert_eq!(
            input.corr,
            CorrKind::Spearman.matrix(&data, 1),
            "rank-er must feed Spearman correlations"
        );
        assert_ne!(
            input.corr,
            CorrKind::Pearson.matrix(&data, 1),
            "Spearman must actually differ from Pearson here"
        );
    }

    #[test]
    fn find_resolves_names() {
        assert!(find("sparse-a01").is_some());
        assert!(find("grn-mid").is_some());
        assert!(find("no-such-scenario").is_none());
    }

    /// The out-of-core points are addressable by name but excluded from
    /// the cross-variant conformance grid (they would be CI-prohibitive
    /// across every PC family).
    #[test]
    fn oocore_grid_is_findable_but_not_in_the_default_grid() {
        let ooc = oocore_grid();
        assert!(!ooc.is_empty());
        let defaults = default_grid();
        for sc in &ooc {
            assert!(find(sc.name).is_some(), "{}", sc.name);
            assert!(
                defaults.iter().all(|d| d.name != sc.name),
                "{} must stay out of default_grid",
                sc.name
            );
            assert!(
                sc.n >= crate::oocore::sparse::SPARSE_MIN_N,
                "{}: n={} under the sparse floor",
                sc.name,
                sc.n
            );
        }
        // names and seeds must stay unique across ALL grids (seeds are
        // the determinism anchor; a reuse would alias two datasets)
        let lingam = lingam_grid();
        let all: Vec<&Scenario> = defaults.iter().chain(&ooc).chain(&lingam).collect();
        let mut names: Vec<&str> = all.iter().map(|s| s.name).collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "scenario name reused across grids");
        let mut seeds: Vec<u64> = all.iter().map(|s| s.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), total, "scenario seed reused across grids");
    }

    /// The lingam grid must stay in lockstep with the pinned python
    /// oracle (`tools/lingam_oracle.py::LINGAM_GRID`) — these literals
    /// are the Rust half of that contract.
    #[test]
    fn lingam_grid_is_pinned_and_non_gaussian() {
        let grid = lingam_grid();
        assert_eq!(grid.len(), 3);
        let rows: Vec<(&str, usize, usize, u64, NoiseKind)> = grid
            .iter()
            .map(|s| (s.name, s.n, s.m, s.seed, s.noise))
            .collect();
        assert_eq!(
            rows,
            vec![
                ("lingam-uniform", 12, 5000, 918, NoiseKind::Uniform),
                ("lingam-laplace", 10, 5000, 916, NoiseKind::Laplace),
                ("lingam-grn", 14, 4000, 953, NoiseKind::Uniform),
            ]
        );
        for s in &grid {
            assert_ne!(s.noise, NoiseKind::Gaussian, "{}: lingam needs non-Gaussian noise", s.name);
            assert!(find(s.name).is_some(), "{}", s.name);
        }
        assert!(
            grid.iter().any(|s| matches!(s.topology, Topology::Grn(..))),
            "GRN coverage in the lingam grid"
        );
        // PC grids keep the paper's Gaussian noise
        for s in default_grid().iter().chain(&oocore_grid()) {
            assert_eq!(s.noise, NoiseKind::Gaussian, "{}", s.name);
        }
    }

    /// Conformance coverage cannot silently lag the registry: a family
    /// added to `family::FAMILIES` must also appear here (and vice
    /// versa) so the grid gates every shipped schedule.
    #[test]
    fn all_variants_match_the_family_registry() {
        use crate::skeleton::family::FAMILIES;
        assert_eq!(ALL_VARIANTS.len(), FAMILIES.len());
        for (v, f) in ALL_VARIANTS.iter().zip(FAMILIES) {
            assert_eq!(*v, f.variant, "ALL_VARIANTS must follow registry order");
        }
    }

    #[test]
    fn config_carries_scenario_parameters() {
        let sc = &default_grid()[4];
        let cfg = sc.config(Variant::CupcS);
        assert_eq!(cfg.alpha, sc.alpha);
        assert_eq!(cfg.max_level, sc.max_level);
        assert_eq!(cfg.variant, Variant::CupcS);
        assert_eq!(cfg.orient, OrientRule::Majority);
    }
}
