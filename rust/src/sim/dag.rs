//! Random DAG generation, following the paper's §5.6 protocol:
//! a lower-triangular adjacency with independent Bernoulli(d) entries and
//! edge weights drawn uniformly from [0.1, 1].

use crate::util::rng::Pcg;

/// A weighted DAG over variables 0..n, edges j → i only for j < i
/// (topological order = variable order, as in the paper).
#[derive(Clone)]
pub struct WeightedDag {
    pub n: usize,
    /// weights[i] = list of (parent j, weight) with j < i
    pub parents: Vec<Vec<(u32, f64)>>,
}

impl WeightedDag {
    /// Erdős–Rényi-style lower-triangular DAG: each (i, j), j < i, is an
    /// edge with probability `d`, weight ~ U[0.1, 1] (paper §5.6).
    pub fn random_er(n: usize, d: f64, rng: &mut Pcg) -> Self {
        let mut parents = vec![Vec::new(); n];
        for i in 1..n {
            for j in 0..i {
                if rng.bernoulli(d) {
                    parents[i].push((j as u32, rng.uniform_in(0.1, 1.0)));
                }
            }
        }
        WeightedDag { n, parents }
    }

    /// GRN-like topology: scale-free-ish in-degree via preferential
    /// attachment, bounded by `max_parents`. Used for the gene-expression
    /// dataset analogs where ER graphs would be too homogeneous.
    pub fn random_grn(n: usize, avg_parents: f64, max_parents: usize, rng: &mut Pcg) -> Self {
        let mut parents = vec![Vec::new(); n];
        let mut popularity = vec![1.0f64; n]; // attachment weights
        for i in 1..n {
            // Poisson-ish number of parents via repeated Bernoulli
            let lam = avg_parents.min(i as f64);
            let mut k = 0usize;
            let acc = rng.uniform();
            let mut p = (-lam).exp();
            let mut cdf = p;
            while acc > cdf && k < max_parents {
                k += 1;
                p *= lam / k as f64;
                cdf += p;
            }
            let k = k.min(i);
            // sample k distinct predecessors ∝ popularity
            let mut chosen = std::collections::HashSet::new();
            let total: f64 = popularity[..i].iter().sum();
            let mut guard = 0;
            while chosen.len() < k && guard < 50 * k + 50 {
                guard += 1;
                let mut r = rng.uniform() * total;
                let mut pick = 0usize;
                for (idx, w) in popularity[..i].iter().enumerate() {
                    r -= w;
                    if r <= 0.0 {
                        pick = idx;
                        break;
                    }
                }
                chosen.insert(pick);
            }
            // sort before weight assignment: HashSet iteration order is
            // per-instance random and must not leak into the stream
            let mut chosen: Vec<usize> = chosen.into_iter().collect();
            chosen.sort_unstable();
            for j in chosen {
                parents[i].push((j as u32, rng.uniform_in(0.1, 1.0)));
                popularity[j] += 1.0;
            }
        }
        WeightedDag { n, parents }
    }

    pub fn n_edges(&self) -> usize {
        self.parents.iter().map(|p| p.len()).sum()
    }

    /// True undirected skeleton as dense 0/1.
    pub fn skeleton_dense(&self) -> Vec<u8> {
        let n = self.n;
        let mut s = vec![0u8; n * n];
        for (i, ps) in self.parents.iter().enumerate() {
            for &(j, _) in ps {
                s[i * n + j as usize] = 1;
                s[j as usize * n + i] = 1;
            }
        }
        s
    }

    /// Directed adjacency (i row, j col = 1 if j → i? No: standard
    /// a[parent][child] = 1).
    pub fn directed_dense(&self) -> Vec<u8> {
        let n = self.n;
        let mut a = vec![0u8; n * n];
        for (i, ps) in self.parents.iter().enumerate() {
            for &(j, _) in ps {
                a[j as usize * n + i] = 1;
            }
        }
        a
    }

    pub fn max_degree(&self) -> usize {
        let n = self.n;
        let mut deg = vec![0usize; n];
        for (i, ps) in self.parents.iter().enumerate() {
            deg[i] += ps.len();
            for &(j, _) in ps {
                deg[j as usize] += 1;
            }
        }
        deg.into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_density_is_respected() {
        let mut rng = Pcg::seeded(1);
        let n = 100;
        let d = 0.1;
        let g = WeightedDag::random_er(n, d, &mut rng);
        let expected = d * (n * (n - 1) / 2) as f64;
        let got = g.n_edges() as f64;
        assert!(
            (got - expected).abs() < 0.2 * expected,
            "edges={got} expected≈{expected}"
        );
    }

    #[test]
    fn er_is_lower_triangular() {
        let mut rng = Pcg::seeded(2);
        let g = WeightedDag::random_er(50, 0.2, &mut rng);
        for (i, ps) in g.parents.iter().enumerate() {
            for &(j, w) in ps {
                assert!((j as usize) < i);
                assert!((0.1..=1.0).contains(&w));
            }
        }
    }

    #[test]
    fn skeleton_symmetric_and_matches_edges() {
        let mut rng = Pcg::seeded(3);
        let g = WeightedDag::random_er(30, 0.15, &mut rng);
        let s = g.skeleton_dense();
        let n = g.n;
        let mut count = 0;
        for i in 0..n {
            for j in 0..n {
                assert_eq!(s[i * n + j], s[j * n + i]);
                if i < j && s[i * n + j] != 0 {
                    count += 1;
                }
            }
        }
        assert_eq!(count, g.n_edges());
    }

    #[test]
    fn grn_bounded_parents() {
        let mut rng = Pcg::seeded(4);
        let g = WeightedDag::random_grn(200, 2.0, 5, &mut rng);
        for ps in &g.parents {
            assert!(ps.len() <= 5);
        }
        assert!(g.n_edges() > 100, "edges={}", g.n_edges());
    }

    #[test]
    fn deterministic_by_seed() {
        let g1 = WeightedDag::random_er(40, 0.1, &mut Pcg::seeded(9));
        let g2 = WeightedDag::random_er(40, 0.1, &mut Pcg::seeded(9));
        assert_eq!(g1.skeleton_dense(), g2.skeleton_dense());
    }
}
