//! Benchmark dataset registry — synthetic analogs of the paper's Table 1.
//!
//! The six gene-expression datasets (NCI-60, MCC, BR-51, S.cerevisiae,
//! S.aureus, DREAM5-Insilico) are not redistributable; we substitute
//! linear-SEM data from GRN-like sparse random DAGs with the **same
//! (n, m)** as Table 1 (see DESIGN.md §3). Each spec also has a `-mini`
//! variant scaled down ~8× for the default `--scale small` experiments
//! so the full harness runs in CI-image time.

use super::dag::WeightedDag;
use super::sem;
use crate::stats::corr::DataMatrix;
use crate::util::rng::Pcg;

#[derive(Clone, Copy, Debug)]
pub enum Topology {
    /// Erdős–Rényi with edge probability d (paper §5.6 protocol)
    Er(f64),
    /// GRN-like preferential attachment (avg parents, max parents)
    Grn(f64, usize),
}

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// number of variables (Table 1 column n)
    pub n: usize,
    /// number of samples (Table 1 column m)
    pub m: usize,
    pub topology: Topology,
    pub seed: u64,
}

/// The Table-1 analogs (full scale) and their `-mini` variants.
pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec { name: "nci60", n: 1190, m: 47, topology: Topology::Grn(1.5, 8), seed: 101 },
    DatasetSpec { name: "mcc", n: 1380, m: 88, topology: Topology::Grn(1.5, 8), seed: 102 },
    DatasetSpec { name: "br51", n: 1592, m: 50, topology: Topology::Grn(1.5, 8), seed: 103 },
    DatasetSpec { name: "scerevisiae", n: 5361, m: 63, topology: Topology::Grn(1.2, 8), seed: 104 },
    DatasetSpec { name: "saureus", n: 2810, m: 160, topology: Topology::Grn(1.3, 8), seed: 105 },
    DatasetSpec { name: "dream5-insilico", n: 1643, m: 850, topology: Topology::Grn(2.0, 10), seed: 106 },
    // mini variants: n/8, m kept >= 40 for test power, same structure
    DatasetSpec { name: "nci60-mini", n: 148, m: 47, topology: Topology::Grn(1.5, 8), seed: 101 },
    DatasetSpec { name: "mcc-mini", n: 172, m: 88, topology: Topology::Grn(1.5, 8), seed: 102 },
    DatasetSpec { name: "br51-mini", n: 199, m: 50, topology: Topology::Grn(1.5, 8), seed: 103 },
    DatasetSpec { name: "scerevisiae-mini", n: 670, m: 63, topology: Topology::Grn(1.2, 8), seed: 104 },
    DatasetSpec { name: "saureus-mini", n: 351, m: 160, topology: Topology::Grn(1.3, 8), seed: 105 },
    DatasetSpec { name: "dream5-insilico-mini", n: 205, m: 850, topology: Topology::Grn(2.0, 10), seed: 106 },
];

/// Table-2 benchmark order (paper columns).
pub const TABLE2_ORDER: [&str; 6] = [
    "nci60",
    "mcc",
    "br51",
    "scerevisiae",
    "saureus",
    "dream5-insilico",
];

pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    SPECS.iter().find(|s| s.name == name)
}

/// A generated dataset: ground-truth DAG + sampled data.
pub struct Dataset {
    pub spec: DatasetSpec,
    pub dag: WeightedDag,
    pub data: DataMatrix,
}

/// Generate the dataset for a spec (deterministic in the spec's seed).
pub fn generate(spec: &DatasetSpec) -> Dataset {
    let mut rng_g = Pcg::new(spec.seed, 1);
    let dag = match spec.topology {
        Topology::Er(d) => WeightedDag::random_er(spec.n, d, &mut rng_g),
        Topology::Grn(avg, maxp) => WeightedDag::random_grn(spec.n, avg, maxp, &mut rng_g),
    };
    let mut rng_s = Pcg::new(spec.seed, 2);
    let data = sem::sample(&dag, spec.m, &mut rng_s);
    Dataset {
        spec: spec.clone(),
        dag,
        data,
    }
}

/// Custom scalability dataset (Fig. 10): ER graph with density d.
pub fn generate_er(n: usize, m: usize, d: f64, seed: u64) -> Dataset {
    let spec = DatasetSpec {
        name: "custom-er",
        n,
        m,
        topology: Topology::Er(d),
        seed,
    };
    generate(&spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_table1_shapes() {
        let t1 = [
            ("nci60", 1190, 47),
            ("mcc", 1380, 88),
            ("br51", 1592, 50),
            ("scerevisiae", 5361, 63),
            ("saureus", 2810, 160),
            ("dream5-insilico", 1643, 850),
        ];
        for (name, n, m) in t1 {
            let s = spec(name).unwrap();
            assert_eq!((s.n, s.m), (n, m), "{name}");
        }
    }

    #[test]
    fn mini_variants_exist_for_all() {
        for base in TABLE2_ORDER {
            let mini = format!("{base}-mini");
            assert!(spec(&mini).is_some(), "{mini} missing");
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let s = spec("nci60-mini").unwrap();
        let a = generate(s);
        let b = generate(s);
        assert_eq!(a.dag.skeleton_dense(), b.dag.skeleton_dense());
        assert_eq!(a.data.x, b.data.x);
        assert_eq!(a.data.m, s.m);
        assert_eq!(a.data.n, s.n);
    }

    #[test]
    fn er_generator_matches_params() {
        let d = generate_er(50, 30, 0.2, 7);
        assert_eq!(d.data.n, 50);
        assert_eq!(d.data.m, 30);
        assert!(d.dag.n_edges() > 0);
    }
}
