//! `cupc serve` — a long-lived, multi-tenant daemon over the batch
//! service layer.
//!
//! One process keeps the two-layer content-addressed cache
//! ([`super::cache::Cache`] in memory, [`super::store::DiskStore`] on
//! disk) warm across requests and shares one global
//! [`super::scheduler::ThreadBudget`] between every client's jobs.
//! Clients speak the length-prefixed JSON protocol of [`super::proto`]
//! over loopback TCP; each submitted job streams its deterministic
//! result record back as it finishes.
//!
//! **Determinism contract** (extends the batch layer's): a job's result
//! record is bit-identical whether it ran via `cupc batch` or `cupc
//! serve`, against a cold or warm cache (memory or disk tier), with one
//! client connected or several concurrently, at any priority. The
//! server guarantees this by construction — it runs the *same*
//! [`run_job`] and embeds the *same* [`result_line`] bytes verbatim in
//! each frame, and each request's jobs run sequentially in manifest
//! order (cross-request concurrency comes from concurrent connections
//! sharing the elastic budget, which is already proven to only move
//! wall-clock time). `tests/serve_conformance.rs` gates it end to end.
//!
//! Untrusted-input posture: the listener refuses non-loopback
//! addresses (the protocol is unauthenticated); request frames are
//! length-capped; the JSON parser is depth- and finiteness-hardened
//! (`util::json`); reads poll with a short socket timeout so an idle
//! connection is dropped after `idle_timeout` and a deliberately
//! stalled frame (slow-loris) after `frame_timeout`; admission control
//! bounds in-flight jobs and concurrent connections with structured
//! `overloaded` / `busy` rejections, so one tenant cannot queue the
//! daemon to death.

use super::cache::Cache;
use super::job::Manifest;
use super::proto::{
    done_frame, encode_frame, error_frame, frame_len, parse_request, pong_frame,
    record_from_result_frame, result_frame, Priority, Request, MAX_REQUEST_BYTES,
    MAX_RESPONSE_BYTES,
};
use super::report::{cache_stats_json, disk_stats_json, result_line};
use super::scheduler::{run_job, ElasticLease, ThreadBudget};
use super::store::DiskStore;
use crate::skeleton::available_threads;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How often blocked reads and the accept loop wake to check timeouts
/// and the shutdown flag.
const POLL: Duration = Duration::from_millis(100);

/// Daemon knobs (`cupc serve` flags map onto these 1:1).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// listen address — must be a loopback literal; the protocol is
    /// unauthenticated, so [`Server::bind`] refuses anything else
    pub addr: String,
    /// global pipeline-worker budget shared by every in-flight job
    pub threads: usize,
    /// in-process cache byte budget (shared across all requests)
    pub cache_bytes: usize,
    /// persistent cache directory (`--cache-dir`); `None` keeps caching
    /// in-process only
    pub cache_dir: Option<PathBuf>,
    /// byte budget for the persistent store
    pub disk_bytes: u64,
    /// concurrent client connections; further connects get `busy`
    pub max_conns: usize,
    /// admission cap: a submit that would push the in-flight job count
    /// past this is rejected with a structured `overloaded` error
    pub max_queued_jobs: usize,
    /// how long a connection may sit idle between requests
    pub idle_timeout: Duration,
    /// how long a started frame may stall without a byte of progress
    /// (slow-loris guard)
    pub frame_timeout: Duration,
    /// per-connection progress on stderr
    pub verbose: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7717".into(),
            threads: available_threads(),
            cache_bytes: 256 << 20,
            cache_dir: None,
            disk_bytes: 1 << 30,
            max_conns: 16,
            max_queued_jobs: 64,
            idle_timeout: Duration::from_secs(300),
            frame_timeout: Duration::from_secs(10),
            verbose: false,
        }
    }
}

/// State shared by the accept loop and every connection handler.
struct Shared {
    opts: ServeOptions,
    budget: Arc<ThreadBudget>,
    cache: Cache,
    store: Option<DiskStore>,
    shutdown: Arc<AtomicBool>,
    /// open client connections
    conns: AtomicUsize,
    /// jobs admitted but not yet finished (the admission-control gauge)
    jobs_inflight: AtomicUsize,
    /// jobs completed successfully over the daemon's lifetime
    jobs_done: AtomicU64,
    /// submit requests admitted over the daemon's lifetime
    requests: AtomicU64,
}

/// A bound, not-yet-running daemon.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

impl Server {
    /// Bind the listener and open the persistent store. Refuses
    /// non-loopback addresses *before* binding: the protocol has no
    /// authentication, so exposure beyond the host is always a
    /// misconfiguration. An unusable `cache_dir` fails here, loudly,
    /// for the same reason `run_batch` makes it fatal.
    pub fn bind(opts: ServeOptions, shutdown: Arc<AtomicBool>) -> Result<Server> {
        let sa: SocketAddr = opts.addr.parse().with_context(|| {
            format!(
                "--addr {:?} is not a socket address literal (host:port)",
                opts.addr
            )
        })?;
        ensure!(
            sa.ip().is_loopback(),
            "refusing to bind {sa}: the serve protocol is unauthenticated, \
             so only loopback addresses are allowed"
        );
        let listener = TcpListener::bind(sa).with_context(|| format!("binding {sa}"))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener nonblocking")?;
        let store = match &opts.cache_dir {
            Some(dir) => Some(DiskStore::open(dir, opts.disk_bytes)?),
            None => None,
        };
        let budget = Arc::new(ThreadBudget::new(opts.threads));
        let cache = Cache::new(opts.cache_bytes);
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                opts,
                budget,
                cache,
                store,
                shutdown,
                conns: AtomicUsize::new(0),
                jobs_inflight: AtomicUsize::new(0),
                jobs_done: AtomicU64::new(0),
                requests: AtomicU64::new(0),
            }),
        })
    }

    /// The bound address (resolves `:0` to the kernel-chosen port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading the bound address")
    }

    /// The accept loop. Returns after the shutdown flag is set *and*
    /// every connection handler has drained — in-flight requests finish
    /// and stream their results; only then does the process exit, so a
    /// SIGTERM never truncates a client's stream mid-record.
    pub fn run(self) -> Result<()> {
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.shared.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((mut stream, peer)) => {
                    // finished handlers are detached on reap; the live
                    // ones are joined at shutdown below
                    handlers.retain(|h| !h.is_finished());
                    if self.shared.conns.load(Ordering::SeqCst) >= self.shared.opts.max_conns {
                        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
                        let _ = write_frame(
                            &mut stream,
                            &error_frame(
                                "busy",
                                &format!(
                                    "connection limit ({}) reached; retry later",
                                    self.shared.opts.max_conns
                                ),
                            ),
                        );
                        continue;
                    }
                    self.shared.conns.fetch_add(1, Ordering::SeqCst);
                    if self.shared.opts.verbose {
                        eprintln!("[serve] {peer} connected");
                    }
                    let shared = self.shared.clone();
                    handlers.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, &shared);
                        shared.conns.fetch_sub(1, Ordering::SeqCst);
                        if shared.opts.verbose {
                            eprintln!("[serve] {peer} disconnected");
                        }
                    }));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
                Err(e) => {
                    // transient accept failures (e.g. EMFILE under fd
                    // pressure) must not kill a long-lived daemon
                    if self.shared.opts.verbose {
                        eprintln!("[serve] accept error: {e}");
                    }
                    std::thread::sleep(POLL);
                }
            }
        }
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Bind on `opts.addr` and run the accept loop on a background thread.
/// Tests (and embedders) use this; the CLI runs [`Server::run`] on the
/// main thread so signals map to a clean exit code.
pub fn spawn(opts: ServeOptions) -> Result<ServerHandle> {
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = Server::bind(opts, shutdown.clone())?;
    let addr = server.local_addr()?;
    let thread = std::thread::spawn(move || server.run());
    Ok(ServerHandle {
        addr,
        shutdown,
        thread: Some(thread),
    })
}

/// A running daemon spawned by [`spawn`]; dropping it requests shutdown
/// and joins, so a panicking test never leaks the listener thread.
pub struct ServerHandle {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    /// Request shutdown and wait for the accept loop and every
    /// connection handler to drain.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::SeqCst);
        match self.thread.take() {
            Some(t) => t
                .join()
                .map_err(|_| anyhow::anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// One frame read off the wire.
enum Frame {
    Payload(Vec<u8>),
    /// close without a response: clean EOF at a frame boundary, idle
    /// timeout, or daemon shutdown
    Close,
    /// framing violated — send one `bad-frame` error, then close (the
    /// stream position is no longer trustworthy)
    Bad(String),
}

enum Fill {
    Full,
    /// EOF / idle timeout / shutdown before the first byte of a frame
    CleanEof,
    Error(String),
}

/// Fill `buf` from the socket, polling at [`POLL`] so timeouts and the
/// shutdown flag are honored. `at_boundary` marks the read that starts
/// a frame: only there are idle timeouts, clean EOFs and shutdowns
/// tolerated — once a frame has begun, lack of progress past
/// `frame_timeout` is a protocol error (the slow-loris guard).
fn read_full(stream: &mut TcpStream, buf: &mut [u8], shared: &Shared, at_boundary: bool) -> Fill {
    let mut filled = 0usize;
    let mut last_progress = Instant::now();
    loop {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 && at_boundary {
                    Fill::CleanEof
                } else {
                    Fill::Error("connection closed mid-frame".into())
                };
            }
            Ok(n) => {
                filled += n;
                last_progress = Instant::now();
                if filled == buf.len() {
                    return Fill::Full;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                let mid_frame = !at_boundary || filled > 0;
                if mid_frame {
                    if last_progress.elapsed() > shared.opts.frame_timeout {
                        return Fill::Error(format!(
                            "frame stalled without progress for over {:.0?} (slow-loris guard)",
                            shared.opts.frame_timeout
                        ));
                    }
                } else {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return Fill::CleanEof;
                    }
                    if last_progress.elapsed() > shared.opts.idle_timeout {
                        return Fill::CleanEof;
                    }
                }
            }
            Err(e) => return Fill::Error(format!("read failed: {e}")),
        }
    }
}

/// Read one length-prefixed request frame.
fn read_frame(stream: &mut TcpStream, shared: &Shared) -> Frame {
    let mut header = [0u8; 4];
    match read_full(stream, &mut header, shared, true) {
        Fill::CleanEof => return Frame::Close,
        Fill::Error(e) => return Frame::Bad(e),
        Fill::Full => {}
    }
    let len = frame_len(header);
    if len == 0 {
        return Frame::Bad("empty frame".into());
    }
    if len > MAX_REQUEST_BYTES {
        return Frame::Bad(format!(
            "{len}-byte frame exceeds the {MAX_REQUEST_BYTES}-byte request cap \
             (is the client speaking this protocol?)"
        ));
    }
    let mut buf = vec![0u8; len];
    match read_full(stream, &mut buf, shared, false) {
        Fill::CleanEof => Frame::Bad("connection closed mid-frame".into()),
        Fill::Error(e) => Frame::Bad(e),
        Fill::Full => Frame::Payload(buf),
    }
}

fn write_frame(stream: &mut TcpStream, payload: &str) -> std::io::Result<()> {
    stream.write_all(&encode_frame(payload))
}

/// Serve one connection until it closes, violates framing, idles out,
/// or the daemon shuts down. An `Err` means the client side died
/// mid-write — there is nobody left to tell, so the caller just drops
/// the connection.
fn handle_conn(mut stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    stream.set_read_timeout(Some(POLL))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    loop {
        match read_frame(&mut stream, shared) {
            Frame::Close => return Ok(()),
            Frame::Bad(msg) => {
                // best effort: the peer may already be gone
                let _ = write_frame(&mut stream, &error_frame("bad-frame", &msg));
                return Ok(());
            }
            Frame::Payload(bytes) => {
                let payload = match std::str::from_utf8(&bytes) {
                    Ok(s) => s,
                    Err(_) => {
                        // framing is still synchronized, so the
                        // connection survives a bad payload
                        write_frame(
                            &mut stream,
                            &error_frame("bad-request", "request payload is not UTF-8"),
                        )?;
                        continue;
                    }
                };
                match parse_request(payload) {
                    Err(e) => {
                        write_frame(&mut stream, &error_frame("bad-request", &format!("{e:#}")))?
                    }
                    Ok(Request::Ping) => write_frame(&mut stream, &pong_frame())?,
                    Ok(Request::Stats) => write_frame(&mut stream, &stats_json(shared))?,
                    Ok(Request::Submit { manifest, priority }) => {
                        handle_submit(&mut stream, shared, &manifest, priority)?
                    }
                }
            }
        }
    }
}

/// Admission-check a submit, then run its jobs sequentially in manifest
/// order, streaming each deterministic record as it finishes. Admission
/// reserves all the request's jobs up front (compare-exchange, so
/// concurrent submits cannot overshoot the cap) and releases each slot
/// as its job completes.
fn handle_submit(
    stream: &mut TcpStream,
    shared: &Shared,
    manifest: &Manifest,
    priority: Priority,
) -> std::io::Result<()> {
    let njobs = manifest.jobs.len();
    let cap = shared.opts.max_queued_jobs;
    let admitted = loop {
        let cur = shared.jobs_inflight.load(Ordering::SeqCst);
        if cur + njobs > cap {
            break false;
        }
        if shared
            .jobs_inflight
            .compare_exchange(cur, cur + njobs, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            break true;
        }
    };
    if !admitted {
        return write_frame(
            stream,
            &error_frame(
                "overloaded",
                &format!(
                    "{njobs} job(s) would exceed the daemon's in-flight cap of {cap}; retry later"
                ),
            ),
        );
    }
    shared.requests.fetch_add(1, Ordering::SeqCst);

    let mut completed = 0usize;
    let mut failed: Option<(String, anyhow::Error)> = None;
    let mut conn_dead: Option<std::io::Error> = None;
    for spec in &manifest.jobs {
        let want = priority.initial_want(shared.budget.total());
        let lease = ElasticLease::acquire(shared.budget.clone(), want);
        if shared.opts.verbose {
            eprintln!(
                "[serve] job {:?} ({}): {} worker(s)",
                spec.name,
                priority.name(),
                lease.width()
            );
        }
        let rep = run_job(spec, &lease, &shared.cache, shared.store.as_ref());
        drop(lease);
        shared.jobs_inflight.fetch_sub(1, Ordering::SeqCst);
        completed += 1;
        match rep {
            Ok(rep) => {
                shared.jobs_done.fetch_add(1, Ordering::SeqCst);
                if let Err(e) =
                    write_frame(stream, &result_frame(&result_line(spec, &rep.core)))
                {
                    conn_dead = Some(e);
                    break;
                }
            }
            Err(e) => {
                failed = Some((spec.name.clone(), e));
                break;
            }
        }
    }
    // release the reservation of any jobs skipped by a failure or a
    // dead connection
    shared
        .jobs_inflight
        .fetch_sub(njobs - completed, Ordering::SeqCst);
    if let Some(e) = conn_dead {
        return Err(e);
    }
    match failed {
        Some((name, e)) => write_frame(
            stream,
            &error_frame(
                "job-failed",
                &format!("job {name:?}: {e:#} (remaining jobs in this request were skipped)"),
            ),
        ),
        None => write_frame(stream, &done_frame(njobs)),
    }
}

/// The `/stats` record: thread-budget occupancy, admission gauges, and
/// the cache/disk counters in exactly the spelling of the batch stats
/// sidecar (shared formatters — CI greps the disk-tier fields).
fn stats_json(shared: &Shared) -> String {
    let disk = match &shared.store {
        Some(s) => disk_stats_json(&s.stats()),
        None => "null".to_string(),
    };
    format!(
        "{{\"stats\":{{\"threads_total\":{},\"threads_idle\":{},\"connections\":{},\
         \"jobs_inflight\":{},\"jobs_done\":{},\"requests\":{},\"cache\":{},\"disk\":{}}}}}",
        shared.budget.total(),
        shared.budget.idle(),
        shared.conns.load(Ordering::SeqCst),
        shared.jobs_inflight.load(Ordering::SeqCst),
        shared.jobs_done.load(Ordering::SeqCst),
        shared.requests.load(Ordering::SeqCst),
        cache_stats_json(&shared.cache.stats()),
        disk
    )
}

/// A blocking client for the serve protocol — the `cupc client`
/// subcommand and the conformance tests both drive the daemon through
/// it.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        // generous: covers a long job between result frames; a hung
        // daemon still fails the call instead of wedging the client
        stream
            .set_read_timeout(Some(Duration::from_secs(600)))
            .context("setting the read timeout")?;
        Ok(Client { stream })
    }

    /// Send one framed payload (tests also use this to speak
    /// well-framed-but-malformed requests).
    pub fn send(&mut self, payload: &str) -> Result<()> {
        self.stream
            .write_all(&encode_frame(payload))
            .context("sending frame")
    }

    /// Put raw bytes on the wire, bypassing framing entirely
    /// (truncated-frame and garbage-bytes tests).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes).context("sending raw bytes")
    }

    /// Receive one response payload.
    pub fn recv(&mut self) -> Result<String> {
        let mut header = [0u8; 4];
        self.stream
            .read_exact(&mut header)
            .context("reading response header")?;
        let len = frame_len(header);
        ensure!(
            len > 0 && len <= MAX_RESPONSE_BYTES,
            "absurd response frame length {len} (stream desynchronized?)"
        );
        let mut buf = vec![0u8; len];
        self.stream
            .read_exact(&mut buf)
            .context("reading response payload")?;
        String::from_utf8(buf).context("response is not UTF-8")
    }

    pub fn ping(&mut self) -> Result<()> {
        self.send("{\"op\":\"ping\"}")?;
        let resp = self.recv()?;
        ensure!(resp == pong_frame(), "unexpected ping response: {resp}");
        Ok(())
    }

    /// The daemon's stats record (`{"stats":{...}}`) as raw JSON text.
    pub fn stats(&mut self) -> Result<String> {
        self.send("{\"op\":\"stats\"}")?;
        let resp = self.recv()?;
        ensure!(
            resp.starts_with("{\"stats\":"),
            "unexpected stats response: {resp}"
        );
        Ok(resp)
    }

    /// Submit a manifest (the verbatim text of the same `{"jobs":[...]}`
    /// document `cupc batch --manifest` reads) and reassemble the
    /// streamed records into a results stream byte-identical to the
    /// batch results file. An error frame — admission rejection, bad
    /// manifest, failed job — surfaces as an `Err` naming the code.
    pub fn submit(&mut self, manifest_text: &str, priority: Priority) -> Result<String> {
        let req = format!(
            "{{\"op\":\"submit\",\"priority\":\"{}\",\"manifest\":{}}}",
            priority.name(),
            manifest_text.trim()
        );
        self.send(&req)?;
        let mut out = String::new();
        loop {
            let resp = self.recv()?;
            if let Some(record) = record_from_result_frame(&resp) {
                out.push_str(record);
                out.push('\n');
            } else if resp.starts_with("{\"done\":") {
                return Ok(out);
            } else {
                bail!(server_error(&resp));
            }
        }
    }
}

/// Render an error frame (or any unexpected payload) as a message.
fn server_error(payload: &str) -> String {
    if let Ok(v) = Json::parse(payload) {
        if let Some(e) = v.get("error") {
            let code = e.get("code").and_then(Json::as_str).unwrap_or("?");
            let msg = e.get("message").and_then(Json::as_str).unwrap_or("?");
            return format!("server error [{code}]: {msg}");
        }
    }
    format!("unexpected server response: {payload}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_opts() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            threads: 2,
            cache_bytes: 32 << 20,
            idle_timeout: Duration::from_secs(30),
            frame_timeout: Duration::from_secs(5),
            ..ServeOptions::default()
        }
    }

    #[test]
    fn bind_refuses_non_loopback_and_garbage_addresses() {
        let shutdown = Arc::new(AtomicBool::new(false));
        for addr in ["0.0.0.0:0", "192.168.1.10:7717", "[::]:0"] {
            let opts = ServeOptions {
                addr: addr.into(),
                ..test_opts()
            };
            let err = Server::bind(opts, shutdown.clone()).expect_err(addr);
            assert!(
                format!("{err:#}").contains("loopback"),
                "{addr}: {err:#}"
            );
        }
        let opts = ServeOptions {
            addr: "localhost:abc".into(),
            ..test_opts()
        };
        let err = Server::bind(opts, shutdown).expect_err("garbage addr");
        assert!(format!("{err:#}").contains("socket address"), "{err:#}");
    }

    #[test]
    fn ping_stats_and_clean_shutdown() {
        let handle = spawn(test_opts()).unwrap();
        let addr = handle.addr.to_string();
        let mut c = Client::connect(&addr).unwrap();
        c.ping().unwrap();
        let stats = c.stats().unwrap();
        let v = Json::parse(&stats).unwrap();
        let s = v.get("stats").unwrap();
        assert_eq!(s.get("threads_total").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("connections").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("jobs_done").unwrap().as_usize(), Some(0));
        assert!(s.get("cache").unwrap().get("budget").is_some());
        assert!(s.get("disk").unwrap().is_null(), "no --cache-dir: null");
        drop(c);
        handle.shutdown().unwrap();
        // the port is released: a fresh connect must fail
        assert!(Client::connect(&addr).is_err());
    }

    #[test]
    fn submit_streams_records_and_keeps_the_cache_warm() {
        let handle = spawn(test_opts()).unwrap();
        let addr = handle.addr.to_string();
        let manifest = r#"{"jobs":[{"name":"a","scenario":"sparse-a01"}]}"#;
        let mut c = Client::connect(&addr).unwrap();
        let cold = c.submit(manifest, Priority::Normal).unwrap();
        assert_eq!(cold.lines().count(), 1);
        let v = Json::parse(cold.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("job").unwrap().as_str(), Some("a"));
        // second submit over the same connection: served from the warm
        // in-process cache, byte-identical
        let warm = c.submit(manifest, Priority::High).unwrap();
        assert_eq!(cold, warm, "warm result must be byte-identical");
        let stats = c.stats().unwrap();
        let v = Json::parse(&stats).unwrap();
        let s = v.get("stats").unwrap();
        assert_eq!(s.get("jobs_done").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("requests").unwrap().as_usize(), Some(2));
        let cache = s.get("cache").unwrap();
        assert!(
            cache.get("hits").unwrap().as_usize().unwrap() >= 2,
            "warm submit must hit the shared cache: {stats}"
        );
        handle.shutdown().unwrap();
    }

    #[test]
    fn malformed_payloads_get_structured_errors_and_the_daemon_survives() {
        let handle = spawn(test_opts()).unwrap();
        let addr = handle.addr.to_string();
        let mut c = Client::connect(&addr).unwrap();
        // well-framed, malformed payload: connection survives
        c.send("not json at all").unwrap();
        let resp = c.recv().unwrap();
        assert!(resp.contains("\"bad-request\""), "{resp}");
        c.ping().unwrap();
        // a deep-nesting bomb is a parse error, not a daemon abort
        c.send(&"[".repeat(100_000)).unwrap();
        let resp = c.recv().unwrap();
        assert!(resp.contains("\"bad-request\""), "{resp}");
        assert!(resp.contains("nesting"), "{resp}");
        c.ping().unwrap();
        // non-finite numbers are rejected at the parser
        c.send(r#"{"op":"submit","manifest":{"jobs":[{"scenario":"grn-mid","alpha":1e999}]}}"#)
            .unwrap();
        let resp = c.recv().unwrap();
        assert!(resp.contains("overflows a finite double"), "{resp}");
        c.ping().unwrap();
        drop(c);
        // garbage bytes (an HTTP request line): bad-frame, then close
        let mut g = Client::connect(&addr).unwrap();
        g.send_raw(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let resp = g.recv().unwrap();
        assert!(resp.contains("\"bad-frame\""), "{resp}");
        // ...and the daemon still serves fresh connections
        let mut c2 = Client::connect(&addr).unwrap();
        c2.ping().unwrap();
        handle.shutdown().unwrap();
    }

    #[test]
    fn oversized_jobs_are_rejected_with_overloaded() {
        let opts = ServeOptions {
            max_queued_jobs: 1,
            ..test_opts()
        };
        let handle = spawn(opts).unwrap();
        let mut c = Client::connect(&handle.addr.to_string()).unwrap();
        let two = r#"{"jobs":[{"name":"a","scenario":"sparse-a01"},
                               {"name":"b","scenario":"grn-mid"}]}"#;
        let err = c.submit(two, Priority::Normal).unwrap_err();
        assert!(format!("{err:#}").contains("overloaded"), "{err:#}");
        // a fitting request on the same connection still runs
        let one = r#"{"jobs":[{"name":"a","scenario":"sparse-a01"}]}"#;
        assert_eq!(c.submit(one, Priority::Normal).unwrap().lines().count(), 1);
        handle.shutdown().unwrap();
    }

    #[test]
    fn failed_jobs_abort_the_request_but_not_the_connection() {
        let handle = spawn(test_opts()).unwrap();
        let mut c = Client::connect(&handle.addr.to_string()).unwrap();
        // first job succeeds and streams; second fails; third is skipped
        let m = r#"{"jobs":[{"name":"ok","scenario":"sparse-a01"},
                            {"name":"bad","csv":"no/such/file.csv"},
                            {"name":"never","scenario":"grn-mid"}]}"#;
        c.send(&format!(
            "{{\"op\":\"submit\",\"manifest\":{m}}}"
        ))
        .unwrap();
        let first = c.recv().unwrap();
        assert!(record_from_result_frame(&first).is_some(), "{first}");
        let second = c.recv().unwrap();
        assert!(second.contains("\"job-failed\""), "{second}");
        assert!(second.contains("bad"), "{second}");
        // the connection survives and the inflight gauge drained
        let stats = c.stats().unwrap();
        let v = Json::parse(&stats).unwrap();
        assert_eq!(
            v.get("stats")
                .unwrap()
                .get("jobs_inflight")
                .unwrap()
                .as_usize(),
            Some(0),
            "{stats}"
        );
        handle.shutdown().unwrap();
    }
}
