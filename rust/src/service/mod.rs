//! The multi-job batch service layer — cuPC's amortization story lifted
//! one level up.
//!
//! The paper amortizes fixed cost across many CI tests inside one PC
//! run; real causal-discovery users (ParallelPC, Le et al. 2015) run
//! *fleets* of related runs — many datasets, alphas, correlation kinds —
//! on one machine. This subsystem batches whole PC jobs the same way
//! the kernels batch tests:
//!
//! * [`job`] — [`job::JobSpec`] / [`job::Manifest`]: JSON job manifests
//!   addressing CSV files, registry datasets, or scenario-grid points;
//! * [`scheduler`] — [`scheduler::run_batch`]: N jobs in flight under
//!   one global [`scheduler::ThreadBudget`] shared with each job's
//!   skeleton pipeline; leases are *elastic*
//!   ([`scheduler::ElasticLease`]): jobs re-lease between skeleton
//!   levels, so a long tail level absorbs workers freed by finished
//!   jobs instead of leaving them idle;
//! * [`cache`] — [`cache::Cache`]: content-addressed two-layer LRU
//!   (data → correlation matrix, correlation + config → result) so
//!   repeated alphas over one dataset skip the gram and repeated jobs
//!   skip everything;
//! * [`store`] — [`store::DiskStore`]: the same two layers spilled to a
//!   persistent `--cache-dir` (versioned, checksummed, LRU-evicted by
//!   byte budget), so repeated `cupc batch` *invocations* — including
//!   concurrent processes — share warm grams and results; corruption is
//!   always a miss, never an error;
//! * [`report`] — deterministic JSON-lines results plus an
//!   observational stats sidecar;
//! * [`proto`] / [`server`] — `cupc serve`: a long-lived multi-tenant
//!   daemon over the same layer. Clients ship manifests over a
//!   loopback-only length-prefixed JSON protocol
//!   ([`proto`]), results stream back record by record, and one
//!   process keeps both cache tiers warm across requests while
//!   admission control (job cap, connection cap, idle / slow-loris
//!   timeouts) keeps any one tenant from queueing the daemon to death.
//!
//! **Determinism contract** (extends the pipeline's): the rendered
//! results stream is bit-identical for any `--job-threads`, any thread
//! budget, any between-level re-lease schedule, cold / warm-memory /
//! warm-disk cache, and batch vs. serve delivery with any number of
//! concurrent clients. Scheduling, caching and transport may only move
//! wall-clock time. Gated end to end by `tests/batch_runner.rs` and
//! `tests/serve_conformance.rs`.

pub mod cache;
pub mod job;
pub mod proto;
pub mod report;
pub mod scheduler;
pub mod server;
pub mod store;

pub use cache::{Cache, CacheStats};
pub use job::{DataSource, JobSpec, Manifest};
pub use proto::{Priority, Request};
pub use report::{render_results, render_stats, CacheOutcome, JobReport, JobResultCore};
pub use scheduler::{run_batch, run_job, BatchOptions, BatchOutput, ElasticLease, ThreadBudget};
pub use server::{Client, ServeOptions, Server, ServerHandle};
pub use store::{DiskStats, DiskStore};
