//! JSON-lines reporting for batch runs.
//!
//! Two streams with different contracts:
//!
//! * **results** ([`render_results`]) — one record per job, manifest
//!   order, containing only *deterministic* fields (spec echo, per-level
//!   tests/removed/edges_after, skeleton and CPDAG edge lists). The
//!   batch determinism gate requires this stream to be bit-identical
//!   for any `--job-threads`, any thread budget, and warm vs. cold
//!   cache — so wall-clock timings and cache hit/miss flags are
//!   banned here by construction.
//! * **stats** ([`render_stats`]) — the observational sidecar: per-job
//!   phase timings, leased worker width, cache hit/miss per layer, and
//!   a trailing cache-summary record. Useful for throughput tracking,
//!   never for result comparison.

use super::cache::CacheStats;
use super::job::JobSpec;
use super::store::DiskStats;
use crate::api::{OrderResult, PcResult};
use crate::util::json::escape;
use std::sync::Arc;

/// Where a cached layer was served from (observational — stats stream
/// only; the results stream must not depend on cache state).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheOutcome {
    /// computed fresh this run (and written to every configured tier)
    Miss,
    /// served from the in-process cache
    Mem,
    /// loaded from the persistent store (`--cache-dir`)
    Disk,
}

impl CacheOutcome {
    /// Stable spelling used in the stats sidecar (CI greps these).
    pub fn name(self) -> &'static str {
        match self {
            CacheOutcome::Miss => "miss",
            CacheOutcome::Mem => "mem",
            CacheOutcome::Disk => "disk",
        }
    }

    pub fn is_hit(self) -> bool {
        !matches!(self, CacheOutcome::Miss)
    }
}

/// One level's deterministic bookkeeping.
#[derive(Clone, Debug, PartialEq)]
pub struct LevelRow {
    pub level: usize,
    pub tests: u64,
    pub removed: usize,
    pub edges_after: usize,
}

/// Orientation-phase bookkeeping — the deterministic counterpart of the
/// per-level rows (census CI tests are counted like skeleton tests;
/// see `crate::orient::OrientStats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OrientRow {
    /// unshielded triples examined
    pub triples: u64,
    /// majority-census CI tests evaluated (0 under the first-sepset rule)
    pub census_tests: u64,
    /// Meek sweeps that oriented at least one edge
    pub meek_sweeps: u64,
}

/// The deterministic core of a finished job — exactly what the result
/// cache stores, so a cache hit and a recomputation are interchangeable
/// by construction (asserted bitwise by the batch suite).
#[derive(Clone, Debug, PartialEq)]
pub struct JobResultCore {
    pub n: usize,
    pub m: usize,
    /// orientation-phase counters (deterministic, so they live in the
    /// results stream, not the stats sidecar)
    pub orient: OrientRow,
    pub levels: Vec<LevelRow>,
    /// undirected skeleton edges, (i, j) with i < j, row-major order
    pub skeleton_edges: Vec<(u32, u32)>,
    /// CPDAG arrows i → j — or, for a causal-order family, the pruned
    /// DAG's arrows (every edge of an order engine is directed)
    pub directed: Vec<(u32, u32)>,
    /// CPDAG undirected edges, (i, j) with i < j (always empty for
    /// causal-order families)
    pub undirected: Vec<(u32, u32)>,
    /// the estimated causal order, roots first — empty for PC families,
    /// whose output is a CPDAG, not an order
    pub order: Vec<u32>,
}

impl JobResultCore {
    pub fn from_pc(res: &PcResult, n: usize, m: usize) -> Self {
        let levels = res
            .skeleton
            .levels
            .iter()
            .map(|l| LevelRow {
                level: l.level,
                tests: l.tests,
                removed: l.removed,
                edges_after: l.edges_after,
            })
            .collect();
        let as_u32 = |v: Vec<(usize, usize)>| -> Vec<(u32, u32)> {
            v.into_iter().map(|(i, j)| (i as u32, j as u32)).collect()
        };
        JobResultCore {
            n,
            m,
            orient: OrientRow {
                triples: res.orient.triples as u64,
                census_tests: res.orient.census_tests,
                meek_sweeps: res.orient.meek_sweeps as u64,
            },
            levels,
            skeleton_edges: as_u32(res.skeleton.graph.edges()),
            directed: as_u32(res.cpdag.directed_edges()),
            undirected: as_u32(res.cpdag.undirected_edges()),
            order: Vec::new(),
        }
    }

    /// The deterministic core of a causal-order run: the DAG adjacency
    /// flows into the same row shape PC jobs use (rounds as level rows,
    /// arrows in `directed`, the undirected support in
    /// `skeleton_edges`), plus the order itself. Orientation counters
    /// stay zero — there is no orientation phase to count.
    pub fn from_order(res: &OrderResult, n: usize, m: usize) -> Self {
        let levels = res
            .rounds
            .iter()
            .map(|l| LevelRow {
                level: l.level,
                tests: l.tests,
                removed: l.removed,
                edges_after: l.edges_after,
            })
            .collect();
        let mut directed: Vec<(u32, u32)> = res
            .edges
            .iter()
            .map(|&(a, b, _w)| (a as u32, b as u32))
            .collect();
        directed.sort_unstable();
        let mut skeleton_edges: Vec<(u32, u32)> = directed
            .iter()
            .map(|&(i, j)| (i.min(j), i.max(j)))
            .collect();
        skeleton_edges.sort_unstable();
        skeleton_edges.dedup();
        JobResultCore {
            n,
            m,
            orient: OrientRow::default(),
            levels,
            skeleton_edges,
            directed,
            undirected: Vec::new(),
            order: res.order.iter().map(|&v| v as u32).collect(),
        }
    }

    /// Approximate heap footprint, for the cache's byte budget.
    pub fn approx_bytes(&self) -> usize {
        self.levels.len() * std::mem::size_of::<LevelRow>()
            + (self.skeleton_edges.len() + self.directed.len() + self.undirected.len())
                * std::mem::size_of::<(u32, u32)>()
            + self.order.len() * std::mem::size_of::<u32>()
            + std::mem::size_of::<Self>()
    }

    /// Stable little-endian binary encoding for the persistent store
    /// (`service::store`). The layout is versioned by the store's
    /// schema header, not here — any layout change must bump
    /// [`super::store::SCHEMA_VERSION`] so old entries degrade to
    /// misses instead of misparsing.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            8 * (6 + 4 * self.levels.len())
                + 8 * (self.skeleton_edges.len()
                    + self.directed.len()
                    + self.undirected.len())
                + 24,
        );
        let push_u64 = |b: &mut Vec<u8>, x: u64| b.extend_from_slice(&x.to_le_bytes());
        push_u64(&mut b, self.n as u64);
        push_u64(&mut b, self.m as u64);
        push_u64(&mut b, self.orient.triples);
        push_u64(&mut b, self.orient.census_tests);
        push_u64(&mut b, self.orient.meek_sweeps);
        push_u64(&mut b, self.levels.len() as u64);
        for l in &self.levels {
            push_u64(&mut b, l.level as u64);
            push_u64(&mut b, l.tests);
            push_u64(&mut b, l.removed as u64);
            push_u64(&mut b, l.edges_after as u64);
        }
        for list in [&self.skeleton_edges, &self.directed, &self.undirected] {
            push_u64(&mut b, list.len() as u64);
            for &(i, j) in list.iter() {
                b.extend_from_slice(&i.to_le_bytes());
                b.extend_from_slice(&j.to_le_bytes());
            }
        }
        // causal-order section (schema v3; empty for PC families)
        push_u64(&mut b, self.order.len() as u64);
        for &v in &self.order {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b
    }

    /// Inverse of [`JobResultCore::to_bytes`]. `None` on any structural
    /// mismatch (short buffer, trailing bytes, counts that don't fit) —
    /// the store treats that as entry corruption, i.e. a miss.
    pub fn from_bytes(b: &[u8]) -> Option<JobResultCore> {
        struct Rd<'a> {
            b: &'a [u8],
            pos: usize,
        }
        impl Rd<'_> {
            fn u64(&mut self) -> Option<u64> {
                let end = self.pos.checked_add(8)?;
                let v = u64::from_le_bytes(self.b.get(self.pos..end)?.try_into().ok()?);
                self.pos = end;
                Some(v)
            }
            fn u32(&mut self) -> Option<u32> {
                let end = self.pos.checked_add(4)?;
                let v = u32::from_le_bytes(self.b.get(self.pos..end)?.try_into().ok()?);
                self.pos = end;
                Some(v)
            }
            /// a claimed element count is only trusted if the bytes for
            /// it are actually present (guards allocation on corruption)
            fn len(&mut self, elem_bytes: usize) -> Option<usize> {
                let n = usize::try_from(self.u64()?).ok()?;
                let need = n.checked_mul(elem_bytes)?;
                if self.b.len() - self.pos < need {
                    return None;
                }
                Some(n)
            }
        }
        let mut r = Rd { b, pos: 0 };
        let n = usize::try_from(r.u64()?).ok()?;
        let m = usize::try_from(r.u64()?).ok()?;
        let orient = OrientRow {
            triples: r.u64()?,
            census_tests: r.u64()?,
            meek_sweeps: r.u64()?,
        };
        let nlevels = r.len(32)?;
        let mut levels = Vec::with_capacity(nlevels);
        for _ in 0..nlevels {
            levels.push(LevelRow {
                level: usize::try_from(r.u64()?).ok()?,
                tests: r.u64()?,
                removed: usize::try_from(r.u64()?).ok()?,
                edges_after: usize::try_from(r.u64()?).ok()?,
            });
        }
        let mut lists: [Vec<(u32, u32)>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for list in &mut lists {
            let nedges = r.len(8)?;
            list.reserve_exact(nedges);
            for _ in 0..nedges {
                list.push((r.u32()?, r.u32()?));
            }
        }
        let norder = r.len(4)?;
        let mut order = Vec::with_capacity(norder);
        for _ in 0..norder {
            order.push(r.u32()?);
        }
        if r.pos != b.len() {
            return None; // trailing garbage is corruption, not slack
        }
        let [skeleton_edges, directed, undirected] = lists;
        Some(JobResultCore {
            n,
            m,
            orient,
            levels,
            skeleton_edges,
            directed,
            undirected,
            order,
        })
    }
}

/// Everything known about a finished job. Deterministic data lives in
/// [`JobResultCore`]; the rest is observational and only ever reaches
/// the stats stream.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub core: Arc<JobResultCore>,
    /// seconds resolving the data source (CSV read / simulation)
    pub seconds_load: f64,
    /// seconds in the correlation phase (≈ 0 on a cache hit)
    pub seconds_corr: f64,
    /// seconds in skeleton + orientation (≈ 0 on a cache hit)
    pub seconds_run: f64,
    /// where the correlation matrix came from
    pub corr_cache: CacheOutcome,
    /// where the result core came from
    pub result_cache: CacheOutcome,
    /// workers leased from the shared budget when the job started
    pub threads_used: usize,
    /// widest the job's elastic lease ever grew (≥ `threads_used`)
    pub threads_peak: usize,
    /// adjacency representation the skeleton's level loop selected
    /// (`"dense"` | `"sparse"` — [`crate::skeleton::OocStats`] spellings;
    /// `"dense"` on a cache hit, where no skeleton ran)
    pub adjacency: &'static str,
    /// peak bytes held by the skeleton's streamed window buffer (0 on a
    /// cache hit) — the observable side of the bounded-memory contract
    pub peak_window_bytes: u64,
}

fn edges_json(edges: &[(u32, u32)]) -> String {
    let mut s = String::with_capacity(2 + edges.len() * 8);
    s.push('[');
    for (idx, (i, j)) in edges.iter().enumerate() {
        if idx > 0 {
            s.push(',');
        }
        s.push_str(&format!("[{i},{j}]"));
    }
    s.push(']');
    s
}

/// One deterministic JSON-lines result record.
pub fn result_line(spec: &JobSpec, core: &JobResultCore) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{{\"job\":\"{}\",\"source\":\"{}\",\"variant\":\"{}\",\"corr\":\"{}\",\
         \"orient\":\"{}\",\"alpha\":{},\"max_level\":{},\"n\":{},\"m\":{}",
        escape(&spec.name),
        escape(&spec.source.label()),
        spec.variant_name(),
        spec.corr.name(),
        spec.orient_name(),
        spec.alpha,
        spec.max_level
            .map(|l| l.to_string())
            .unwrap_or_else(|| "null".into()),
        core.n,
        core.m
    ));
    s.push_str(&format!(",\"edges\":{}", core.skeleton_edges.len()));
    s.push_str(",\"levels\":[");
    for (idx, l) in core.levels.iter().enumerate() {
        if idx > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"level\":{},\"tests\":{},\"removed\":{},\"edges_after\":{}}}",
            l.level, l.tests, l.removed, l.edges_after
        ));
    }
    s.push(']');
    s.push_str(&format!(
        ",\"orientation\":{{\"triples\":{},\"census_tests\":{},\"meek_sweeps\":{}}}",
        core.orient.triples, core.orient.census_tests, core.orient.meek_sweeps
    ));
    s.push_str(&format!(",\"skeleton\":{}", edges_json(&core.skeleton_edges)));
    s.push_str(&format!(",\"directed\":{}", edges_json(&core.directed)));
    s.push_str(&format!(",\"undirected\":{}", edges_json(&core.undirected)));
    if !core.order.is_empty() {
        // causal-order families only — PC records keep their exact
        // historical shape (the byte-identity gates depend on it)
        let mut o = String::with_capacity(2 + core.order.len() * 4);
        o.push('[');
        for (idx, v) in core.order.iter().enumerate() {
            if idx > 0 {
                o.push(',');
            }
            o.push_str(&v.to_string());
        }
        o.push(']');
        s.push_str(&format!(",\"order\":{o}"));
    }
    s.push('}');
    s
}

/// One observational JSON-lines stats record. `corr_cache` /
/// `result_cache` say where each layer was served from
/// (`miss` | `mem` | `disk` — the CI warm-cache gate greps these);
/// `threads_peak` records how wide the elastic lease grew; `adjacency` /
/// `peak_window_bytes` record the skeleton's out-of-core behavior (the
/// CI oocore-smoke gate greps `adjacency`).
pub fn stats_line(spec: &JobSpec, rep: &JobReport) -> String {
    format!(
        "{{\"job\":\"{}\",\"threads\":{},\"threads_peak\":{},\"corr_cache\":\"{}\",\
         \"result_cache\":\"{}\",\
         \"seconds_load\":{:.6},\"seconds_corr\":{:.6},\"seconds_run\":{:.6},\
         \"adjacency\":\"{}\",\"peak_window_bytes\":{}}}",
        escape(&spec.name),
        rep.threads_used,
        rep.threads_peak,
        rep.corr_cache.name(),
        rep.result_cache.name(),
        rep.seconds_load,
        rep.seconds_corr,
        rep.seconds_run,
        rep.adjacency,
        rep.peak_window_bytes
    )
}

/// The deterministic results stream: one line per job, manifest order,
/// trailing newline.
pub fn render_results(jobs: &[JobSpec], reports: &[JobReport]) -> String {
    debug_assert_eq!(jobs.len(), reports.len());
    let mut s = String::new();
    for (spec, rep) in jobs.iter().zip(reports) {
        s.push_str(&result_line(spec, &rep.core));
        s.push('\n');
    }
    s
}

/// The in-process cache counters as a JSON object (no enclosing key).
/// Shared by the batch stats sidecar and the serve daemon's `/stats`
/// endpoint so both spell the fields identically (CI greps them).
pub fn cache_stats_json(c: &CacheStats) -> String {
    format!(
        "{{\"hits\":{},\"misses\":{},\"evictions\":{},\"entries\":{},\
         \"bytes\":{},\"budget\":{}}}",
        c.hits, c.misses, c.evictions, c.entries, c.bytes, c.budget
    )
}

/// The persistent-store counters as a JSON object (no enclosing key).
pub fn disk_stats_json(d: &DiskStats) -> String {
    format!(
        "{{\"hits\":{},\"misses\":{},\"evictions\":{},\"dropped\":{},\
         \"entries\":{},\"bytes\":{},\"budget\":{}}}",
        d.hits, d.misses, d.evictions, d.dropped, d.entries, d.bytes, d.budget
    )
}

/// The observational stats stream: per-job lines plus a trailing
/// in-process cache summary record — and, when a persistent store was
/// in play, a trailing disk-store record.
pub fn render_stats(
    jobs: &[JobSpec],
    reports: &[JobReport],
    cache: &CacheStats,
    disk: Option<&DiskStats>,
) -> String {
    debug_assert_eq!(jobs.len(), reports.len());
    let mut s = String::new();
    for (spec, rep) in jobs.iter().zip(reports) {
        s.push_str(&stats_line(spec, rep));
        s.push('\n');
    }
    s.push_str(&format!("{{\"cache\":{}}}\n", cache_stats_json(cache)));
    if let Some(d) = disk {
        s.push_str(&format!("{{\"disk\":{}}}\n", disk_stats_json(d)));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::family::FamilyId;
    use crate::service::job::DataSource;
    use crate::skeleton::{OrientRule, Variant};
    use crate::stats::corr::CorrKind;
    use crate::util::json::Json;

    fn toy_spec() -> JobSpec {
        JobSpec {
            name: "toy \"quoted\"".into(),
            source: DataSource::Scenario("sparse-a01".into()),
            family: FamilyId::Pc(Variant::CupcS),
            alpha: 0.01,
            max_level: Some(2),
            corr: CorrKind::Pearson,
            orient: OrientRule::Standard,
        }
    }

    fn toy_core() -> JobResultCore {
        JobResultCore {
            n: 4,
            m: 100,
            orient: OrientRow {
                triples: 3,
                census_tests: 12,
                meek_sweeps: 1,
            },
            levels: vec![
                LevelRow {
                    level: 0,
                    tests: 6,
                    removed: 2,
                    edges_after: 4,
                },
                LevelRow {
                    level: 1,
                    tests: 8,
                    removed: 1,
                    edges_after: 3,
                },
            ],
            skeleton_edges: vec![(0, 1), (1, 2), (2, 3)],
            directed: vec![(0, 1)],
            undirected: vec![(1, 2), (2, 3)],
            order: vec![],
        }
    }

    #[test]
    fn result_line_is_valid_json_with_the_deterministic_fields() {
        let line = result_line(&toy_spec(), &toy_core());
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("job").unwrap().as_str(), Some("toy \"quoted\""));
        assert_eq!(v.get("source").unwrap().as_str(), Some("scenario:sparse-a01"));
        assert_eq!(v.get("variant").unwrap().as_str(), Some("cupc-s"));
        assert_eq!(v.get("alpha").unwrap().as_f64(), Some(0.01));
        assert_eq!(v.get("max_level").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("edges").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("levels").unwrap().as_array().unwrap().len(), 2);
        let o = v.get("orientation").unwrap();
        assert_eq!(o.get("triples").unwrap().as_usize(), Some(3));
        assert_eq!(o.get("census_tests").unwrap().as_usize(), Some(12));
        assert_eq!(o.get("meek_sweeps").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("skeleton").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("directed").unwrap().as_array().unwrap().len(), 1);
        // no observational fields may leak into the deterministic stream
        assert!(v.get("seconds_run").is_none());
        assert!(v.get("corr_cache").is_none());
        assert!(v.get("threads").is_none());
        assert!(v.get("adjacency").is_none());
        assert!(v.get("peak_window_bytes").is_none());
        // PC records keep their exact historical shape: no order key
        assert!(v.get("order").is_none());
    }

    /// Causal-order jobs flow through the same row shape: rounds as
    /// level rows, DAG arrows in `directed`, the order as its own
    /// array — and the record parses as JSON like any PC record.
    #[test]
    fn order_results_render_with_the_dag_adjacency_shape() {
        let res = OrderResult {
            order: vec![2, 0, 1],
            edges: vec![(2, 0, 0.8), (2, 1, -0.6), (0, 1, 0.3)],
            rounds: vec![crate::skeleton::LevelStats {
                level: 0,
                tests: 3,
                removed: 1,
                edges_after: 2,
                seconds: 0.5,
            }],
            seconds: 1.0,
        };
        let core = JobResultCore::from_order(&res, 3, 100);
        assert_eq!(core.order, vec![2, 0, 1]);
        assert_eq!(core.directed, vec![(0, 1), (2, 0), (2, 1)], "row-major");
        assert_eq!(core.skeleton_edges, vec![(0, 1), (0, 2), (1, 2)]);
        assert!(core.undirected.is_empty());
        assert_eq!(core.orient, OrientRow::default());
        assert_eq!(core.levels.len(), 1);
        assert_eq!(core.levels[0].tests, 3);

        let mut spec = toy_spec();
        spec.family = FamilyId::Lingam;
        let v = Json::parse(&result_line(&spec, &core)).unwrap();
        assert_eq!(v.get("variant").unwrap().as_str(), Some("lingam"));
        let order = v.get("order").unwrap().as_array().unwrap();
        let got: Vec<usize> = order.iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(got, vec![2, 0, 1]);
        assert_eq!(v.get("directed").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("undirected").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn uncapped_max_level_serializes_as_null() {
        let mut spec = toy_spec();
        spec.max_level = None;
        let v = Json::parse(&result_line(&spec, &toy_core())).unwrap();
        assert!(v.get("max_level").unwrap().is_null());
    }

    #[test]
    fn stats_line_is_valid_json_with_the_observational_fields() {
        let rep = JobReport {
            core: Arc::new(toy_core()),
            seconds_load: 0.25,
            seconds_corr: 0.5,
            seconds_run: 1.0,
            corr_cache: CacheOutcome::Disk,
            result_cache: CacheOutcome::Miss,
            threads_used: 3,
            threads_peak: 5,
            adjacency: "sparse",
            peak_window_bytes: 4096,
        };
        let v = Json::parse(&stats_line(&toy_spec(), &rep)).unwrap();
        assert_eq!(v.get("corr_cache").unwrap().as_str(), Some("disk"));
        assert_eq!(v.get("result_cache").unwrap().as_str(), Some("miss"));
        assert_eq!(v.get("threads").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("threads_peak").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("seconds_run").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("adjacency").unwrap().as_str(), Some("sparse"));
        assert_eq!(v.get("peak_window_bytes").unwrap().as_usize(), Some(4096));
    }

    #[test]
    fn cache_outcome_names_are_the_ci_grep_contract() {
        // .github/workflows/ci.yml greps these exact spellings in the
        // warm-cache gate — renaming them silently breaks that job
        assert_eq!(CacheOutcome::Miss.name(), "miss");
        assert_eq!(CacheOutcome::Mem.name(), "mem");
        assert_eq!(CacheOutcome::Disk.name(), "disk");
        assert!(!CacheOutcome::Miss.is_hit());
        assert!(CacheOutcome::Mem.is_hit());
        assert!(CacheOutcome::Disk.is_hit());
    }

    #[test]
    fn render_streams_are_line_per_job() {
        let jobs = vec![toy_spec()];
        let reports = vec![JobReport {
            core: Arc::new(toy_core()),
            seconds_load: 0.0,
            seconds_corr: 0.0,
            seconds_run: 0.0,
            corr_cache: CacheOutcome::Miss,
            result_cache: CacheOutcome::Miss,
            threads_used: 1,
            threads_peak: 1,
            adjacency: "dense",
            peak_window_bytes: 0,
        }];
        let results = render_results(&jobs, &reports);
        assert_eq!(results.lines().count(), 1);
        assert!(results.ends_with('\n'));
        let cache = CacheStats {
            hits: 1,
            misses: 2,
            evictions: 0,
            entries: 3,
            bytes: 1024,
            budget: 4096,
        };
        let stats = render_stats(&jobs, &reports, &cache, None);
        assert_eq!(stats.lines().count(), 2, "jobs + cache summary");
        let last = stats.lines().last().unwrap();
        let v = Json::parse(last).unwrap();
        assert_eq!(
            v.get("cache").unwrap().get("hits").unwrap().as_usize(),
            Some(1)
        );
        // with a disk store, a trailing disk record is appended
        let disk = DiskStats {
            hits: 4,
            misses: 1,
            evictions: 2,
            dropped: 1,
            entries: 6,
            bytes: 2048,
            budget: 1 << 20,
        };
        let stats = render_stats(&jobs, &reports, &cache, Some(&disk));
        assert_eq!(stats.lines().count(), 3, "jobs + cache + disk");
        let v = Json::parse(stats.lines().last().unwrap()).unwrap();
        let d = v.get("disk").unwrap();
        assert_eq!(d.get("hits").unwrap().as_usize(), Some(4));
        assert_eq!(d.get("dropped").unwrap().as_usize(), Some(1));
    }

    /// Level rows are self-describing (each carries its own `level`
    /// field), so a family whose bookkeeping arrives in descending or
    /// gapped level order — the reversed-order schedule's natural shape —
    /// must flow through the JSON render and the binary codec verbatim,
    /// with no sorting, renumbering, or contiguity assumption anywhere.
    #[test]
    fn level_rows_tolerate_descending_and_gapped_order() {
        let mut core = toy_core();
        core.levels = vec![
            LevelRow { level: 3, tests: 5, removed: 1, edges_after: 2 },
            LevelRow { level: 1, tests: 9, removed: 0, edges_after: 3 },
            LevelRow { level: 0, tests: 6, removed: 2, edges_after: 4 },
        ];
        let bytes = core.to_bytes();
        let back = JobResultCore::from_bytes(&bytes).expect("roundtrip");
        assert_eq!(back.levels, core.levels, "codec must preserve row order");

        let mut spec = toy_spec();
        spec.family = FamilyId::Pc(Variant::Reversed);
        let v = Json::parse(&result_line(&spec, &core)).unwrap();
        assert_eq!(v.get("variant").unwrap().as_str(), Some("reversed"));
        let rows = v.get("levels").unwrap().as_array().unwrap();
        let levels: Vec<usize> = rows
            .iter()
            .map(|r| r.get("level").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(levels, vec![3, 1, 0], "render must preserve row order");
        assert_eq!(rows[0].get("tests").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn core_binary_roundtrip_is_exact() {
        for core in [
            toy_core(),
            JobResultCore {
                n: 0,
                m: 0,
                orient: OrientRow::default(),
                levels: vec![],
                skeleton_edges: vec![],
                directed: vec![],
                undirected: vec![],
                order: vec![],
            },
            {
                let mut c = toy_core();
                c.order = vec![3, 0, 1, 2];
                c.undirected = vec![];
                c
            },
        ] {
            let bytes = core.to_bytes();
            let back = JobResultCore::from_bytes(&bytes).expect("roundtrip");
            assert_eq!(back, core);
        }
    }

    #[test]
    fn corrupt_core_bytes_decode_to_none_not_panic() {
        let bytes = toy_core().to_bytes();
        // truncations at every boundary
        for cut in [0, 1, 7, 8, 23, bytes.len() - 1] {
            assert!(JobResultCore::from_bytes(&bytes[..cut]).is_none(), "cut={cut}");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(JobResultCore::from_bytes(&long).is_none());
        // absurd claimed list length must not allocate or panic
        let mut lie = bytes.clone();
        let lvl_count_at = 40; // after n, m and the three orientation counters
        lie[lvl_count_at..lvl_count_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(JobResultCore::from_bytes(&lie).is_none());
    }

    #[test]
    fn approx_bytes_scales_with_edges() {
        let small = toy_core();
        let mut big = toy_core();
        big.skeleton_edges = (0..1000u32).map(|i| (i, i + 1)).collect();
        assert!(big.approx_bytes() > small.approx_bytes() + 7000);
    }
}
