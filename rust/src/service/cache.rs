//! Content-addressed in-process result cache with an LRU byte budget.
//!
//! Two layers mirror the two expensive phases of a PC job:
//!
//! * **data bytes + correlation kind → correlation matrix** — repeated
//!   alphas / variants / level caps over one dataset skip the gram
//!   computation entirely;
//! * **correlation bytes + run parameters → [`JobResultCore`]** — an
//!   identical job resubmitted while the cache is warm skips the whole
//!   skeleton + orientation run.
//!
//! Keys are 128-bit content hashes (two independent 64-bit streams over
//! the same bytes — not cryptographic, but a practical collision floor
//! far below the job counts a single process sees). Values are `Arc`s,
//! so a hit is a pointer clone and cached-vs-recomputed results are
//! bitwise interchangeable by construction. Eviction is
//! least-recently-touched under a byte budget; an entry larger than the
//! whole budget is simply not cached (it would evict everything and
//! still not fit).
//!
//! Determinism: the cache can change *when* work happens, never *what*
//! it produces — values are exactly the bytes a cold computation would
//! produce, so warm and cold batch runs render identical results files
//! (gated by `tests/batch_runner.rs`).
//!
//! This module is the in-process tier; [`super::store::DiskStore`]
//! persists the same two content-addressed layers (same [`Key`]s) under
//! `--cache-dir` so they survive the process and are shared across
//! concurrent invocations. The scheduler probes memory first, then
//! disk, then recomputes — populating both tiers on the way out.

use super::report::JobResultCore;
use crate::family::FamilyId;
use crate::skeleton::OrientRule;
use crate::stats::corr::{CorrKind, DataMatrix};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

/// 128-bit content key.
pub type Key = (u64, u64);

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;
/// splitmix64-style constant for the second, independent stream
const MIX_OFFSET: u64 = 0x6c62272e07bb0142;
const MIX_PRIME: u64 = 0x9e3779b97f4a7c15;

/// Two-stream byte hasher: FNV-1a plus a rotate-multiply accumulator.
/// Chunking never matters — `write(a); write(b)` ≡ `write(a ++ b)`,
/// so callers can stream fields without worrying about framing:
///
/// ```
/// use cupc::service::cache::ContentHasher;
///
/// let mut chunked = ContentHasher::new();
/// chunked.write(b"corr-");
/// chunked.write(b"bytes");
/// let mut whole = ContentHasher::new();
/// whole.write(b"corr-bytes");
/// assert_eq!(chunked.finish(), whole.finish());
///
/// let mut other = ContentHasher::new();
/// other.write(b"corr+bytes");
/// assert_ne!(other.finish(), whole.finish());
/// ```
pub struct ContentHasher {
    a: u64,
    b: u64,
}

impl Default for ContentHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl ContentHasher {
    pub fn new() -> Self {
        ContentHasher {
            a: FNV_OFFSET,
            b: MIX_OFFSET,
        }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &x in bytes {
            self.a = (self.a ^ x as u64).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ x as u64).wrapping_mul(MIX_PRIME).rotate_left(17);
        }
    }

    pub fn write_u8(&mut self, x: u8) {
        self.write(&[x]);
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    /// Hash the exact bit patterns (not the numeric values): the cache
    /// must distinguish inputs that differ in any bit.
    pub fn write_f64s(&mut self, xs: &[f64]) {
        for x in xs {
            self.write(&x.to_le_bytes());
        }
    }

    pub fn finish(&self) -> Key {
        (self.a, self.b)
    }
}

/// Key for the correlation layer: data bytes + shape + estimator kind.
pub fn data_key(data: &DataMatrix, kind: CorrKind) -> Key {
    let mut h = ContentHasher::new();
    h.write_u64(data.m as u64);
    h.write_u64(data.n as u64);
    h.write_u8(kind.tag());
    h.write_f64s(&data.x);
    h.finish()
}

/// Key for the result layer: input bytes + shape + run parameters.
///
/// `input` is the family's actual numeric input — the correlation
/// matrix for PC families, the raw data columns for causal-order
/// families (which never compute a correlation matrix). The family tag
/// (registry `tag`, unique across both kinds) keys them apart even if
/// the byte streams collided.
#[allow(clippy::too_many_arguments)] // a key is its full parameter list
pub fn result_key(
    input: &[f64],
    n: usize,
    m: usize,
    alpha: f64,
    max_level: Option<usize>,
    family: FamilyId,
    orient: OrientRule,
) -> Key {
    let mut h = ContentHasher::new();
    h.write_u64(n as u64);
    h.write_u64(m as u64);
    h.write_f64s(&[alpha]);
    h.write_u64(max_level.map(|l| l as u64).unwrap_or(u64::MAX));
    h.write_u8(super::job::family_tag(family));
    h.write_u8(super::job::orient_tag(orient));
    h.write_f64s(input);
    h.finish()
}

enum Slot {
    Corr(Arc<Vec<f64>>),
    Result(Arc<JobResultCore>),
}

struct Entry {
    value: Slot,
    bytes: usize,
    stamp: u64,
}

/// bookkeeping overhead charged per entry on top of the payload
const ENTRY_OVERHEAD: usize = 64;

struct Inner {
    map: HashMap<Key, Entry>,
    clock: u64,
    bytes: usize,
    budget: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Thread-safe cache shared by every job worker of a batch run.
pub struct Cache {
    inner: Mutex<Inner>,
    /// keys currently being computed (in-flight coalescing)
    inflight: Mutex<HashSet<Key>>,
    inflight_cv: Condvar,
}

/// The exclusive right to compute one key's value. Dropping the claim —
/// normally after `put_*`, but also during unwinding — releases the key
/// and wakes every waiter, so a failed or panicked computation can
/// never strand the other workers.
pub struct ComputeClaim<'a> {
    cache: &'a Cache,
    key: Key,
}

impl Drop for ComputeClaim<'_> {
    fn drop(&mut self) {
        let mut g = self.cache.inflight.lock().unwrap();
        g.remove(&self.key);
        drop(g);
        self.cache.inflight_cv.notify_all();
    }
}

/// Aggregate counters (the stats stream's trailing record).
#[derive(Clone, Debug, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
    pub budget: usize,
}

impl Cache {
    pub fn new(budget_bytes: usize) -> Self {
        Cache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                clock: 0,
                bytes: 0,
                budget: budget_bytes,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            inflight: Mutex::new(HashSet::new()),
            inflight_cv: Condvar::new(),
        }
    }

    /// Claim the right to compute `key`'s value, coalescing concurrent
    /// computations of the same content: `Some(claim)` means the caller
    /// is the computer (put the value, then drop the claim); `None`
    /// means another thread held the claim and has since released it —
    /// re-check the cache (the value is there unless the computer
    /// failed or the entry was evicted immediately, in which case a
    /// fresh `claim_compute` will claim). Without this, N jobs over the
    /// same dataset would each run the full gram and the amortization
    /// would vanish exactly when jobs run concurrently.
    pub fn claim_compute(&self, key: Key) -> Option<ComputeClaim<'_>> {
        let mut g = self.inflight.lock().unwrap();
        if g.insert(key) {
            return Some(ComputeClaim { cache: self, key });
        }
        while g.contains(&key) {
            g = self.inflight_cv.wait(g).unwrap();
        }
        None
    }

    pub fn get_corr(&self, key: Key) -> Option<Arc<Vec<f64>>> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let found = match g.map.get_mut(&key) {
            Some(Entry {
                value: Slot::Corr(v),
                stamp,
                ..
            }) => {
                *stamp = clock;
                Some(v.clone())
            }
            _ => None,
        };
        if found.is_some() {
            g.hits += 1;
        } else {
            g.misses += 1;
        }
        found
    }

    pub fn get_result(&self, key: Key) -> Option<Arc<JobResultCore>> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let found = match g.map.get_mut(&key) {
            Some(Entry {
                value: Slot::Result(v),
                stamp,
                ..
            }) => {
                *stamp = clock;
                Some(v.clone())
            }
            _ => None,
        };
        if found.is_some() {
            g.hits += 1;
        } else {
            g.misses += 1;
        }
        found
    }

    pub fn put_corr(&self, key: Key, v: Arc<Vec<f64>>) {
        let bytes = v.len() * std::mem::size_of::<f64>() + ENTRY_OVERHEAD;
        self.put(key, bytes, Slot::Corr(v));
    }

    pub fn put_result(&self, key: Key, v: Arc<JobResultCore>) {
        let bytes = v.approx_bytes() + ENTRY_OVERHEAD;
        self.put(key, bytes, Slot::Result(v));
    }

    fn put(&self, key: Key, bytes: usize, value: Slot) {
        let mut g = self.inner.lock().unwrap();
        if bytes > g.budget {
            return; // larger than the whole budget: not cacheable
        }
        g.clock += 1;
        let stamp = g.clock;
        if let Some(old) = g.map.insert(key, Entry { value, bytes, stamp }) {
            g.bytes -= old.bytes;
        }
        g.bytes += bytes;
        while g.bytes > g.budget {
            // evict the least-recently-touched entry; the entry just
            // inserted carries the newest stamp so it survives
            let victim = g
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            match victim {
                Some(k) if k != key => {
                    let e = g.map.remove(&k).unwrap();
                    g.bytes -= e.bytes;
                    g.evictions += 1;
                }
                _ => break,
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            entries: g.map.len(),
            bytes: g.bytes,
            budget: g.budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skeleton::Variant;

    fn toy_data(seed: u64) -> DataMatrix {
        use crate::util::rng::Pcg;
        let (m, n) = (20, 4);
        let mut rng = Pcg::seeded(seed);
        let x: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        DataMatrix::new(x, m, n)
    }

    #[test]
    fn hashing_is_stable_and_chunking_invariant() {
        let mut one = ContentHasher::new();
        one.write(b"abcdef");
        let mut split = ContentHasher::new();
        split.write(b"ab");
        split.write(b"cdef");
        assert_eq!(one.finish(), split.finish());

        let d = toy_data(1);
        assert_eq!(
            data_key(&d, CorrKind::Pearson),
            data_key(&d, CorrKind::Pearson),
            "same input must key identically across calls"
        );
    }

    #[test]
    fn keys_separate_distinct_inputs() {
        let d1 = toy_data(1);
        let d2 = toy_data(2);
        assert_ne!(data_key(&d1, CorrKind::Pearson), data_key(&d2, CorrKind::Pearson));
        assert_ne!(
            data_key(&d1, CorrKind::Pearson),
            data_key(&d1, CorrKind::Spearman),
            "the correlation kind is part of the identity"
        );
        // shape is hashed, not just bytes: 4×2 vs 2×4 of the same values
        let a = DataMatrix::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 4, 2);
        let b = DataMatrix::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 2, 4);
        assert_ne!(data_key(&a, CorrKind::Pearson), data_key(&b, CorrKind::Pearson));
    }

    #[test]
    fn result_keys_separate_run_parameters() {
        let cups = FamilyId::Pc(Variant::CupcS);
        let cupe = FamilyId::Pc(Variant::CupcE);
        let corr = vec![1.0, 0.5, 0.5, 1.0];
        let base = result_key(&corr, 2, 100, 0.01, None, cups, OrientRule::Standard);
        for other in [
            result_key(&corr, 2, 100, 0.05, None, cups, OrientRule::Standard),
            result_key(&corr, 2, 100, 0.01, Some(2), cups, OrientRule::Standard),
            result_key(&corr, 2, 100, 0.01, None, cupe, OrientRule::Standard),
            result_key(&corr, 2, 100, 0.01, None, cups, OrientRule::Majority),
            result_key(&corr, 2, 200, 0.01, None, cups, OrientRule::Standard),
            // the two engine kinds can never share a result entry,
            // even over identical input bytes
            result_key(&corr, 2, 100, 0.01, None, FamilyId::Lingam, OrientRule::Standard),
        ] {
            assert_ne!(base, other);
        }
    }

    fn corr_of(len: usize, fill: f64) -> Arc<Vec<f64>> {
        Arc::new(vec![fill; len])
    }

    #[test]
    fn get_returns_the_exact_cached_value() {
        let cache = Cache::new(1 << 20);
        let v = corr_of(16, 0.25);
        cache.put_corr((1, 1), v.clone());
        let got = cache.get_corr((1, 1)).expect("hit");
        assert_eq!(*got, *v, "cached value must be bitwise identical");
        assert!(cache.get_corr((2, 2)).is_none());
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
    }

    #[test]
    fn lru_evicts_least_recently_touched_under_byte_budget() {
        // each entry: 16 f64 = 128 bytes + overhead 64 = 192; budget
        // fits two entries but not three
        let budget = 2 * 192 + 10;
        let cache = Cache::new(budget);
        cache.put_corr((1, 0), corr_of(16, 1.0));
        cache.put_corr((2, 0), corr_of(16, 2.0));
        // touch (1,0) so (2,0) becomes the LRU victim
        assert!(cache.get_corr((1, 0)).is_some());
        cache.put_corr((3, 0), corr_of(16, 3.0));
        assert!(cache.get_corr((1, 0)).is_some(), "recently touched survives");
        assert!(cache.get_corr((2, 0)).is_none(), "LRU entry evicted");
        assert!(cache.get_corr((3, 0)).is_some(), "new entry present");
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert!(st.bytes <= st.budget, "{} > {}", st.bytes, st.budget);
        assert_eq!(st.entries, 2);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let cache = Cache::new(100);
        cache.put_corr((1, 0), corr_of(1000, 0.0)); // 8064 bytes > 100
        assert!(cache.get_corr((1, 0)).is_none());
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().bytes, 0);
    }

    #[test]
    fn reinsert_replaces_without_double_counting() {
        let cache = Cache::new(1 << 20);
        cache.put_corr((1, 0), corr_of(16, 1.0));
        let before = cache.stats().bytes;
        cache.put_corr((1, 0), corr_of(16, 2.0));
        assert_eq!(cache.stats().bytes, before, "same size, same accounting");
        assert_eq!(cache.stats().entries, 1);
        assert_eq!(*cache.get_corr((1, 0)).unwrap(), vec![2.0; 16]);
    }

    #[test]
    fn compute_claims_are_exclusive_and_reclaimable() {
        let cache = Cache::new(1 << 20);
        let claim = cache.claim_compute((1, 1));
        assert!(claim.is_some(), "first claimer computes");
        drop(claim);
        assert!(
            cache.claim_compute((1, 1)).is_some(),
            "a released key is claimable again (e.g. after a failed computation)"
        );
        // distinct keys never interfere
        let a = cache.claim_compute((3, 3));
        let b = cache.claim_compute((4, 4));
        assert!(a.is_some() && b.is_some());
    }

    #[test]
    fn concurrent_claimers_coalesce_on_the_computer() {
        use std::sync::mpsc;
        let cache = Arc::new(Cache::new(1 << 20));
        let claim = cache.claim_compute((2, 2)).unwrap();
        let (tx, rx) = mpsc::channel();
        let c2 = cache.clone();
        let waiter = std::thread::spawn(move || {
            let got = c2.claim_compute((2, 2));
            tx.send(got.is_none()).unwrap();
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "the second claimer must block while the key is in flight"
        );
        cache.put_corr((2, 2), corr_of(4, 1.0));
        drop(claim);
        let waited = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("dropping the claim must wake the waiter");
        assert!(waited, "the waiter gets None and re-checks the cache");
        assert!(cache.get_corr((2, 2)).is_some(), "the value is there to re-check");
        waiter.join().unwrap();
    }

    #[test]
    fn corr_and_result_layers_do_not_alias() {
        use crate::service::report::JobResultCore;
        let cache = Cache::new(1 << 20);
        cache.put_corr((7, 7), corr_of(4, 0.5));
        // a result lookup on the same key must miss, not panic or alias
        assert!(cache.get_result((7, 7)).is_none());
        let core = Arc::new(JobResultCore {
            n: 2,
            m: 10,
            orient: crate::service::report::OrientRow::default(),
            levels: vec![],
            skeleton_edges: vec![(0, 1)],
            directed: vec![],
            undirected: vec![(0, 1)],
            order: vec![],
        });
        cache.put_result((8, 8), core.clone());
        assert_eq!(cache.get_result((8, 8)).as_deref(), Some(&*core));
        assert!(cache.get_corr((8, 8)).is_none());
    }
}
