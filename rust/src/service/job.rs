//! Job specifications and the batch manifest.
//!
//! A [`JobSpec`] names a data source — a CSV file, a registered
//! [`crate::sim::datasets`] entry, or a [`crate::sim::scenarios`] grid
//! point — plus the run parameters (engine family, alpha, level cap,
//! correlation kind, orientation rule). The `variant` key resolves
//! through the top-level [`crate::family`] registry, so a manifest can
//! mix both engine kinds — CI-test PC schedules and causal-order
//! engines (`"variant": "lingam"`) — with no other changes. A
//! [`Manifest`] is an ordered list of jobs parsed from JSON
//! (`cupc batch --manifest jobs.json`):
//!
//! ```json
//! {"jobs": [
//!   {"name": "a", "dataset": "nci60-mini", "variant": "cups", "max_level": 1},
//!   {"csv": "data.csv", "alpha": 0.05, "corr": "spearman"},
//!   {"scenario": "grn-mid", "orient": "majority"}
//! ]}
//! ```
//!
//! Exactly one of `csv` / `dataset` / `scenario` addresses the data.
//! Everything else is optional: `name` defaults to `job-<index>`,
//! `variant` to `cups`, `orient` to `standard`; `alpha`, `max_level`
//! and `corr` default to 0.01 / uncapped / `pearson` — except for
//! scenario sources, where they default to the grid point's own values
//! so naming a scenario reproduces it (explicit keys, including
//! `"max_level": null` for uncapped, always override). Dataset and
//! scenario names are validated at parse time so a typo fails before
//! any job runs.

use crate::family::FamilyId;
use crate::sim::{datasets, scenarios};
use crate::skeleton::{Config, OrientRule, Variant};
use crate::stats::corr::CorrKind;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Where a job's observational data comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSource {
    /// CSV file on disk (samples × variables, optional header)
    Csv(PathBuf),
    /// entry of the Table-1 analog registry (`sim::datasets`)
    Dataset(String),
    /// point of the conformance grid (`sim::scenarios::default_grid`)
    Scenario(String),
}

impl DataSource {
    /// Stable display form used in report records.
    pub fn label(&self) -> String {
        match self {
            DataSource::Csv(p) => format!("csv:{}", p.display()),
            DataSource::Dataset(n) => format!("dataset:{n}"),
            DataSource::Scenario(n) => format!("scenario:{n}"),
        }
    }
}

/// One engine run: data source + run parameters.
///
/// Determinism note: every family except `parcpu` produces
/// bit-reproducible records (including per-level test counts — the
/// pipeline's thread-count invariance). `parcpu`'s per-level *test
/// counts* and first-found sepsets are scheduling-dependent by design,
/// so the batch determinism contract covers the deterministic
/// schedules; `parcpu` jobs still produce the identical skeleton.
/// Causal-order families (`lingam`) are fully deterministic, ignore
/// `max_level`, `corr`, and `orient`, and use `alpha` not at all —
/// their decisions are the pairwise-measure scores and the fixed
/// pruning threshold.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub source: DataSource,
    pub family: FamilyId,
    pub alpha: f64,
    pub max_level: Option<usize>,
    pub corr: CorrKind,
    pub orient: OrientRule,
}

impl JobSpec {
    /// The run config for this job at a leased worker width. For
    /// causal-order families the `variant` field is inert (the engine
    /// never reads it) and stays at its default.
    pub fn config(&self, threads: usize) -> Config {
        Config {
            alpha: self.alpha,
            max_level: self.max_level,
            variant: self.family.variant().unwrap_or(Variant::CupcS),
            orient: self.orient,
            ..Config::default()
        }
        .with_threads(threads)
    }

    /// The PC variant, for PC-only layers (`cupc shard`); `None` for
    /// causal-order families.
    pub fn pc_variant(&self) -> Option<Variant> {
        self.family.variant()
    }

    /// Canonical family spelling — the report record's `variant` field
    /// (the key name predates the second engine kind and is pinned for
    /// downstream parsers).
    pub fn variant_name(&self) -> &'static str {
        crate::family::of(self.family).name
    }

    pub fn orient_name(&self) -> &'static str {
        match self.orient {
            OrientRule::Standard => "standard",
            OrientRule::Majority => "majority",
        }
    }
}

/// Canonical CLI spelling of a PC variant (delegates to the top-level
/// [`crate::family`] registry — the single source of truth for family
/// metadata). Kept Variant-typed for PC-only callers (shard plans).
pub fn variant_name(v: Variant) -> &'static str {
    crate::family::of(FamilyId::Pc(v)).name
}

/// Stable tag of a PC variant for content hashing (cache keys and
/// shard-plan bytes depend on it — never renumber). The values live in
/// the registry; `tags_are_stable` below pins every historical
/// assignment so a registry edit can never silently re-key the disk
/// cache.
pub fn variant_tag(v: Variant) -> u8 {
    crate::family::of(FamilyId::Pc(v)).tag
}

/// Stable tag of any engine family (either kind) for content hashing —
/// the generalization [`variant_tag`] is the PC restriction of.
pub fn family_tag(f: FamilyId) -> u8 {
    crate::family::of(f).tag
}

/// Stable tag for content hashing.
pub fn orient_tag(o: OrientRule) -> u8 {
    match o {
        OrientRule::Standard => 0,
        OrientRule::Majority => 1,
    }
}

/// An ordered list of jobs. Record order in the results file is
/// manifest order regardless of scheduling.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub jobs: Vec<JobSpec>,
}

impl Manifest {
    /// Parse a manifest document. Errors name the offending job index.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest JSON")?;
        let jobs_json = root
            .get("jobs")
            .and_then(Json::as_array)
            .context("manifest must be an object with a \"jobs\" array")?;
        Self::from_jobs_json(jobs_json)
    }

    /// Build a manifest from an already-parsed `jobs` array. Shared by
    /// the file loader and the serve protocol's submit requests, so
    /// both surfaces enforce the identical validation (name/source
    /// checks, eager dataset/scenario lookup, duplicate-name rejection).
    pub fn from_jobs_json(jobs_json: &[Json]) -> Result<Manifest> {
        ensure!(!jobs_json.is_empty(), "manifest has no jobs");
        let mut jobs = Vec::with_capacity(jobs_json.len());
        for (idx, j) in jobs_json.iter().enumerate() {
            jobs.push(parse_job(j, idx).with_context(|| format!("job #{idx}"))?);
        }
        let mut names: Vec<&str> = jobs.iter().map(|j| j.name.as_str()).collect();
        names.sort_unstable();
        for w in names.windows(2) {
            ensure!(
                w[0] != w[1],
                "duplicate job name {:?} (records are keyed by name)",
                w[0]
            );
        }
        Ok(Manifest { jobs })
    }

    /// Load and parse a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("manifest {}", path.display()))
    }
}

fn parse_job(j: &Json, idx: usize) -> Result<JobSpec> {
    ensure!(
        matches!(j, Json::Obj(_)),
        "each job must be a JSON object, got {j:?}"
    );
    let src_keys = ["csv", "dataset", "scenario"]
        .iter()
        .filter(|&&k| j.get(k).is_some())
        .count();
    ensure!(
        src_keys == 1,
        "exactly one of \"csv\", \"dataset\", \"scenario\" is required (found {src_keys})"
    );
    let source = if let Some(p) = j.get("csv") {
        DataSource::Csv(PathBuf::from(
            p.as_str().context("\"csv\" must be a path string")?,
        ))
    } else if let Some(d) = j.get("dataset") {
        let name = d.as_str().context("\"dataset\" must be a string")?;
        ensure!(
            datasets::spec(name).is_some(),
            "unknown dataset {name:?} (see `cupc` for the registry)"
        );
        DataSource::Dataset(name.to_string())
    } else {
        let name = j
            .get("scenario")
            .unwrap()
            .as_str()
            .context("\"scenario\" must be a string")?;
        ensure!(
            scenarios::find(name).is_some(),
            "unknown scenario {name:?} (see sim::scenarios::default_grid)"
        );
        DataSource::Scenario(name.to_string())
    };
    // scenario sources default alpha / max_level / corr to the grid
    // point's own values, so `{"scenario": "rank-grn"}` reproduces the
    // conformance point instead of silently running it under the global
    // defaults; explicit keys (including `"max_level": null`) override
    let (default_alpha, default_max_level, default_corr) = match &source {
        DataSource::Scenario(sname) => {
            let sc = scenarios::find(sname).expect("scenario validated above");
            (sc.alpha, sc.max_level, sc.corr)
        }
        _ => (0.01, None, CorrKind::Pearson),
    };
    let name = match j.get("name") {
        Some(v) => v.as_str().context("\"name\" must be a string")?.to_string(),
        None => format!("job-{idx}"),
    };
    let family = match j.get("variant") {
        Some(v) => {
            let s = v.as_str().context("\"variant\" must be a string")?;
            crate::family::parse(s).with_context(|| format!("unknown variant {s:?}"))?
        }
        None => FamilyId::Pc(Variant::CupcS),
    };
    let alpha = match j.get("alpha") {
        Some(v) => v.as_f64().context("\"alpha\" must be a number")?,
        None => default_alpha,
    };
    ensure!(
        alpha > 0.0 && alpha < 1.0,
        "alpha must be in (0, 1), got {alpha}"
    );
    let max_level = match j.get("max_level") {
        None => default_max_level,
        Some(Json::Null) => None,
        Some(v) => Some(
            v.as_usize()
                .context("\"max_level\" must be a non-negative integer or null")?,
        ),
    };
    let corr = match j.get("corr") {
        Some(v) => {
            let s = v.as_str().context("\"corr\" must be a string")?;
            CorrKind::parse(s)
                .with_context(|| format!("unknown corr kind {s:?} (pearson|spearman)"))?
        }
        None => default_corr,
    };
    let orient = match j.get("orient") {
        Some(v) => match v.as_str().context("\"orient\" must be a string")? {
            "standard" => OrientRule::Standard,
            "majority" => OrientRule::Majority,
            other => bail!("unknown orient rule {other:?} (standard|majority)"),
        },
        None => OrientRule::Standard,
    };
    Ok(JobSpec {
        name,
        source,
        family,
        alpha,
        max_level,
        corr,
        orient,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_manifest() {
        let m = Manifest::parse(
            r#"{"jobs": [
                {"name": "a", "dataset": "nci60-mini", "variant": "cupe",
                 "alpha": 0.05, "max_level": 2, "corr": "spearman",
                 "orient": "majority"},
                {"csv": "some/data.csv"},
                {"scenario": "grn-mid", "max_level": null}
            ]}"#,
        )
        .unwrap();
        assert_eq!(m.jobs.len(), 3);
        let a = &m.jobs[0];
        assert_eq!(a.name, "a");
        assert_eq!(a.source, DataSource::Dataset("nci60-mini".into()));
        assert_eq!(a.family, FamilyId::Pc(Variant::CupcE));
        assert_eq!(a.alpha, 0.05);
        assert_eq!(a.max_level, Some(2));
        assert_eq!(a.corr, CorrKind::Spearman);
        assert_eq!(a.orient, OrientRule::Majority);

        let b = &m.jobs[1];
        assert_eq!(b.name, "job-1", "name defaults to the index");
        assert_eq!(b.source, DataSource::Csv(PathBuf::from("some/data.csv")));
        assert_eq!(
            b.family,
            FamilyId::Pc(Variant::CupcS),
            "variant defaults to cups"
        );
        assert_eq!(b.alpha, 0.01);
        assert_eq!(b.max_level, None);
        assert_eq!(b.corr, CorrKind::Pearson);
        assert_eq!(b.orient, OrientRule::Standard);

        assert_eq!(m.jobs[2].source, DataSource::Scenario("grn-mid".into()));
        assert_eq!(m.jobs[2].max_level, None, "explicit null is uncapped");
    }

    #[test]
    fn rejects_bad_manifests() {
        for (text, needle) in [
            ("[]", "\"jobs\" array"),
            (r#"{"jobs": []}"#, "no jobs"),
            (r#"{"jobs": [{}]}"#, "exactly one of"),
            (
                r#"{"jobs": [{"csv": "a.csv", "dataset": "nci60-mini"}]}"#,
                "exactly one of",
            ),
            (r#"{"jobs": [{"dataset": "nope"}]}"#, "unknown dataset"),
            (r#"{"jobs": [{"scenario": "nope"}]}"#, "unknown scenario"),
            (
                r#"{"jobs": [{"csv": "a.csv", "variant": "warp"}]}"#,
                "unknown variant",
            ),
            (r#"{"jobs": [{"csv": "a.csv", "alpha": 1.5}]}"#, "alpha"),
            (
                r#"{"jobs": [{"csv": "a.csv", "max_level": -1}]}"#,
                "max_level",
            ),
            (
                r#"{"jobs": [{"csv": "a.csv", "corr": "kendall"}]}"#,
                "unknown corr",
            ),
            (
                r#"{"jobs": [{"name": "x", "csv": "a.csv"},
                             {"name": "x", "csv": "b.csv"}]}"#,
                "duplicate job name",
            ),
        ] {
            let err = Manifest::parse(text).expect_err(text);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{text}: {msg}");
        }
    }

    #[test]
    fn scenario_jobs_default_to_the_grid_points_parameters() {
        let m = Manifest::parse(r#"{"jobs": [{"scenario": "rank-grn"}]}"#).unwrap();
        let sc = crate::sim::scenarios::find("rank-grn").unwrap();
        let j = &m.jobs[0];
        assert_eq!(j.alpha, sc.alpha);
        assert_eq!(j.max_level, sc.max_level);
        assert_eq!(j.corr, sc.corr);
        assert_eq!(j.corr, CorrKind::Spearman, "rank-grn is a Spearman point");
        assert_eq!(j.max_level, Some(2), "rank-grn is capped at 2");
        // explicit keys still override, including null for uncapped
        let m = Manifest::parse(
            r#"{"jobs": [{"scenario": "rank-grn", "corr": "pearson",
                          "max_level": null, "alpha": 0.05}]}"#,
        )
        .unwrap();
        let j = &m.jobs[0];
        assert_eq!(j.corr, CorrKind::Pearson);
        assert_eq!(j.max_level, None);
        assert_eq!(j.alpha, 0.05);
        // non-scenario sources keep the global defaults
        let m = Manifest::parse(r#"{"jobs": [{"csv": "a.csv"}]}"#).unwrap();
        assert_eq!(m.jobs[0].alpha, 0.01);
        assert_eq!(m.jobs[0].max_level, None);
        assert_eq!(m.jobs[0].corr, CorrKind::Pearson);
    }

    #[test]
    fn config_carries_job_parameters() {
        let m = Manifest::parse(
            r#"{"jobs": [{"scenario": "rank-er", "variant": "serial",
                          "alpha": 0.05, "max_level": 3, "orient": "majority"}]}"#,
        )
        .unwrap();
        let cfg = m.jobs[0].config(5);
        assert_eq!(cfg.alpha, 0.05);
        assert_eq!(cfg.max_level, Some(3));
        assert_eq!(cfg.variant, Variant::Serial);
        assert_eq!(cfg.orient, OrientRule::Majority);
        assert_eq!(cfg.threads, 5);
    }

    /// A manifest can mix both engine kinds: the lingam spelling
    /// resolves through the top-level registry and its config carries
    /// the shared knobs (threads) while the PC-only `variant` field
    /// stays inert at its default.
    #[test]
    fn manifest_accepts_the_lingam_family() {
        let m = Manifest::parse(
            r#"{"jobs": [
                {"scenario": "grn-mid", "variant": "reversed"},
                {"name": "l", "scenario": "grn-mid", "variant": "lingam"}
            ]}"#,
        )
        .unwrap();
        let l = &m.jobs[1];
        assert_eq!(l.family, FamilyId::Lingam);
        assert_eq!(l.variant_name(), "lingam");
        assert_eq!(l.pc_variant(), None);
        assert_eq!(l.config(3).threads, 3);
        assert_eq!(family_tag(FamilyId::Lingam), 7);
        // the alias spellings resolve too
        for alias in ["paralingam", "direct-lingam", "LINGAM"] {
            let m = Manifest::parse(&format!(
                r#"{{"jobs": [{{"csv": "a.csv", "variant": "{alias}"}}]}}"#
            ))
            .unwrap();
            assert_eq!(m.jobs[0].family, FamilyId::Lingam, "{alias}");
        }
    }

    #[test]
    fn tags_are_injective() {
        use crate::sim::scenarios::ALL_VARIANTS;
        let mut tags: Vec<u8> = ALL_VARIANTS.iter().map(|&v| variant_tag(v)).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), ALL_VARIANTS.len());
        let mut names: Vec<&str> = ALL_VARIANTS.iter().map(|&v| variant_name(v)).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALL_VARIANTS.len(), "variant names must be unique");
        assert_ne!(
            orient_tag(OrientRule::Standard),
            orient_tag(OrientRule::Majority)
        );
    }

    /// Disk-cache compatibility: these exact assignments have shipped —
    /// a registry reshuffle that changes any of them silently invalidates
    /// (or worse, cross-contaminates) every persistent cache, so they are
    /// pinned one by one. New families append fresh tags.
    #[test]
    fn tags_are_stable() {
        for (v, tag, name) in [
            (Variant::Serial, 0u8, "serial"),
            (Variant::ParallelCpu, 1, "parcpu"),
            (Variant::CupcE, 2, "cupc-e"),
            (Variant::CupcS, 3, "cupc-s"),
            (Variant::Baseline1, 4, "baseline1"),
            (Variant::Baseline2, 5, "baseline2"),
            (Variant::Reversed, 6, "reversed"),
        ] {
            assert_eq!(variant_tag(v), tag, "{v:?}");
            assert_eq!(variant_name(v), name, "{v:?}");
            assert_eq!(
                Variant::parse(name),
                Some(v),
                "canonical name must parse back to the variant"
            );
        }
        assert_eq!(family_tag(FamilyId::Lingam), 7, "lingam appended at 7");
        assert_eq!(orient_tag(OrientRule::Standard), 0);
        assert_eq!(orient_tag(OrientRule::Majority), 1);
    }

    #[test]
    fn manifest_accepts_the_reversed_family() {
        let m = Manifest::parse(
            r#"{"jobs": [{"scenario": "grn-mid", "variant": "reversed"}]}"#,
        )
        .unwrap();
        assert_eq!(m.jobs[0].family, FamilyId::Pc(Variant::Reversed));
        assert_eq!(m.jobs[0].variant_name(), "reversed");
        assert_eq!(m.jobs[0].config(2).variant, Variant::Reversed);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(
            DataSource::Dataset("x".into()).label(),
            "dataset:x"
        );
        assert_eq!(
            DataSource::Csv(PathBuf::from("a/b.csv")).label(),
            "csv:a/b.csv"
        );
        assert_eq!(DataSource::Scenario("s".into()).label(), "scenario:s");
    }
}
