//! The batch scheduler: N jobs in flight under one global worker budget.
//!
//! Two nested levels of parallelism share a single pool of
//! `BatchOptions::threads` workers:
//!
//! * **across jobs** — up to `job_threads` jobs run concurrently;
//! * **within a job** — each job leases workers from the shared
//!   [`ThreadBudget`] and runs its skeleton pipeline at the leased
//!   width ([`crate::skeleton::Config::with_threads`]).
//!
//! The lease policy is work-conserving: a job asks for its fair share of
//! the *remaining* jobs (so seven small jobs split the budget) but a
//! job that arrives when the queue has drained is handed every idle
//! worker — big jobs borrow the workers small jobs no longer need.
//! Leases are released on job completion, never resized mid-job.
//!
//! Determinism: the lease size, the number of job workers, and the
//! cache state can only change wall-clock time. Per-job results are
//! thread-count invariant (the pipeline contract), the correlation gram
//! is blocked identically for any width, cache values are exactly what
//! a cold computation produces, and reports are collected by manifest
//! index — so the rendered results stream is bit-identical for any
//! `job_threads`, any budget, and warm vs. cold cache
//! (`tests/batch_runner.rs` gates all three).

use super::cache::{self, Cache, CacheStats};
use super::job::{DataSource, JobSpec, Manifest};
use super::report::{JobReport, JobResultCore};
use crate::api::pc_stable_corr;
use crate::data::csv::load_csv;
use crate::sim::{datasets, scenarios};
use crate::skeleton::available_threads;
use crate::stats::corr::DataMatrix;
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A counting budget of pipeline workers shared by every in-flight job.
pub struct ThreadBudget {
    state: Mutex<BudgetState>,
    cv: Condvar,
    total: usize,
}

struct BudgetState {
    available: usize,
    /// callers currently inside `lease` (for fair division)
    waiters: usize,
}

impl ThreadBudget {
    pub fn new(total: usize) -> Self {
        let total = total.max(1);
        ThreadBudget {
            state: Mutex::new(BudgetState {
                available: total,
                waiters: 0,
            }),
            cv: Condvar::new(),
            total,
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Lease between 1 and `want` workers, blocking while none are
    /// available. The grant is capped at the fair share of what is idle
    /// among concurrent leasers, so simultaneous arrivals split the
    /// budget instead of the first one draining it.
    pub fn lease(&self, want: usize) -> Lease<'_> {
        let want = want.max(1);
        let mut st = self.state.lock().unwrap();
        st.waiters += 1;
        while st.available == 0 {
            st = self.cv.wait(st).unwrap();
        }
        let fair = (st.available / st.waiters).max(1);
        let n = fair.min(want).min(st.available);
        st.available -= n;
        st.waiters -= 1;
        drop(st);
        Lease { budget: self, n }
    }

    fn release(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        st.available += n;
        drop(st);
        self.cv.notify_all();
    }
}

/// A held worker allocation; returns the workers on drop.
pub struct Lease<'a> {
    budget: &'a ThreadBudget,
    /// number of workers granted (≥ 1)
    pub n: usize,
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        self.budget.release(self.n);
    }
}

/// Batch-run knobs.
#[derive(Clone, Debug)]
pub struct BatchOptions {
    /// jobs in flight at once
    pub job_threads: usize,
    /// global pipeline-worker budget shared by all in-flight jobs
    pub threads: usize,
    /// cache byte budget
    pub cache_bytes: usize,
    /// per-job progress on stderr
    pub verbose: bool,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            job_threads: 1,
            threads: available_threads(),
            cache_bytes: 256 << 20,
            verbose: false,
        }
    }
}

/// Everything a batch run produces, reports in manifest order.
pub struct BatchOutput {
    pub reports: Vec<JobReport>,
    pub cache: CacheStats,
}

fn load_data(spec: &JobSpec) -> Result<DataMatrix> {
    match &spec.source {
        DataSource::Csv(p) => Ok(load_csv(p)?.0),
        DataSource::Dataset(name) => {
            let s = datasets::spec(name).with_context(|| format!("unknown dataset {name:?}"))?;
            Ok(datasets::generate(s).data)
        }
        DataSource::Scenario(name) => {
            let sc = scenarios::find(name).with_context(|| format!("unknown scenario {name:?}"))?;
            Ok(sc.generate_data().1)
        }
    }
}

/// Run one job at a leased worker width against the shared cache.
pub fn run_job(spec: &JobSpec, threads: usize, cache: &Cache) -> Result<JobReport> {
    let t = Timer::start();
    let data = load_data(spec).with_context(|| format!("job {:?}", spec.name))?;
    let seconds_load = t.elapsed_s();

    let t = Timer::start();
    let dk = cache::data_key(&data, spec.corr);
    let (corr, corr_cache_hit) = loop {
        if let Some(c) = cache.get_corr(dk) {
            break (c, true);
        }
        // coalesce concurrent jobs over the same data: one computes the
        // gram, the others wait on the claim and then re-check the cache
        if let Some(claim) = cache.claim_compute(dk) {
            let c = Arc::new(spec.corr.matrix(&data, threads));
            cache.put_corr(dk, c.clone());
            drop(claim);
            break (c, false);
        }
    };
    let seconds_corr = t.elapsed_s();

    let t = Timer::start();
    let rk = cache::result_key(
        &corr,
        data.n,
        data.m,
        spec.alpha,
        spec.max_level,
        spec.variant,
        spec.orient,
    );
    let (core, result_cache_hit) = loop {
        if let Some(c) = cache.get_result(rk) {
            break (c, true);
        }
        if let Some(claim) = cache.claim_compute(rk) {
            let cfg = spec.config(threads);
            let res = pc_stable_corr(&corr, data.n, data.m, &cfg).map(|r| {
                let core = Arc::new(JobResultCore::from_pc(&r, data.n, data.m));
                cache.put_result(rk, core.clone());
                core
            });
            drop(claim); // release before `?` so a failure never strands waiters
            let core = res
                .with_context(|| format!("job {:?} ({})", spec.name, spec.source.label()))?;
            break (core, false);
        }
    };
    let seconds_run = t.elapsed_s();

    Ok(JobReport {
        core,
        seconds_load,
        seconds_corr,
        seconds_run,
        corr_cache_hit,
        result_cache_hit,
        threads_used: threads,
    })
}

/// Run every manifest job, up to `job_threads` concurrently, under one
/// shared [`ThreadBudget`] and [`Cache`]. Reports come back in manifest
/// order. On a job failure the batch stops claiming new jobs (jobs
/// already in flight run to completion) and the lowest-index error is
/// reported.
pub fn run_batch(manifest: &Manifest, opts: &BatchOptions, cache: &Cache) -> Result<BatchOutput> {
    let njobs = manifest.jobs.len();
    let workers = opts.job_threads.clamp(1, njobs.max(1));
    let budget = ThreadBudget::new(opts.threads);
    let mut slots: Vec<Option<Result<JobReport>>> = Vec::with_capacity(njobs);
    slots.resize_with(njobs, || None);

    if workers <= 1 {
        for (idx, spec) in manifest.jobs.iter().enumerate() {
            let lease = budget.lease(budget.total());
            if opts.verbose {
                eprintln!("[batch] job {idx} {:?}: {} worker(s)", spec.name, lease.n);
            }
            let rep = run_job(spec, lease.n, cache);
            let failed = rep.is_err();
            slots[idx] = Some(rep);
            if failed {
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        let aborted = AtomicBool::new(false);
        let results = Mutex::new(slots);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    if aborted.load(Ordering::Relaxed) {
                        break;
                    }
                    let idx = next.fetch_add(1, Ordering::Relaxed);
                    if idx >= njobs {
                        break;
                    }
                    let spec = &manifest.jobs[idx];
                    // fair share of the queue that is left; the last
                    // jobs standing borrow the drained queue's workers
                    let remaining = njobs - idx;
                    let want = (budget.total() / workers.min(remaining)).max(1);
                    let lease = budget.lease(want);
                    if opts.verbose {
                        eprintln!("[batch] job {idx} {:?}: {} worker(s)", spec.name, lease.n);
                    }
                    let rep = run_job(spec, lease.n, cache);
                    drop(lease);
                    if rep.is_err() {
                        aborted.store(true, Ordering::Relaxed);
                    }
                    results.lock().unwrap()[idx] = Some(rep);
                });
            }
        });
        slots = results.into_inner().unwrap();
    }

    let mut reports = Vec::with_capacity(njobs);
    for (idx, slot) in slots.into_iter().enumerate() {
        // claims are handed out in index order, so a failure (Some(Err))
        // always precedes the skipped suffix (None) — the real error is
        // what surfaces
        let rep = slot
            .with_context(|| format!("job #{idx} skipped after an earlier job failed"))?
            .with_context(|| format!("job #{idx} failed"))?;
        reports.push(rep);
    }
    Ok(BatchOutput {
        reports,
        cache: cache.stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::report::render_results;
    use crate::skeleton::{OrientRule, Variant};
    use crate::stats::corr::CorrKind;

    fn scenario_job(name: &str, scenario: &str, alpha: f64, corr: CorrKind) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            source: DataSource::Scenario(scenario.to_string()),
            variant: Variant::CupcS,
            alpha,
            max_level: None,
            corr,
            orient: OrientRule::Standard,
        }
    }

    #[test]
    fn budget_grants_are_bounded_and_returned() {
        let b = ThreadBudget::new(8);
        assert_eq!(b.total(), 8);
        {
            let lone = b.lease(100);
            assert_eq!(lone.n, 8, "a lone leaser borrows the whole budget");
        }
        let small = b.lease(3);
        assert_eq!(small.n, 3, "want caps the grant");
        let rest = b.lease(100);
        assert_eq!(rest.n, 5, "only the idle workers are grantable");
        drop(small);
        drop(rest);
        assert_eq!(b.lease(100).n, 8, "drops return every worker");
    }

    #[test]
    fn zero_budget_still_grants_one() {
        let b = ThreadBudget::new(0);
        assert_eq!(b.total(), 1, "a budget can never be empty");
        assert_eq!(b.lease(1).n, 1);
    }

    #[test]
    fn exhausted_budget_blocks_until_release() {
        use std::sync::mpsc;
        let b = Arc::new(ThreadBudget::new(1));
        let first = b.lease(1);
        let (tx, rx) = mpsc::channel();
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || {
            let lease = b2.lease(1);
            tx.send(lease.n).unwrap();
            drop(lease);
        });
        // the waiter cannot proceed while the budget is held
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "lease must block while the budget is exhausted"
        );
        drop(first);
        let granted = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("release must wake the waiter");
        assert_eq!(granted, 1);
        waiter.join().unwrap();
    }

    /// Cold vs. warm `run_job`: the warm run is served from the cache
    /// and its core is bitwise identical to the recomputed one — the
    /// cache-correctness satellite at the API level.
    #[test]
    fn warm_job_is_cached_and_bitwise_identical() {
        let spec = scenario_job("a", "sparse-a01", 0.01, CorrKind::Pearson);
        let cache = Cache::new(64 << 20);
        let cold = run_job(&spec, 2, &cache).unwrap();
        assert!(!cold.corr_cache_hit);
        assert!(!cold.result_cache_hit);
        let warm = run_job(&spec, 1, &cache).unwrap();
        assert!(warm.corr_cache_hit);
        assert!(warm.result_cache_hit);
        assert_eq!(cold.core, warm.core, "cached result must be bitwise equal");
        // an independent cold run recomputes the same bytes
        let fresh = run_job(&spec, 4, &Cache::new(64 << 20)).unwrap();
        assert_eq!(cold.core, fresh.core);
    }

    /// Two alphas over one dataset share the correlation layer.
    #[test]
    fn corr_layer_is_shared_across_alphas() {
        let cache = Cache::new(64 << 20);
        let a = run_job(
            &scenario_job("a", "sparse-a01", 0.01, CorrKind::Pearson),
            1,
            &cache,
        )
        .unwrap();
        let b = run_job(
            &scenario_job("b", "sparse-a01", 0.05, CorrKind::Pearson),
            1,
            &cache,
        )
        .unwrap();
        assert!(!a.corr_cache_hit);
        assert!(b.corr_cache_hit, "same data + kind must reuse the gram");
        assert!(!b.result_cache_hit, "different alpha is a different result");
        // Spearman over the same data is a different correlation identity
        let c = run_job(
            &scenario_job("c", "sparse-a01", 0.01, CorrKind::Spearman),
            1,
            &cache,
        )
        .unwrap();
        assert!(!c.corr_cache_hit);
    }

    #[test]
    fn run_batch_is_job_thread_invariant_and_ordered() {
        let manifest = Manifest {
            jobs: vec![
                scenario_job("one", "sparse-a01", 0.01, CorrKind::Pearson),
                scenario_job("two", "sparse-a01", 0.05, CorrKind::Pearson),
                scenario_job("three", "grn-mid", 0.01, CorrKind::Pearson),
                scenario_job("four", "rank-er", 0.01, CorrKind::Spearman),
            ],
        };
        let run = |job_threads: usize| {
            let cache = Cache::new(64 << 20);
            let out = run_batch(
                &manifest,
                &BatchOptions {
                    job_threads,
                    threads: 4,
                    ..BatchOptions::default()
                },
                &cache,
            )
            .unwrap();
            render_results(&manifest.jobs, &out.reports)
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(4));
        assert_eq!(serial.lines().count(), 4);
    }

    /// A failure must stop the queue: later jobs are skipped, not run.
    #[test]
    fn a_failing_job_stops_the_queue() {
        let manifest = Manifest {
            jobs: vec![
                JobSpec {
                    name: "bad".into(),
                    source: DataSource::Csv("no/such/file.csv".into()),
                    variant: Variant::CupcS,
                    alpha: 0.01,
                    max_level: None,
                    corr: CorrKind::Pearson,
                    orient: OrientRule::Standard,
                },
                scenario_job("later", "sparse-a01", 0.01, CorrKind::Pearson),
            ],
        };
        let cache = Cache::new(1 << 20);
        let err = run_batch(&manifest, &BatchOptions::default(), &cache)
            .expect_err("the bad job must fail the batch");
        assert!(format!("{err:#}").contains("job #0"), "{err:#}");
        // the bad job dies before touching the cache, so any cache
        // traffic would mean the second job ran after the failure
        let st = cache.stats();
        assert_eq!(
            st.hits + st.misses,
            0,
            "the queue must stop before the next job starts: {st:?}"
        );
    }

    #[test]
    fn batch_errors_name_the_failing_job() {
        let manifest = Manifest {
            jobs: vec![JobSpec {
                name: "missing".into(),
                source: DataSource::Csv("definitely/not/here.csv".into()),
                variant: Variant::CupcS,
                alpha: 0.01,
                max_level: None,
                corr: CorrKind::Pearson,
                orient: OrientRule::Standard,
            }],
        };
        let err = run_batch(
            &manifest,
            &BatchOptions::default(),
            &Cache::new(1 << 20),
        )
        .expect_err("missing CSV must fail the batch");
        let msg = format!("{err:#}");
        assert!(msg.contains("missing"), "{msg}");
        assert!(msg.contains("not/here.csv"), "{msg}");
    }
}
